"""Autoscaling under a million-request open-loop load, on a virtual clock.

The REAL :class:`~repro.serve.autoscale.AutoscalePolicy` — the same object
``FleetRouter.step_all`` consults — drives a deterministic queueing
simulator through a ramp / flash-crowd-spike / decay schedule from
``benchmarks.traces.open_loop_arrivals`` (Zipf-bucketed lengths,
seed-pinned, streamed tick by tick so ~10^6 requests never materialize in
memory at once). Per-request service times come from the repo's own cost
model: ``compile_entry`` prices prefill at each bucket edge and one decode
step per hardware model at the FULL architecture dims, so the simulator
runs in the real cost regime — v5e/v6e prefill costs diverge ~4.5x
(compute-bound) while decode diverges ~2x (bandwidth-bound), which is
exactly the asymmetry the policy's mix-weighted candidate pricing exists
to exploit.

Why a simulator and not real engines: at 10^6 requests the point under
test is the POLICY (signals -> decisions -> capacity), not the kernels.
The policy cannot tell the difference — it only sees the adapter protocol
(``live_instances`` / ``queue_depths`` / ``ttft_window_since`` /
``traffic_mix`` / ``price_candidate`` / ``scale_join`` / …) that
:class:`SimFleet` implements identically to ``FleetRouter``; the
real-router integration is covered by ``tests/test_autoscale.py``.

Arms and assertions (exit 1 on violation; CI runs ``--smoke``):

  static    right-sized fixed fleet — enough v5e instances to absorb the
            spike rate, computed from the cost model (the capacity
            baseline the policy must approach);
  policy    starts at ``min_instances=1`` and autoscales over a
            heterogeneous {v5e at price 1.0, v6e at price 3.0} pool.

  1. zero lost requests in every arm: completed == submitted at ~10^6
     scale, every queue fully drained;
  2. the policy holds pooled p95 TTFT within ``TTFT_P95_FACTOR`` x the
     static fleet's p95 while spending FEWER instance-steps (elasticity
     pays for its reaction lag);
  3. the policy actually scales: >= 1 join and >= 1 drain, and the fleet
     returns to ``min_instances`` live members by the end of the decay;
  4. byte-identical traces and identical decision logs across a full
     re-run (same seed -> same schedule -> same decisions);
  5. cross-model join divergence: under a compute-heavy mix the first
     join is the high-FLOPs model (tpu_v6e despite its 3x price), under
     a memory-heavy mix the high-bandwidth-per-price model (tpu_v5e) —
     the paper's cross-model result at fleet-capacity granularity.

``--trace-out`` writes the balanced policy run's trace (the re-run lands
at ``<stem>.rerun<suffix>`` for CI's ``trace_report --diff``);
``--decisions-out`` writes the decision logs as a JSON artifact. TTFT
spans are sampled 1-in-``TTFT_SAMPLE_EVERY`` into the trace so
``trace_report`` reads a meaningful (and bounded) latency summary.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
from collections import deque
from typing import Dict, List, Optional, Tuple

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from traces import OPEN_LOOP_MIXES, open_loop_arrivals, zipf_weights

ARCH = "qwen2-1.5b"
EDGES = (512, 4096, 32768)
#: Candidate pool: hardware -> relative $/instance-step. v6e is faster on
#: BOTH axes, so without pricing it would always win; at 3x the price it
#: wins only where its advantage exceeds 3x — prefill-heavy traffic.
PRICES = {"tpu_v5e": 1.0, "tpu_v6e": 3.0}
TICK_S = 0.5                     # virtual seconds per simulator tick
FULL_REF_LEN = 32768
TTFT_SAMPLE_EVERY = 997          # 1-in-N trace sampling (prime stride)
TTFT_P95_FACTOR = 10.0
MAX_DRAIN_TICKS = 50_000

SMOKE = dict(total=20_000, peak_rate=60.0, mix_total=4_000)
FULL = dict(total=1_000_000, peak_rate=120.0, mix_total=200_000)

SEED = 11


class VirtualClock:
    """Injectable tracer clock; the driver advances it between ticks."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


# -- cost model --------------------------------------------------------------
def cost_table(plans_path: Optional[str], print_fn) -> Dict[str, dict]:
    """hardware -> {"prefill": {edge: s}, "decode_step": s} at the FULL
    architecture dims (batch 1). A ``--plans`` artifact is consulted
    first (exact-match cells only — a nearest/cross-hardware donor's
    score is the donor's, not this cell's); anything it misses is
    compiled fresh."""
    from repro import configs
    from repro.core import HARDWARE_REGISTRY, Autotuner
    from repro.core.plans import TilePlan, compile_entry
    from repro.launch.specs import kernel_problems

    plans = None
    if plans_path and os.path.exists(plans_path):
        plans = TilePlan.load(plans_path)

    cfg = configs.get_arch(ARCH)

    def score(kernel: str, problem, hw) -> float:
        if plans is not None:
            res = plans.resolve(kernel, problem, "float32", hw)
            if res is not None and getattr(res, "source", None) == "exact":
                return res.score_s
        return compile_entry(kernel, problem, "float32", hw,
                             autotuner=Autotuner()).score_s

    costs: Dict[str, dict] = {}
    for hw_name in sorted(PRICES):
        hw = HARDWARE_REGISTRY[hw_name]
        prefill = {}
        for edge in EDGES:
            prob = kernel_problems(cfg, 1, edge, "prefill")["flash_attention"]
            prefill[edge] = score("flash_attention", prob, hw)
        dec_prob = kernel_problems(cfg, 1, FULL_REF_LEN,
                                   "decode")["flash_decode"]
        costs[hw_name] = {
            "prefill": prefill,
            "decode_step": score("flash_decode", dec_prob, hw),
        }
        print_fn(f"# {hw_name}: prefill "
                 + ", ".join(f"@{e}={prefill[e]:.3e}s" for e in EDGES)
                 + f", decode_step={costs[hw_name]['decode_step']:.3e}s")
    return costs


def service_s(costs: Dict[str, dict], hw: str, bucket: int,
              new_tokens: int) -> float:
    c = costs[hw]
    return c["prefill"][bucket] + new_tokens * c["decode_step"]


def expected_service_s(costs: Dict[str, dict], hw: str, mix: str) -> float:
    """Analytic expected per-request seconds for one generator mix — used
    to right-size the static arm from the cost model alone."""
    order, (nt_lo, nt_hi) = OPEN_LOOP_MIXES[mix]
    edges = sorted(EDGES)
    ranked = edges if order == "asc" else edges[::-1]
    w = zipf_weights(len(ranked))
    avg_nt = (nt_lo + nt_hi) / 2.0
    return sum(float(wi) * service_s(costs, hw, b, int(round(avg_nt)))
               for wi, b in zip(w, ranked))


# -- the queueing simulator --------------------------------------------------
class SimInstance:
    """One simulated server: FIFO queue, ``TICK_S`` seconds of service
    capacity per tick. A queue item is (submit_t, bucket, prefill_s,
    total_s); TTFT = time the prefill portion completes - submit."""

    __slots__ = ("name", "hw", "queue", "head_done", "backlog_s")

    def __init__(self, name: str, hw: str):
        self.name = name
        self.hw = hw
        self.queue: deque = deque()
        self.head_done = 0.0
        self.backlog_s = 0.0


class SimFleet:
    """The autoscale adapter protocol over SimInstances — duck-typed
    identically to ``FleetRouter``'s implementation, so the policy under
    test is byte-for-byte the production one."""

    def __init__(self, costs: Dict[str, dict], clock: VirtualClock,
                 proc=None):
        self.costs = costs
        self.clock = clock
        self.proc = proc
        self.instances: Dict[str, SimInstance] = {}
        self.status: Dict[str, str] = {}
        self.ttfts: List[float] = []
        self.submitted = 0
        self.completed = 0
        self.instance_steps = 0
        self.peak_live = 0
        self._mix: Dict[int, int] = {}
        self._nt_sum = 0
        self._nt_n = 0

    def add_instance(self, name: str, hw: str) -> None:
        self.instances[name] = SimInstance(name, hw)
        self.status[name] = "live"

    # -- adapter protocol --------------------------------------------------
    def live_instances(self) -> List[str]:
        return [n for n in sorted(self.instances)
                if self.status[n] == "live"]

    def known_instances(self) -> set:
        return set(self.instances)

    def instance_hardware(self, name: str) -> Optional[str]:
        inst = self.instances.get(name)
        return inst.hw if inst is not None else None

    def queue_depths(self) -> Dict[str, int]:
        return {n: len(inst.queue)
                for n, inst in sorted(self.instances.items())}

    def ttft_marks(self) -> int:
        return len(self.ttfts)

    def ttft_window_since(self, mark) -> Tuple[List[float], bool]:
        return list(self.ttfts[mark or 0:]), False

    def traffic_mix(self) -> Tuple[Dict[int, int], int, int]:
        return dict(self._mix), self._nt_sum, self._nt_n

    def pool_occupancy(self) -> float:
        return 0.0

    def orphan_count(self) -> int:
        return 0

    def _mix_price(self, hw: str, mix, nt: int) -> float:
        if not mix:
            mix = {e: 1 for e in EDGES}
        total_w = sum(mix.values())
        return sum(w * service_s(self.costs, hw, b, nt)
                   for b, w in sorted(mix.items())) / max(total_w, 1)

    def price_instance(self, name: str, mix, nt: int) -> float:
        return self._mix_price(self.instances[name].hw, mix, nt)

    def price_candidate(self, cand, mix, nt: int) -> float:
        return self._mix_price(cand.hardware, mix, nt)

    def scale_join(self, name: str, engine: SimInstance) -> None:
        self.instances[name] = engine
        self.status[name] = "live"

    def scale_drain(self, name: str) -> None:
        if self.status.get(name) == "live":
            self.status[name] = "draining"

    def record_autoscale(self, decision) -> None:
        if self.proc is not None:
            self.proc.autoscale(decision.action, decision.instance,
                                decision.hardware, decision.reason,
                                decision.signals)

    # -- load + service ----------------------------------------------------
    def submit(self, t: float, length: int, new_tokens: int) -> None:
        bucket = next(e for e in EDGES if length <= e)
        self._mix[bucket] = self._mix.get(bucket, 0) + 1
        self._nt_sum += new_tokens
        self._nt_n += 1
        live = self.live_instances()
        best, best_score = None, None
        for n in live:
            inst = self.instances[n]
            svc = service_s(self.costs, inst.hw, bucket, new_tokens)
            score = svc * (1.0 + inst.backlog_s / TICK_S)
            if best_score is None or (score, n) < (best_score, best):
                best, best_score = n, score
        inst = self.instances[best]
        pf = self.costs[inst.hw]["prefill"][bucket]
        total = service_s(self.costs, inst.hw, bucket, new_tokens)
        inst.queue.append((t, bucket, pf, total))
        inst.backlog_s += total
        self.submitted += 1

    def tick(self, t0: float) -> None:
        """Serve up to TICK_S seconds of queued work on every powered
        instance; record TTFTs at the virtual time prefill completes."""
        for name in sorted(self.instances):
            st = self.status[name]
            if st not in ("live", "draining"):
                continue
            self.instance_steps += 1
            inst = self.instances[name]
            budget = TICK_S
            while inst.queue and budget > 1e-12:
                submit_t, bucket, pf, total = inst.queue[0]
                rem = total - inst.head_done
                take = min(rem, budget)
                if inst.head_done < pf <= inst.head_done + take + 1e-12:
                    ttft = (t0 + (TICK_S - budget)
                            + (pf - inst.head_done)) - submit_t
                    self.ttfts.append(ttft)
                    if (self.proc is not None
                            and len(self.ttfts) % TTFT_SAMPLE_EVERY == 1):
                        self.proc.span(
                            0, "ttft", "lifecycle", submit_t, ttft,
                            args={"rid": len(self.ttfts), "bucket": bucket})
                inst.head_done += take
                budget -= take
                inst.backlog_s = max(inst.backlog_s - take, 0.0)
                if take >= rem - 1e-12:
                    inst.queue.popleft()
                    inst.head_done = 0.0
                    self.completed += 1
            if st == "draining" and not inst.queue:
                self.status[name] = "drained"
        self.peak_live = max(self.peak_live, len(self.live_instances()))

    def pending(self) -> int:
        return sum(len(inst.queue) for inst in self.instances.values())


# -- arms --------------------------------------------------------------------
def make_policy(costs, n_max: int):
    from repro.serve import AutoscalePolicy, ScaleCandidate

    candidates = tuple(
        ScaleCandidate(name=hw.split("_")[-1], hardware=hw,
                       make_engine=lambda name, hw=hw: SimInstance(name, hw),
                       price=PRICES[hw])
        for hw in sorted(PRICES))
    return AutoscalePolicy(
        candidates, min_instances=1, max_instances=n_max,
        interval=2, cooldown=1,
        queue_high=32.0, queue_low=2.0,
        ttft_high=2.0 * TICK_S, ttft_low=0.5 * TICK_S,
        low_evals=6, min_ttft_samples=32,
        instance_prices={"a": PRICES["tpu_v5e"]})


def run_arm(costs, *, total: int, peak_rate: float, mix: str,
            static_n: Optional[int] = None, n_max: int = 8,
            tracer=None, clock: Optional[VirtualClock] = None):
    """One full ramp/spike/decay pass. ``static_n`` fixes that many v5e
    instances with no policy; otherwise the arm starts at one v5e and the
    AutoscalePolicy decides everything."""
    proc = (tracer.attach("sim-fleet", kind="router") if tracer is not None
            else None)
    fleet = SimFleet(costs, clock or VirtualClock(), proc=proc)
    policy = None
    if static_n is not None:
        for i in range(static_n):
            fleet.add_instance(f"s{i}", "tpu_v5e")
    else:
        fleet.add_instance("a", "tpu_v5e")
        policy = make_policy(costs, n_max)
    phase_seen = []
    last_tick = 0
    for tick, phase, batch in open_loop_arrivals(
            SEED, EDGES, total, peak_rate=peak_rate, mix=mix):
        t0 = tick * TICK_S
        if clock is not None:
            clock.t = t0
        if not phase_seen or phase_seen[-1] != phase:
            phase_seen.append(phase)
        for length, nt in batch:
            fleet.submit(t0, length, nt)
        fleet.tick(t0)
        if policy is not None:
            policy.observe(fleet, tick)
        last_tick = tick
    drain_ticks = 0
    while fleet.pending():
        last_tick += 1
        drain_ticks += 1
        if drain_ticks > MAX_DRAIN_TICKS:
            break
        t0 = last_tick * TICK_S
        if clock is not None:
            clock.t = t0
        fleet.tick(t0)
        if policy is not None:
            policy.observe(fleet, last_tick)
    if tracer is not None:
        tracer.flush()
    return dict(fleet=fleet, policy=policy, ticks=last_tick + 1,
                phases=phase_seen)


def run(smoke: bool = False, plans_path: Optional[str] = None,
        trace_out: Optional[str] = None, decisions_out: Optional[str] = None,
        print_fn=print) -> int:
    from repro import kernels
    from repro.obs import Tracer, write_trace
    from repro.serve.metrics import nearest_rank

    kernels.register_all()
    p = SMOKE if smoke else FULL
    costs = cost_table(plans_path, print_fn)

    failures = 0

    def check(cond: bool, msg: str) -> None:
        nonlocal failures
        if not cond:
            failures += 1
            print_fn(f"FAIL: {msg}")

    # Right-size the static arm from the cost model: enough v5e capacity
    # to absorb the spike rate with one instance of headroom.
    svc = expected_service_s(costs, "tpu_v5e", "balanced")
    static_n = math.ceil(p["peak_rate"] * 3.0 * svc / TICK_S) + 1
    print_fn(f"# balanced mix: E[service] on tpu_v5e = {svc * 1e3:.2f}ms "
             f"-> static fleet = {static_n} x tpu_v5e")

    def p95(arm) -> float:
        return nearest_rank(arm["fleet"].ttfts, 0.95)

    def summarize(label: str, arm) -> None:
        f = arm["fleet"]
        n_dec = len(arm["policy"].decisions) if arm["policy"] else 0
        print_fn(f"{label}: {f.completed}/{f.submitted} requests over "
                 f"{arm['ticks']} ticks, p95 TTFT={p95(arm) * 1e3:.1f}ms, "
                 f"instance_steps={f.instance_steps}, "
                 f"peak_live={f.peak_live}, decisions={n_dec}")

    # -- static right-sized baseline ---------------------------------------
    static = run_arm(costs, total=p["total"], peak_rate=p["peak_rate"],
                     mix="balanced", static_n=static_n)
    summarize("static", static)
    check(static["fleet"].completed == static["fleet"].submitted
          and static["fleet"].submitted == p["total"],
          f"static: lost requests ({static['fleet'].completed}/"
          f"{static['fleet'].submitted}, expected {p['total']})")

    # -- policy arm (with trace) -------------------------------------------
    clock = VirtualClock()
    tracer = Tracer(clock=clock)
    policy_arm = run_arm(costs, total=p["total"], peak_rate=p["peak_rate"],
                         mix="balanced", n_max=static_n, tracer=tracer,
                         clock=clock)
    summarize("policy", policy_arm)
    pf, pol = policy_arm["fleet"], policy_arm["policy"]
    check(policy_arm["phases"] == ["ramp", "spike", "decay"],
          f"policy: phases out of order: {policy_arm['phases']}")
    check(pf.completed == pf.submitted and pf.submitted == p["total"],
          f"policy: lost requests ({pf.completed}/{pf.submitted}, "
          f"expected {p['total']})")
    joins = [d for d in pol.decisions if d.action == "join"]
    drains = [d for d in pol.decisions if d.action == "drain"]
    check(len(joins) >= 1, "policy: never joined capacity")
    check(len(drains) >= 1, "policy: never drained capacity")
    check(pf.peak_live > 1, "policy: fleet never grew past 1 instance")
    check(len(pf.live_instances()) == pol.min_instances,
          f"policy: decay did not return the fleet to min_instances "
          f"(live={pf.live_instances()})")
    check(p95(policy_arm) <= TTFT_P95_FACTOR * p95(static),
          f"policy p95 TTFT {p95(policy_arm):.4f}s exceeds "
          f"{TTFT_P95_FACTOR}x static {p95(static):.4f}s")
    check(pf.instance_steps < static["fleet"].instance_steps,
          f"policy used {pf.instance_steps} instance-steps, static only "
          f"{static['fleet'].instance_steps} — elasticity saved nothing")

    # -- determinism: full re-run, identical decisions + trace bytes -------
    clock2 = VirtualClock()
    tracer2 = Tracer(clock=clock2)
    rerun = run_arm(costs, total=p["total"], peak_rate=p["peak_rate"],
                    mix="balanced", n_max=static_n, tracer=tracer2,
                    clock=clock2)
    log1 = [d.as_dict() for d in pol.decisions]
    log2 = [d.as_dict() for d in rerun["policy"].decisions]
    check(log1 == log2, "determinism: re-run decision log differs")
    check(rerun["fleet"].ttfts == pf.ttfts,
          "determinism: re-run TTFT stream differs")
    if trace_out:
        stem, suffix = os.path.splitext(trace_out)
        rerun_out = f"{stem}.rerun{suffix or '.json'}"
        write_trace(tracer, trace_out)
        write_trace(tracer2, rerun_out)
        with open(trace_out, "rb") as f:
            b1 = f.read()
        with open(rerun_out, "rb") as f:
            b2 = f.read()
        check(b1 == b2, "determinism: re-run trace is not byte-identical")
        print_fn(f"# trace written to {trace_out} ({len(tracer.events)} "
                 f"events; re-run at {rerun_out} is byte-identical)")

    # -- cross-model join divergence by traffic mix ------------------------
    first_join = {}
    for mix in ("compute_heavy", "memory_heavy"):
        arm = run_arm(costs, total=p["mix_total"],
                      peak_rate=p["peak_rate"] / 2, mix=mix, n_max=6)
        summarize(mix, arm)
        f = arm["fleet"]
        check(f.completed == f.submitted and f.submitted == p["mix_total"],
              f"{mix}: lost requests ({f.completed}/{f.submitted})")
        mix_joins = [d for d in arm["policy"].decisions
                     if d.action == "join"]
        check(len(mix_joins) >= 1, f"{mix}: policy never joined")
        if mix_joins:
            first_join[mix] = mix_joins[0].hardware
            print_fn(f"# {mix}: first join = {mix_joins[0].hardware} "
                     f"(reason={mix_joins[0].reason})")
    if len(first_join) == 2:
        check(first_join["compute_heavy"] == "tpu_v6e",
              f"compute-heavy mix joined {first_join['compute_heavy']}, "
              f"expected tpu_v6e (prefill advantage 4.5x > 3x price)")
        check(first_join["memory_heavy"] == "tpu_v5e",
              f"memory-heavy mix joined {first_join['memory_heavy']}, "
              f"expected tpu_v5e (decode advantage 2x < 3x price)")
        check(first_join["compute_heavy"] != first_join["memory_heavy"],
              "mixes joined the same hardware — no cross-model divergence")

    if decisions_out:
        payload = {
            "balanced": pol.as_dict(),
            "static_n": static_n,
            "first_join_by_mix": first_join,
            "p95_ttft_s": {"policy": p95(policy_arm), "static": p95(static)},
            "instance_steps": {"policy": pf.instance_steps,
                               "static": static["fleet"].instance_steps},
        }
        with open(decisions_out, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print_fn(f"# decision log written to {decisions_out}")

    print_fn("PASS" if not failures else f"{failures} FAILURES")
    return failures


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="2e4-request schedule for CI (seconds, not minutes)")
    ap.add_argument("--plans", default=None,
                    help="compiled TilePlan artifact; exact-match cells are "
                         "reused for the cost table, everything else is "
                         "compiled fresh")
    ap.add_argument("--trace-out", default=None,
                    help="write the balanced policy run's deterministic "
                         "trace here (re-run lands at <stem>.rerun<suffix>; "
                         "the bench asserts byte equality and CI diffs the "
                         "pair with trace_report --diff)")
    ap.add_argument("--decisions-out", default=None,
                    help="write the autoscale decision log JSON here "
                         "(uploaded as a CI artifact)")
    args = ap.parse_args()
    sys.exit(1 if run(smoke=args.smoke, plans_path=args.plans,
                      trace_out=args.trace_out,
                      decisions_out=args.decisions_out)
             else 0)


if __name__ == "__main__":
    main()
