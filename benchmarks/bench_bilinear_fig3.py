"""Paper Fig. 3 reproduction: bilinear tile sweep x scales x 2 GPU models.

The paper measured wall-clock on a GTX260 and a GeForce 8800 GTS for an
800x800 source upscaled by 2/4/6/8/10 across CUDA block dims. We evaluate
the same sweep through the cost model calibrated with their Table I
descriptors, and report the same qualitative results (see
tests/test_paper_claims.py for the pinned assertions).

CSV: scale,gpu,tile_wxh,cost_ms,is_best
"""
import itertools

import repro.kernels.bilinear.ops  # noqa: F401
from repro.core import Autotuner, GEFORCE_8800GTS, GTX260
from repro.core.tiling import TileShape

SWEEP = [TileShape((h, w)) for h, w in itertools.product((4, 8, 16, 32),
                                                         repeat=2)]
SCALES = (2, 4, 6, 8, 10)


def run(print_fn=print):
    at = Autotuner()
    print_fn("scale,gpu,tile,cost_ms,is_best")
    summary = {}
    for scale in SCALES:
        prob = dict(src_h=800, src_w=800, scale=scale)
        for hw in (GTX260, GEFORCE_8800GTS):
            res = at.sweep("bilinear_cuda", prob, "float32", hw, tiles=SWEEP)
            best = res.best.tile
            summary[(scale, hw.name)] = (best, res.best.score,
                                         res.sensitivity())
            for e in sorted(res.entries, key=lambda e: e.tile):
                print_fn(
                    f"{scale},{hw.name},{e.tile[1]}x{e.tile[0]},"
                    f"{e.score * 1e3:.3f},{int(e.tile == best)}"
                )
    print_fn("# summary: scale gpu best_tile(WxH) best_ms sensitivity")
    for (scale, gpu), (t, s, sens) in summary.items():
        print_fn(f"# {scale} {gpu} {t[1]}x{t[0]} {s*1e3:.2f} {sens:.2f}")
    return summary


if __name__ == "__main__":
    run()
