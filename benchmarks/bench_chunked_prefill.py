"""Unchunked vs one-chunk-per-step vs step-packed prefill on shared traces.

The head-of-line scenario the chunked scheduler exists for: a long prompt
(the "32k" class) is admitted just before a burst of small prompts (the
"512" class). With monolithic prefill the whole long prompt occupies one
engine step, so every queued small request's first token waits behind it;
with chunked prefill the engine builds mixed steps — one plan-sized prefill
chunk co-scheduled with the decode batch under a per-step token budget —
and small prefills overtake between chunks. **Step packing** densifies the
mixed step further: SEVERAL in-flight prefills' chunks ride one launch
under the plan's per-hardware pack width, so a burst of shorts stops
serializing one chunk per step. The **paged** arm runs the same packed
schedule on the fleet-wide paged KV pool (page size from the plan's
``kv_page`` cell): prefill residency is bounded by pool headroom instead
of ``prefill_slots``, so under pool pressure it holds strictly more
concurrent in-flight prefills than the contiguous arms' slot cap.

All arms drive the real ``ServeEngine`` (identical model, plan, trace, and
greedy outputs) on a **cost-model virtual clock**: after every engine step
the clock advances by the step's modeled seconds (tokens processed x the
plan's per-token prefill/decode cost + a fixed step overhead), so the
TTFT/TPOT comparison is deterministic, hardware-independent, and measures
exactly what this subsystem changes — the schedule, not the arithmetic.
``--smoke`` scales the trace to the reduced config (long = top bucket edge)
so CI finishes in seconds; the full trace uses the literal 512/32k mix.

Asserted invariants (exit 1 on violation; CI runs ``--smoke``):
  1. p95 small-request TTFT: chunked < unchunked on the mixed trace, and
     packed no worse than chunked;
  2. equal work all arms (paged included): same completions, same greedy
     tokens; chunked and paged total virtual time within ``MAX_SLOWDOWN``
     of unchunked, and packed total virtual time <= chunked (packing only
     removes steps); the paged pool drains balanced (refcounts to zero),
     and on ``--trace overflow_heavy`` the paged arm's peak resident
     prefills strictly exceed ``prefill_slots``;
  3. the ``chunked_prefill`` plan cell compiles *different chunk lengths*,
     the ``packed_prefill`` cell *different pack widths*, AND the
     ``kv_page`` cell *different KV page sizes* on tpu_v5e vs tpu_v6e at
     full dims (the paper's per-hardware-model optimum, applied to the
     chunk-length, pack-width, and page-size tile axes);
  4. a prompt longer than every bucket edge is admitted via chunking and
     completes (the overflow-admission fix), instead of being dropped.

Traces come from ``benchmarks/traces.py`` (shared with
``bench_serve_scheduler`` and ``tests/test_serve_packing.py``); ``--trace
FAMILY`` swaps the default head-of-line trace for a seed-pinned
adversarial family (``all_short`` / ``all_long`` / ``bimodal`` /
``overflow_heavy``) — the exact prompts the conformance suite replays.
``--hist-out packing_hist.json`` dumps the packed arm's
chunks-per-step histogram plus the paged arm's pool counters (the CI
artifact). ``--trace-out trace.json`` records all four arms into one
deterministic virtual-clock lifecycle trace (one Perfetto process per
arm, see ``repro.obs``) and asserts the trace's per-arm ``ttft`` spans
reproduce the reported p95 TTFTs.

``--plans plans.json`` reuses a compiled artifact (the CI workflow passes
the compile-plans job's artifact) instead of recompiling; the bench falls
back to compiling its own serving cells when the artifact is missing or
does not cover the bench's shape family.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Tuple

import numpy as np

import traces as trace_lib

SMOKE = dict(
    edges=(64, 1024),
    small_lens=(10, 24, 40, 60, 18, 33, 51, 12, 45, 28),
    long_lens=(900, 980),
    new_tokens=3,
    slots=2,
    # Room for >= 2 small-bucket chunks + the decode batch per step, so the
    # packed arm actually packs (the budget is what it trades against).
    step_token_budget=200,
    prefill_slots=4,
    arrivals_per_step=3,
)
FULL = dict(
    edges=(512, 32768),
    small_lens=(120, 300, 480, 200, 410, 90, 350, 260, 440, 160),
    long_lens=(30000, 32000),
    new_tokens=3,
    slots=2,
    step_token_budget=2600,
    prefill_slots=4,
    arrivals_per_step=3,
)
HARDWARE = "tpu_v5e"
DIVERGENCE_HW = ("tpu_v5e", "tpu_v6e")
ARCH = "qwen2-1.5b"
STEP_OVERHEAD_S = 20e-6
MAX_SLOWDOWN = 1.5


class VirtualClock:
    """Injectable engine clock; the driver advances it between steps."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def make_trace(params: dict, rng: np.random.Generator,
               vocab: int) -> List[np.ndarray]:
    """Long prompt first, then the small burst, then the second long —
    the head-of-line pattern (shared builder: benchmarks/traces.py)."""
    lens = trace_lib.head_of_line_lengths(params["small_lens"],
                                          params["long_lens"])
    return trace_lib.prompts(lens, rng, vocab)


def load_or_compile_plan(path: Optional[str], cfg, edges, slots: int,
                         max_len: int, print_fn) -> object:
    """Reuse a compiled artifact when it covers this bench's shape family;
    compile the serving cells otherwise."""
    del cfg  # the serving cells are derived from ARCH's smoke config
    from repro.launch.compile_plans import (
        load_or_compile_cells, serve_bucket_cells,
    )

    cells = serve_bucket_cells([ARCH], edges, slots, max_len, smoke=True)
    return load_or_compile_cells(
        path, cells, (HARDWARE,),
        meta={"generated_by": "bench_chunked_prefill"}, print_fn=print_fn)


FULL_REF_LEN = 32768  # the prefill cell the per-token cost is taken from


def step_cost_model(slots: int, max_len: int):
    """(per-prefill-token s, per-decode-step s) for the virtual clock.

    Costed at the FULL architecture's dims — the smoke trace scales the
    executed lengths down so CI finishes in seconds, but the clock keeps
    the real cost regime, where a monolithic long prefill is orders of
    magnitude above the per-step overhead. Prefill is per-token from the
    32k flash_attention cell; decode is one slot-batch step over the
    engine's actual cache length. Both arms use the same constants, so
    only the schedule differs.
    """
    from repro import configs
    from repro.core import HARDWARE_REGISTRY, Autotuner
    from repro.core.plans import compile_entry
    from repro.launch.specs import kernel_problems

    cfg_full = configs.get_arch(ARCH)
    hw = HARDWARE_REGISTRY[HARDWARE]
    tuner = Autotuner()
    pf_prob = kernel_problems(cfg_full, 1, FULL_REF_LEN,
                              "prefill")["flash_attention"]
    t_pf = compile_entry("flash_attention", pf_prob, "float32", hw,
                         autotuner=tuner).score_s / FULL_REF_LEN
    dec_prob = kernel_problems(cfg_full, slots, max_len,
                               "decode")["flash_decode"]
    t_dec = compile_entry("flash_decode", dec_prob, "float32", hw,
                          autotuner=tuner).score_s
    return t_pf, t_dec


def drive(engine, clock: VirtualClock, trace, new_tokens: int,
          arrivals_per_step: int, t_pf: float, t_dec: float,
          max_steps: int = 20000) -> Tuple[Dict[int, float], int]:
    """Open-loop virtual-time drive; returns (rid -> submit virtual time,
    peak concurrently-resident prefills — the occupancy the paged pool
    unlocks past ``prefill_slots``)."""
    submit_t: Dict[int, float] = {}
    i = 0
    peak_resident = 0
    for tick in range(max_steps):
        while i < len(trace) and i < arrivals_per_step * (tick + 1):
            rid = engine.add_request(trace[i], max_new_tokens=new_tokens)
            assert rid is not None, f"trace request {i} rejected"
            submit_t[rid] = clock.t
            i += 1
        if not (engine.step() or engine.scheduler.pending()) \
                and i >= len(trace):
            break
        peak_resident = max(peak_resident, len(engine._chunking))
        stats = engine.last_step_stats
        # One decode step advances the whole slot batch at once.
        clock.t += (STEP_OVERHEAD_S + stats["prefill_tokens"] * t_pf
                    + (t_dec if stats["decode_tokens"] else 0.0))
    return submit_t, peak_resident


def run(smoke: bool = False, plans_path: Optional[str] = None,
        trace_family: Optional[str] = None, hist_out: Optional[str] = None,
        trace_out: Optional[str] = None, print_fn=print) -> int:
    import jax

    from repro import configs, kernels
    from repro.core import HARDWARE_REGISTRY
    from repro.models import api
    from repro.serve import BucketPolicy, ServeEngine, ShapeBucketScheduler

    kernels.register_all()
    p = SMOKE if smoke else FULL
    edges, slots = p["edges"], p["slots"]
    new_tokens = p["new_tokens"]
    small_edge, top = min(edges), max(edges)
    max_len = top + new_tokens + 8
    cfg = configs.get_smoke(ARCH)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    if trace_family:
        # Seed-pinned adversarial family — the exact prompts the packing
        # conformance suite replays (benchmarks/traces.py).
        trace = trace_lib.make_trace(trace_family, seed=0,
                                     vocab=cfg.vocab_size, edges=edges)
    else:
        trace = make_trace(p, rng, cfg.vocab_size)
    allow_overflow = any(len(pr) > top for pr in trace)
    plan = load_or_compile_plan(plans_path, cfg, edges, slots, max_len,
                                print_fn)
    t_pf, t_dec = step_cost_model(slots, max_len)
    print_fn(f"# trace: {trace_lib.trace_summary(trace, edges)} "
             f"(family={trace_family or 'head_of_line (default)'}); "
             f"virtual clock t_pf={t_pf:.2e}s/tok t_dec={t_dec:.2e}s/step")

    # One tracer spans all four arms; each arm attaches as its own
    # Perfetto process and the tracer's clock follows the arm currently
    # driving (virtual clocks -> the exported trace is deterministic).
    tracer = None
    clock_box: Dict[str, Optional[VirtualClock]] = {"clock": None}
    if trace_out:
        from repro.obs import Tracer

        tracer = Tracer(clock=lambda: clock_box["clock"].t
                        if clock_box["clock"] is not None else 0.0)

    failures = 0
    results = {}
    packed_hist: Dict[str, int] = {}
    paged_pool: Dict[str, object] = {}
    for mode in ("unchunked", "chunked", "packed", "paged"):
        clock = VirtualClock()
        clock_box["clock"] = clock
        eng = ServeEngine(
            cfg, params,
            max_len=(max_len if not allow_overflow
                     else 2 * top + new_tokens + 8),
            slots=slots, plans=plan,
            hardware=HARDWARE_REGISTRY[HARDWARE],
            scheduler=ShapeBucketScheduler(
                BucketPolicy(edges, max_queue=len(trace) + 1,
                             allow_overflow=allow_overflow)),
            clock=clock,
            chunk_prefill=(mode != "unchunked"),
            pack_prefill=(mode in ("packed", "paged")),
            prefill_slots=p["prefill_slots"],
            step_token_budget=(p["step_token_budget"]
                               if mode != "unchunked" else 0),
            paged=(mode == "paged"),
            tracer=tracer, instance=mode)
        _, resident_peak = drive(eng, clock, trace, new_tokens,
                                 p["arrivals_per_step"], t_pf, t_dec)
        if tracer is not None:
            tracer.flush()  # close this arm's deferred step span on its clock
        m = eng.metrics.as_dict()
        small = m["ttft_s"].get(str(small_edge), {})
        results[mode] = dict(
            wall=clock.t,
            completed=eng.metrics.completed,
            tokens={r.rid: tuple(r.out_tokens) for r in eng._finished},
            p95=small.get("p95_s", 0.0),
            p50=small.get("p50_s", 0.0),
            mean=small.get("mean_s", 0.0),
            chunks=dict(eng.metrics.chunks_per_prefill),
            resident_peak=resident_peak,
        )
        if mode == "packed":
            packed_hist = {str(n): c for n, c in sorted(
                eng.metrics.packed_chunks_per_step.items())}
        if mode == "paged":
            eng.pool.check_balanced()        # refcounts drained to zero
            paged_pool = dict(m["pool"], resident_peak=resident_peak,
                              page=eng.pool.page)
            print_fn(f"# paged pool: page={eng.pool.page} "
                     f"pages={eng.pool.n_pages} "
                     f"used_max={m['pool']['pages_used_max']} "
                     f"resident_peak={resident_peak} "
                     f"(prefill_slots={p['prefill_slots']})")
        print_fn(f"{mode}: total={clock.t * 1e3:.2f}ms virtual, "
                 f"completed={eng.metrics.completed}, small-bucket TTFT "
                 f"mean={results[mode]['mean'] * 1e3:.2f}ms "
                 f"p50={results[mode]['p50'] * 1e3:.2f}ms "
                 f"p95={results[mode]['p95'] * 1e3:.2f}ms "
                 f"chunks/prefill={results[mode]['chunks']}")
    print_fn(f"# packed chunks/step histogram: {packed_hist}")
    if hist_out:
        with open(hist_out, "w") as f:
            json.dump({"packed_chunks_per_step": packed_hist,
                       "paged_pool": paged_pool,
                       "trace": trace_lib.trace_summary(trace, edges),
                       "family": trace_family or "head_of_line",
                       "results": {m: {k: v for k, v in r.items()
                                       if k != "tokens"}
                                   for m, r in results.items()}},
                      f, indent=1, sort_keys=True)
        print_fn(f"# packed histogram written to {hist_out}")

    if tracer is not None:
        # Export, reload, and check the trace against the metrics it rode
        # along with: nearest-rank p95 over each arm's small-bucket ``ttft``
        # span durations must reproduce the arm's reported p95 exactly.
        from repro.obs import load_trace, write_trace
        from repro.serve.metrics import nearest_rank

        write_trace(tracer, trace_out)
        reloaded = load_trace(trace_out)
        pid_by_mode = {pr["name"]: pr["pid"] for pr in reloaded["procs"]}
        for mode in ("unchunked", "chunked", "packed", "paged"):
            durs = [ev.get("dur", 0.0) for ev in reloaded["events"]
                    if ev.get("name") == "ttft"
                    and ev["pid"] == pid_by_mode[mode]
                    and (ev.get("args") or {}).get("bucket") == small_edge]
            trace_p95 = nearest_rank(durs, 0.95)
            if not np.isclose(trace_p95, results[mode]["p95"], rtol=1e-9,
                              atol=0.0):
                failures += 1
                print_fn(f"FAIL: {mode} trace ttft p95 {trace_p95:.6e}s "
                         f"!= metrics p95 {results[mode]['p95']:.6e}s")
        print_fn(f"# trace written to {trace_out} ({len(tracer.events)} "
                 f"events; per-arm trace p95 TTFT matches ServeMetrics)")

    # 1. tail TTFT of small requests: chunked beats unchunked, packed is
    # no worse than one-chunk-per-step. The chunked-vs-unchunked win is
    # the head-of-line effect — it only exists when long prompts block
    # shorts, so it is asserted only on traces that contain longs
    # (all_short has no head-of-line to cut; packing must still hold).
    summary = trace_lib.trace_summary(trace, edges)
    if summary["small"] > 0 and summary["long"] + summary["overflow"] > 0:
        if not results["chunked"]["p95"] < results["unchunked"]["p95"]:
            failures += 1
            print_fn(f"FAIL: chunked small-request p95 TTFT "
                     f"{results['chunked']['p95']:.4f}s not below unchunked "
                     f"{results['unchunked']['p95']:.4f}s")
    if results["packed"]["p95"] > results["chunked"]["p95"]:
        failures += 1
        print_fn(f"FAIL: packed small-request p95 TTFT "
                 f"{results['packed']['p95']:.4f}s above one-chunk "
                 f"{results['chunked']['p95']:.4f}s")
    # 2. equal work: same completions and greedy tokens, bounded overhead;
    # packing only removes steps, so packed virtual time <= one-chunk.
    for mode in ("chunked", "packed", "paged"):
        if results[mode]["completed"] != results["unchunked"]["completed"]:
            failures += 1
            print_fn(f"FAIL: {mode} completion count differs from unchunked")
        if results[mode]["tokens"] != results["unchunked"]["tokens"]:
            failures += 1
            print_fn(f"FAIL: {mode} greedy outputs differ from unchunked "
                     f"(parity broken)")
    if results["chunked"]["wall"] > MAX_SLOWDOWN * results["unchunked"]["wall"]:
        failures += 1
        print_fn(f"FAIL: chunked total virtual time "
                 f"{results['chunked']['wall']:.4f}s exceeds "
                 f"{MAX_SLOWDOWN}x unchunked "
                 f"{results['unchunked']['wall']:.4f}s")
    if results["packed"]["wall"] > results["chunked"]["wall"]:
        failures += 1
        print_fn(f"FAIL: packed total virtual time "
                 f"{results['packed']['wall']:.4f}s exceeds one-chunk "
                 f"{results['chunked']['wall']:.4f}s (throughput regressed)")
    if results["paged"]["wall"] > MAX_SLOWDOWN * results["unchunked"]["wall"]:
        failures += 1
        print_fn(f"FAIL: paged total virtual time "
                 f"{results['paged']['wall']:.4f}s exceeds "
                 f"{MAX_SLOWDOWN}x unchunked "
                 f"{results['unchunked']['wall']:.4f}s")
    # 2b. occupancy: the paged pool decouples resident prefills from
    # ``prefill_slots`` — on the pool-pressure trace the paged arm must
    # hold strictly more concurrent in-flight prefills than the slot cap
    # (the contiguous arms are clamped to it by construction).
    if trace_family == "overflow_heavy":
        if results["paged"]["resident_peak"] <= p["prefill_slots"]:
            failures += 1
            print_fn(f"FAIL: paged resident_peak "
                     f"{results['paged']['resident_peak']} not above "
                     f"prefill_slots={p['prefill_slots']} — the pool did "
                     f"not unlock occupancy past the contiguous cap")
        else:
            print_fn(f"# occupancy: paged held "
                     f"{results['paged']['resident_peak']} resident "
                     f"prefills > prefill_slots={p['prefill_slots']}")

    # 3. per-hardware divergence at full dims: chunk length (32k prompt)
    # and pack width (the 512-token small-request class).
    from repro.core import Autotuner
    from repro.core.plans import compile_entry
    from repro.launch.specs import kernel_problems

    cfg_full = configs.get_arch(ARCH)
    prob = kernel_problems(cfg_full, 1, 32768,
                           "chunked_prefill")["chunked_prefill"]
    chunk_by_hw = {}
    for hw_name in DIVERGENCE_HW:
        entry = compile_entry("chunked_prefill", prob, "float32",
                              HARDWARE_REGISTRY[hw_name],
                              autotuner=Autotuner())
        chunk_by_hw[hw_name] = entry.tile[0]
        print_fn(f"# chunked_prefill @ sq=32768 on {hw_name}: "
                 f"tile {entry.tile} ({entry.dominant}-bound)")
    if len(set(chunk_by_hw.values())) < 2:
        failures += 1
        print_fn(f"FAIL: chunk length does not diverge across "
                 f"{DIVERGENCE_HW}: {chunk_by_hw}")
    pack_prob = kernel_problems(cfg_full, 1, 512,
                                "packed_prefill")["packed_prefill"]
    pack_by_hw = {}
    for hw_name in DIVERGENCE_HW:
        entry = compile_entry("packed_prefill", pack_prob, "float32",
                              HARDWARE_REGISTRY[hw_name],
                              autotuner=Autotuner())
        pack_by_hw[hw_name] = entry.tile[0]
        print_fn(f"# packed_prefill @ sq=512 on {hw_name}: "
                 f"tile {entry.tile} ({entry.dominant}-bound)")
    if len(set(pack_by_hw.values())) < 2:
        failures += 1
        print_fn(f"FAIL: pack width does not diverge across "
                 f"{DIVERGENCE_HW}: {pack_by_hw}")
    # KV page size diverges too: the pool's page geometry is a plan cell,
    # so different hardware models get different page sizes at full dims
    # (probed at the 32k decode cell — the power-of-two cache length the
    # serving buckets compile).
    page_prob = kernel_problems(cfg_full, p["slots"], 32768,
                                "decode")["kv_page"]
    page_by_hw = {}
    for hw_name in DIVERGENCE_HW:
        entry = compile_entry("kv_page", page_prob, "float32",
                              HARDWARE_REGISTRY[hw_name],
                              autotuner=Autotuner())
        page_by_hw[hw_name] = entry.tile[0]
        print_fn(f"# kv_page @ skv=32768 on {hw_name}: "
                 f"tile {entry.tile} ({entry.dominant}-bound)")
    if len(set(page_by_hw.values())) < 2:
        failures += 1
        print_fn(f"FAIL: KV page size does not diverge across "
                 f"{DIVERGENCE_HW}: {page_by_hw}")

    # 4. overflow admission: longer than every edge, admitted via chunking.
    clock = VirtualClock()
    eng = ServeEngine(
        cfg, params, max_len=2 * top + new_tokens + 8, slots=slots,
        plans=plan, hardware=HARDWARE_REGISTRY[HARDWARE],
        scheduler=ShapeBucketScheduler(
            BucketPolicy(edges, allow_overflow=True)),
        clock=clock, chunk_prefill=True,
        step_token_budget=p["step_token_budget"])
    overflow = rng.integers(2, cfg.vocab_size,
                            size=top + small_edge).astype(np.int32)
    rid = eng.add_request(overflow, max_new_tokens=new_tokens)
    done = eng.run_until_done(max_steps=20000)
    if rid is None or len(done) != 1 or len(done[0].out_tokens) != new_tokens:
        failures += 1
        print_fn("FAIL: over-length prompt was not served via chunked "
                 "overflow admission")
    else:
        print_fn(f"# overflow: len-{len(overflow)} prompt admitted at "
                 f"bucket {done[0].bucket}, served in "
                 f"{dict(eng.metrics.chunks_per_prefill)} chunks")

    print_fn("PASS" if not failures else f"{failures} FAILURES")
    return failures


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="scaled trace for CI (seconds, not minutes)")
    ap.add_argument("--plans", default=None,
                    help="compiled TilePlan artifact to reuse (falls back "
                         "to compiling the bench's own serving cells)")
    ap.add_argument("--trace", default=None, choices=trace_lib.FAMILIES,
                    help="replace the default head-of-line trace with a "
                         "seed-pinned adversarial family (shared with the "
                         "packing conformance suite)")
    ap.add_argument("--hist-out", default=None,
                    help="write the packed arm's chunks-per-step histogram "
                         "to this JSON path (the CI artifact)")
    ap.add_argument("--trace-out", default=None,
                    help="write a deterministic (virtual-clock) lifecycle "
                         "trace of all four arms to this path — one "
                         "Perfetto process per arm; the bench asserts the "
                         "trace reproduces its reported p95 TTFTs")
    args = ap.parse_args()
    sys.exit(1 if run(smoke=args.smoke, plans_path=args.plans,
                      trace_family=args.trace, hist_out=args.hist_out,
                      trace_out=args.trace_out)
             else 0)


if __name__ == "__main__":
    main()
