"""Chunked vs monolithic prefill under a mixed small/long request trace.

The head-of-line scenario the chunked scheduler exists for: a long prompt
(the "32k" class) is admitted just before a burst of small prompts (the
"512" class). With monolithic prefill the whole long prompt occupies one
engine step, so every queued small request's first token waits behind it;
with chunked prefill the engine builds mixed steps — one plan-sized prefill
chunk co-scheduled with the decode batch under a per-step token budget —
and small prefills overtake between chunks.

Both arms drive the real ``ServeEngine`` (identical model, plan, trace, and
greedy outputs) on a **cost-model virtual clock**: after every engine step
the clock advances by the step's modeled seconds (tokens processed x the
plan's per-token prefill/decode cost + a fixed step overhead), so the
TTFT/TPOT comparison is deterministic, hardware-independent, and measures
exactly what this subsystem changes — the schedule, not the arithmetic.
``--smoke`` scales the trace to the reduced config (long = top bucket edge)
so CI finishes in seconds; the full trace uses the literal 512/32k mix.

Asserted invariants (exit 1 on violation; CI runs ``--smoke``):
  1. p95 small-request TTFT: chunked < unchunked on the mixed trace;
  2. equal work both arms: same completions, same greedy tokens, and
     chunked total virtual time within ``MAX_SLOWDOWN`` of unchunked
     (the chunk-overhead bound — "equal total throughput");
  3. the ``chunked_prefill`` plan cell compiles *different chunk lengths*
     on tpu_v5e vs tpu_v6e at the full-dims 32k prompt (the paper's
     per-hardware-model optimum, applied to the chunk-length tile axis);
  4. a prompt longer than every bucket edge is admitted via chunking and
     completes (the overflow-admission fix), instead of being dropped.

``--plans plans.json`` reuses a compiled artifact (the CI workflow passes
the compile-plans job's artifact) instead of recompiling; the bench falls
back to compiling its own serving cells when the artifact is missing or
does not cover the bench's shape family.
"""
from __future__ import annotations

import argparse
import sys
from typing import Dict, List, Optional, Tuple

import numpy as np

SMOKE = dict(
    edges=(64, 1024),
    small_lens=(10, 24, 40, 60, 18, 33, 51, 12, 45, 28),
    long_lens=(900, 980),
    new_tokens=3,
    slots=2,
    step_token_budget=80,
    arrivals_per_step=2,
)
FULL = dict(
    edges=(512, 32768),
    small_lens=(120, 300, 480, 200, 410, 90, 350, 260, 440, 160),
    long_lens=(30000, 32000),
    new_tokens=3,
    slots=2,
    step_token_budget=2600,
    arrivals_per_step=2,
)
HARDWARE = "tpu_v5e"
DIVERGENCE_HW = ("tpu_v5e", "tpu_v6e")
ARCH = "qwen2-1.5b"
STEP_OVERHEAD_S = 20e-6
MAX_SLOWDOWN = 1.5


class VirtualClock:
    """Injectable engine clock; the driver advances it between steps."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def make_trace(params: dict, rng: np.random.Generator,
               vocab: int) -> List[np.ndarray]:
    """Long prompt first, then the small burst, then the second long —
    the head-of-line pattern."""
    lens = [params["long_lens"][0], *params["small_lens"][:6],
            params["long_lens"][1], *params["small_lens"][6:]]
    return [rng.integers(2, vocab, size=int(n)).astype(np.int32)
            for n in lens]


def load_or_compile_plan(path: Optional[str], cfg, edges, slots: int,
                         max_len: int, print_fn) -> object:
    """Reuse a compiled artifact when it covers this bench's shape family;
    compile the serving cells otherwise."""
    del cfg  # the serving cells are derived from ARCH's smoke config
    from repro.launch.compile_plans import (
        load_or_compile_cells, serve_bucket_cells,
    )

    cells = serve_bucket_cells([ARCH], edges, slots, max_len, smoke=True)
    return load_or_compile_cells(
        path, cells, (HARDWARE,),
        meta={"generated_by": "bench_chunked_prefill"}, print_fn=print_fn)


FULL_REF_LEN = 32768  # the prefill cell the per-token cost is taken from


def step_cost_model(slots: int, max_len: int):
    """(per-prefill-token s, per-decode-step s) for the virtual clock.

    Costed at the FULL architecture's dims — the smoke trace scales the
    executed lengths down so CI finishes in seconds, but the clock keeps
    the real cost regime, where a monolithic long prefill is orders of
    magnitude above the per-step overhead. Prefill is per-token from the
    32k flash_attention cell; decode is one slot-batch step over the
    engine's actual cache length. Both arms use the same constants, so
    only the schedule differs.
    """
    from repro import configs
    from repro.core import HARDWARE_REGISTRY, Autotuner
    from repro.core.plans import compile_entry
    from repro.launch.specs import kernel_problems

    cfg_full = configs.get_arch(ARCH)
    hw = HARDWARE_REGISTRY[HARDWARE]
    tuner = Autotuner()
    pf_prob = kernel_problems(cfg_full, 1, FULL_REF_LEN,
                              "prefill")["flash_attention"]
    t_pf = compile_entry("flash_attention", pf_prob, "float32", hw,
                         autotuner=tuner).score_s / FULL_REF_LEN
    dec_prob = kernel_problems(cfg_full, slots, max_len,
                               "decode")["flash_decode"]
    t_dec = compile_entry("flash_decode", dec_prob, "float32", hw,
                          autotuner=tuner).score_s
    return t_pf, t_dec


def drive(engine, clock: VirtualClock, trace, new_tokens: int,
          arrivals_per_step: int, t_pf: float, t_dec: float,
          max_steps: int = 20000) -> Dict[int, float]:
    """Open-loop virtual-time drive; returns rid -> submit virtual time."""
    submit_t: Dict[int, float] = {}
    i = 0
    for tick in range(max_steps):
        while i < len(trace) and i < arrivals_per_step * (tick + 1):
            rid = engine.add_request(trace[i], max_new_tokens=new_tokens)
            assert rid is not None, f"trace request {i} rejected"
            submit_t[rid] = clock.t
            i += 1
        if not (engine.step() or engine.scheduler.pending()) \
                and i >= len(trace):
            break
        stats = engine.last_step_stats
        # One decode step advances the whole slot batch at once.
        clock.t += (STEP_OVERHEAD_S + stats["prefill_tokens"] * t_pf
                    + (t_dec if stats["decode_tokens"] else 0.0))
    return submit_t


def run(smoke: bool = False, plans_path: Optional[str] = None,
        print_fn=print) -> int:
    import jax

    from repro import configs, kernels
    from repro.core import HARDWARE_REGISTRY
    from repro.models import api
    from repro.serve import BucketPolicy, ServeEngine, ShapeBucketScheduler

    kernels.register_all()
    p = SMOKE if smoke else FULL
    edges, slots = p["edges"], p["slots"]
    new_tokens = p["new_tokens"]
    small_edge, top = min(edges), max(edges)
    max_len = top + new_tokens + 8
    cfg = configs.get_smoke(ARCH)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    trace = make_trace(p, rng, cfg.vocab_size)
    plan = load_or_compile_plan(plans_path, cfg, edges, slots, max_len,
                                print_fn)
    t_pf, t_dec = step_cost_model(slots, max_len)
    print_fn(f"# trace: {len(trace)} requests "
             f"({len(p['small_lens'])} small <= {small_edge}, "
             f"{len(p['long_lens'])} long ~{top}); virtual clock "
             f"t_pf={t_pf:.2e}s/tok t_dec={t_dec:.2e}s/step")

    failures = 0
    results = {}
    for mode in ("unchunked", "chunked"):
        clock = VirtualClock()
        eng = ServeEngine(
            cfg, params, max_len=max_len, slots=slots, plans=plan,
            hardware=HARDWARE_REGISTRY[HARDWARE],
            scheduler=ShapeBucketScheduler(
                BucketPolicy(edges, max_queue=len(trace) + 1)),
            clock=clock,
            chunk_prefill=(mode == "chunked"),
            step_token_budget=(p["step_token_budget"]
                               if mode == "chunked" else 0))
        drive(eng, clock, trace, new_tokens, p["arrivals_per_step"],
              t_pf, t_dec)
        m = eng.metrics.as_dict()
        small = m["ttft_s"].get(str(small_edge), {})
        results[mode] = dict(
            wall=clock.t,
            completed=eng.metrics.completed,
            tokens={r.rid: tuple(r.out_tokens) for r in eng._finished},
            p95=small.get("p95_s", 0.0),
            p50=small.get("p50_s", 0.0),
            mean=small.get("mean_s", 0.0),
            chunks=dict(eng.metrics.chunks_per_prefill),
        )
        print_fn(f"{mode}: total={clock.t * 1e3:.2f}ms virtual, "
                 f"completed={eng.metrics.completed}, small-bucket TTFT "
                 f"mean={results[mode]['mean'] * 1e3:.2f}ms "
                 f"p50={results[mode]['p50'] * 1e3:.2f}ms "
                 f"p95={results[mode]['p95'] * 1e3:.2f}ms "
                 f"chunks/prefill={results[mode]['chunks']}")

    # 1. tail TTFT of small requests improves.
    if not results["chunked"]["p95"] < results["unchunked"]["p95"]:
        failures += 1
        print_fn(f"FAIL: chunked small-request p95 TTFT "
                 f"{results['chunked']['p95']:.4f}s not below unchunked "
                 f"{results['unchunked']['p95']:.4f}s")
    # 2. equal work: same completions and greedy tokens, bounded overhead.
    if results["chunked"]["completed"] != results["unchunked"]["completed"]:
        failures += 1
        print_fn("FAIL: completion counts differ between arms")
    if results["chunked"]["tokens"] != results["unchunked"]["tokens"]:
        failures += 1
        print_fn("FAIL: greedy outputs differ between arms (parity broken)")
    if results["chunked"]["wall"] > MAX_SLOWDOWN * results["unchunked"]["wall"]:
        failures += 1
        print_fn(f"FAIL: chunked total virtual time "
                 f"{results['chunked']['wall']:.4f}s exceeds "
                 f"{MAX_SLOWDOWN}x unchunked "
                 f"{results['unchunked']['wall']:.4f}s")

    # 3. per-hardware chunk-length divergence at the full-dims 32k cell.
    from repro.core import Autotuner
    from repro.core.plans import compile_entry
    from repro.launch.specs import kernel_problems

    cfg_full = configs.get_arch(ARCH)
    prob = kernel_problems(cfg_full, 1, 32768,
                           "chunked_prefill")["chunked_prefill"]
    chunk_by_hw = {}
    for hw_name in DIVERGENCE_HW:
        entry = compile_entry("chunked_prefill", prob, "float32",
                              HARDWARE_REGISTRY[hw_name],
                              autotuner=Autotuner())
        chunk_by_hw[hw_name] = entry.tile[0]
        print_fn(f"# chunked_prefill @ sq=32768 on {hw_name}: "
                 f"tile {entry.tile} ({entry.dominant}-bound)")
    if len(set(chunk_by_hw.values())) < 2:
        failures += 1
        print_fn(f"FAIL: chunk length does not diverge across "
                 f"{DIVERGENCE_HW}: {chunk_by_hw}")

    # 4. overflow admission: longer than every edge, admitted via chunking.
    clock = VirtualClock()
    eng = ServeEngine(
        cfg, params, max_len=2 * top + new_tokens + 8, slots=slots,
        plans=plan, hardware=HARDWARE_REGISTRY[HARDWARE],
        scheduler=ShapeBucketScheduler(
            BucketPolicy(edges, allow_overflow=True)),
        clock=clock, chunk_prefill=True,
        step_token_budget=p["step_token_budget"])
    overflow = rng.integers(2, cfg.vocab_size,
                            size=top + small_edge).astype(np.int32)
    rid = eng.add_request(overflow, max_new_tokens=new_tokens)
    done = eng.run_until_done(max_steps=20000)
    if rid is None or len(done) != 1 or len(done[0].out_tokens) != new_tokens:
        failures += 1
        print_fn("FAIL: over-length prompt was not served via chunked "
                 "overflow admission")
    else:
        print_fn(f"# overflow: len-{len(overflow)} prompt admitted at "
                 f"bucket {done[0].bucket}, served in "
                 f"{dict(eng.metrics.chunks_per_prefill)} chunks")

    print_fn("PASS" if not failures else f"{failures} FAILURES")
    return failures


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="scaled trace for CI (seconds, not minutes)")
    ap.add_argument("--plans", default=None,
                    help="compiled TilePlan artifact to reuse (falls back "
                         "to compiling the bench's own serving cells)")
    args = ap.parse_args()
    sys.exit(1 if run(smoke=args.smoke, plans_path=args.plans) else 0)


if __name__ == "__main__":
    main()
