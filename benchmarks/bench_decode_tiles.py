"""Dense vs flash-decode attention across cache lengths, by plan tile.

For each cache length the AOT compiler picks the decode cell's KV split
(``bkv``) per hardware model, and the bench

* reports the chosen split on both modelled targets (the paper's
  cross-model claim on the decode cell: VMEM capacity bounds the split, so
  the same cache length wants a different ``bkv`` per model);
* times the dense masked-softmax decode against the split-KV flash-decode
  lowering at the resolved split on the running backend;
* checks parity (<= 2e-5, f32) between the two lowerings.

Asserted invariants (exit 1 on violation; CI runs ``--smoke``):
  1. every decode cell compiles to a plan entry whose split divides the
     cache (no silent tile clamp on the decode path);
  2. dense and flash-decode agree on every timed cell;
  3. at least one decode cell resolves a different ``bkv`` on the two
     modelled hardware targets.
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Dict, List, Tuple

HARDWARE = ("tpu_v5e", "tpu_v6e")

SMOKE = dict(
    timed_lens=(256, 512, 1024),
    plan_lens=(1024, 8192, 32768),
    b=2, hq=4, hkv=2, d=64, iters=5,
)
FULL = dict(
    timed_lens=(1024, 8192, 32768),
    plan_lens=(1024, 8192, 32768),
    b=4, hq=12, hkv=2, d=128, iters=20,
)


def compile_decode_cells(p: dict, plans_path=None,
                         print_fn=print) -> Dict[Tuple[str, int], int]:
    """(hardware, cache_len) -> plan-chosen bkv.

    With ``plans_path``, reuses a compiled artifact (CI passes the
    compile-plans job's upload) when it covers every decode cell on both
    hardware models, recompiling exactly these cells otherwise — the same
    reuse-with-fallback path the other serving benches take.
    """
    from repro import kernels
    from repro.launch.compile_plans import load_or_compile_cells

    kernels.register_all()
    cells = [
        ("flash_decode", dict(b=p["b"], skv=skv, d=p["d"], hq=p["hq"],
                              hkv=p["hkv"], window=0))
        for skv in sorted(set(p["plan_lens"]) | set(p["timed_lens"]))
    ]
    plan = load_or_compile_cells(plans_path, cells, HARDWARE,
                                 print_fn=print_fn)
    chosen = {}
    for hw_name in HARDWARE:
        for kernel, problem in cells:
            entry = plan.lookup(kernel, problem, "float32", hw_name)
            chosen[(hw_name, problem["skv"])] = int(entry.tile[0])
    return chosen


def _time(fn, *args, iters: int) -> float:
    import jax

    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(smoke: bool = False, plans_path=None, print_fn=print) -> int:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.hardware import PRODUCTION_TARGET
    from repro.kernels.flash_attention.decode import flash_decode_ref

    p = SMOKE if smoke else FULL
    failures = 0

    chosen = compile_decode_cells(p, plans_path=plans_path,
                                  print_fn=print_fn)
    print_fn("# decode-cell plan tiles (bkv) per hardware model:")
    for skv in sorted({s for _, s in chosen}):
        row = {hw: chosen[(hw, skv)] for hw in HARDWARE}
        print_fn(f"#   cache {skv:>6}: " + ", ".join(
            f"{hw}={bkv}" for hw, bkv in row.items()))
        for hw in HARDWARE:
            if skv % chosen[(hw, skv)]:
                failures += 1
                print_fn(f"FAIL: {hw} cache {skv}: bkv {chosen[(hw, skv)]} "
                         f"does not divide the cache")
    if not any(chosen[(HARDWARE[0], skv)] != chosen[(HARDWARE[1], skv)]
               for skv in {s for _, s in chosen}):
        failures += 1
        print_fn("FAIL: no decode cell picks a different bkv across the two "
                 "hardware models")

    def dense(q, k, v, pos):
        n_rep = p["hq"] // p["hkv"]
        ke = jnp.repeat(k, n_rep, axis=1) if n_rep > 1 else k
        ve = jnp.repeat(v, n_rep, axis=1) if n_rep > 1 else v
        s = jnp.einsum("bhk,bhsk->bhs", q, ke,
                       preferred_element_type=jnp.float32) * p["d"] ** -0.5
        mask = jnp.arange(k.shape[2]) <= pos
        s = jnp.where(mask[None, None], s, -2.0e30)
        pr = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhs,bhsk->bhk", pr.astype(ve.dtype), ve,
                          preferred_element_type=jnp.float32).astype(q.dtype)

    dense_j = jax.jit(dense)
    rng = np.random.default_rng(0)
    print_fn("cache_len,bkv,dense_ms,flash_ms,max_abs_diff")
    for skv in p["timed_lens"]:
        bkv = chosen[(PRODUCTION_TARGET.name, skv)] \
            if (PRODUCTION_TARGET.name, skv) in chosen \
            else chosen[(HARDWARE[0], skv)]
        q = jnp.asarray(rng.standard_normal(
            (p["b"], p["hq"], p["d"]), np.float32) * 0.3)
        k = jnp.asarray(rng.standard_normal(
            (p["b"], p["hkv"], skv, p["d"]), np.float32) * 0.3)
        v = jnp.asarray(rng.standard_normal(
            (p["b"], p["hkv"], skv, p["d"]), np.float32))
        pos = jnp.asarray(skv - 1, jnp.int32)

        flash = jax.jit(lambda q, k, v, pos, bkv=bkv: flash_decode_ref(
            q, k, v, pos=pos, bkv=bkv))
        d_ref = dense_j(q, k, v, pos)
        f_ref = flash(q, k, v, pos)
        diff = float(jnp.max(jnp.abs(d_ref - f_ref)))
        if diff > 2e-5:
            failures += 1
            print_fn(f"FAIL: parity {diff:.2e} > 2e-5 at cache {skv}")
        t_dense = _time(dense_j, q, k, v, pos, iters=p["iters"])
        t_flash = _time(flash, q, k, v, pos, iters=p["iters"])
        print_fn(f"{skv},{bkv},{t_dense * 1e3:.3f},{t_flash * 1e3:.3f},"
                 f"{diff:.2e}")

    print_fn("PASS" if not failures else f"{failures} FAILURES")
    return failures


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small cells for CI (short traces, tiny geometry)")
    ap.add_argument("--plans", default=None,
                    help="compiled tile-plan artifact to reuse; recompiles "
                         "these cells when missing or non-covering")
    args = ap.parse_args()
    sys.exit(1 if run(smoke=args.smoke, plans_path=args.plans) else 0)


if __name__ == "__main__":
    main()
