"""Fleet fault tolerance under scripted chaos, on the virtual clock.

A heterogeneous fleet (tpu_v5e + tpu_v6e, one paged chunk-prefill engine
each) serves a fixed request trace while a deterministic
:class:`~repro.serve.faults.FaultScript` kills, stalls, drains, degrades,
and joins instances at scripted step numbers. Because every scenario runs
the real ``ServeEngine``/``FleetRouter`` on a shared cost-model virtual
clock (per-hardware step costs from the compiled plan, scaled by the
injector's degrade factor), the whole chaos run is replayable: same
script, same trace, byte-identical Perfetto export.

Scenarios (each asserted against the undisturbed baseline run):

  baseline   no faults — reference tokens per fleet id (fid) + pooled TTFT;
  kill       an instance dies mid-run (liveness detection): its queued and
             in-flight requests re-queue on the survivor, re-prefilled from
             their original prompts;
  stall      an instance wedges (steps become no-ops): only the progress
             watchdog can catch it; a later scripted recovery returns the
             (evicted, empty) instance to rotation and work stealing gives
             it load again;
  drain      graceful retirement (queued work handed off for free, no retry
             consumed) while the other instance runs latency-degraded;
  join       the fleet starts with ONE instance; a tpu_v6e engine joins
             mid-run and serves requests with plan cells resolved for its
             OWN hardware (plan_resolve audit events on its pid prove it);
  determinism  the kill scenario replayed from scratch must export a
             byte-identical trace.

Asserted invariants (exit 1 on violation; CI runs ``--smoke``):
  1. zero loss / zero duplication: every scenario finishes exactly the
     baseline's fid set, with ``router.lost == 0``;
  2. token parity: recovered/stolen/drained requests produce byte-equal
     greedy tokens vs the undisturbed run (re-prefill from the original
     prompt, never from dead caches);
  3. every engine's paged pool drains refcount-balanced — including the
     killed/stalled instances whose residents were force-evicted;
  4. pooled p95/p99 TTFT inflation vs baseline stays under
     ``TTFT_P95_BOUND``/``TTFT_P99_BOUND`` (recovery is not free, but it
     is bounded), and the trace's submit-anchored ``ttft`` spans reproduce
     the pooled metrics p95 exactly;
  5. failure/recovery/drain/join events land in the trace's ``fleet`` lane
     with the expected detection channel (liveness for kill, watchdog for
     stall);
  6. the joiner's ``chunked_prefill`` cell compiles a different chunk
     length than the incumbent's hardware at full dims (the paper's
     per-model optimum, carried through engine join).

``--trace-out`` writes the kill scenario's trace; the determinism re-run
is written next to it as ``<stem>.rerun<suffix>`` so CI can
``trace_report --diff`` the pair (the bench itself asserts byte
equality).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

import numpy as np

SMOKE = dict(
    edges=(64, 1024),
    lens=(18, 40, 900, 22, 55, 33, 700, 12, 47, 60, 25, 38, 810, 19),
    new_tokens=3,
    slots=2,
    step_token_budget=200,
    prefill_slots=4,
    arrivals_per_step=2,
)
FULL = dict(
    edges=(512, 32768),
    lens=(120, 300, 30000, 200, 410, 90, 28000, 350, 260, 440, 160, 480,
          31000, 210),
    new_tokens=3,
    slots=2,
    step_token_budget=2600,
    prefill_slots=4,
    arrivals_per_step=2,
)
# instance name -> hardware model; "b" is the heterogeneous partner and
# (in the join scenario) the mid-run joiner.
FLEET = (("a", "tpu_v5e"), ("b", "tpu_v6e"))
ARCH = "qwen2-1.5b"
STEP_OVERHEAD_S = 20e-6
WATCHDOG_THRESHOLD = 4
RETRY_BUDGET = 2
# Chaos TTFT tail vs the undisturbed baseline: recovery re-prefills lost
# work and drains it through fewer instances, so the tail inflates — the
# bound asserts it stays a small multiple, not unbounded (measured: the
# worst scenario sits near 2.2x on both the smoke and full traces).
TTFT_P95_BOUND = 4.0
TTFT_P99_BOUND = 4.0
FULL_REF_LEN = 32768


class VirtualClock:
    """Injectable engine clock; the driver advances it between steps."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def load_or_compile_plan(path: Optional[str], edges, slots: int,
                         max_len: int, print_fn) -> object:
    """Reuse a compiled artifact when it covers this bench's shape family
    on BOTH fleet hardware models; compile the serving cells otherwise."""
    from repro.launch.compile_plans import (
        load_or_compile_cells, serve_bucket_cells,
    )

    cells = serve_bucket_cells([ARCH], edges, slots, max_len, smoke=True)
    hw_names = tuple(sorted({hw for _, hw in FLEET}))
    return load_or_compile_cells(
        path, cells, hw_names,
        meta={"generated_by": "bench_fleet_chaos"}, print_fn=print_fn)


def step_cost_model(slots: int, max_len: int) -> Dict[str, Tuple[float,
                                                                 float]]:
    """hardware -> (per-prefill-token s, per-decode-step s), costed at the
    FULL architecture's dims so the clock keeps the real cost regime (the
    smoke trace only scales the executed lengths). Per-hardware constants:
    the v6e joiner really is faster per step, so lockstep wall time follows
    the slowest stepped instance."""
    from repro import configs
    from repro.core import HARDWARE_REGISTRY, Autotuner
    from repro.core.plans import compile_entry
    from repro.launch.specs import kernel_problems

    cfg_full = configs.get_arch(ARCH)
    costs = {}
    for hw_name in sorted({hw for _, hw in FLEET}):
        hw = HARDWARE_REGISTRY[hw_name]
        tuner = Autotuner()
        pf_prob = kernel_problems(cfg_full, 1, FULL_REF_LEN,
                                  "prefill")["flash_attention"]
        t_pf = compile_entry("flash_attention", pf_prob, "float32", hw,
                             autotuner=tuner).score_s / FULL_REF_LEN
        dec_prob = kernel_problems(cfg_full, slots, max_len,
                                   "decode")["flash_decode"]
        t_dec = compile_entry("flash_decode", dec_prob, "float32", hw,
                              autotuner=tuner).score_s
        costs[hw_name] = (t_pf, t_dec)
    return costs


def make_trace(p: dict, vocab: int) -> List[np.ndarray]:
    rng = np.random.default_rng(7)
    return [rng.integers(2, vocab, size=n).astype(np.int32)
            for n in p["lens"]]


def drive(router, clock: VirtualClock, injector, trace, p,
          costs: Dict[str, Tuple[float, float]],
          max_steps: int = 20000) -> None:
    """Open-loop drive on the shared virtual clock. Lockstep: the clock
    advances by the slowest instance that actually stepped this tick
    (``steps_run`` delta), scaled by the injector's degrade factor."""
    i = 0
    for tick in range(max_steps):
        while i < len(trace) and i < p["arrivals_per_step"] * (tick + 1):
            d = router.route(trace[i], max_new_tokens=p["new_tokens"])
            if d is None:
                break          # backpressure: retry this request next tick
            i += 1
        before = {n: eng.steps_run for n, eng in router.engines.items()}
        residue = router.step_all()
        cost = 0.0
        for n, eng in router.engines.items():
            if eng.steps_run == before.get(n):
                continue       # skipped (dead/stalled/drained) or no-op
            t_pf, t_dec = costs[eng.hardware.name]
            stats = eng.last_step_stats
            c = (stats["prefill_tokens"] * t_pf
                 + (t_dec if stats["decode_tokens"] else 0.0))
            factor = injector.latency_factor(n) if injector else 1.0
            cost = max(cost, c * factor)
        clock.t += STEP_OVERHEAD_S + cost
        if not residue and not router.pending() and i >= len(trace):
            return
    raise RuntimeError(f"chaos drive not drained after {max_steps} steps")


def run_scenario(label: str, p, cfg, params, plan, policy_edges,
                 script_events, costs, names=FLEET, with_trace: bool = True):
    """One fleet + one fault script + the shared trace; returns the
    scenario record (results, pooled TTFT, trace handle, router)."""
    import jax  # noqa: F401  (engines already built against jax params)

    from repro.core import HARDWARE_REGISTRY
    from repro.obs import Tracer
    from repro.serve import (BucketPolicy, FaultEvent, FaultInjector,
                             FaultScript, FleetRouter, ServeEngine,
                             ShapeBucketScheduler)

    p_top = max(policy_edges)
    max_len = p_top + p["new_tokens"] + 8
    clock = VirtualClock()
    tracer = Tracer(clock=clock) if with_trace else None
    policy = BucketPolicy(policy_edges, max_queue=len(p["lens"]) + 8)

    def make_engine(name: str, hw_name: str) -> ServeEngine:
        return ServeEngine(
            cfg, params, max_len=max_len, slots=p["slots"],
            plans=plan, hardware=HARDWARE_REGISTRY[hw_name],
            scheduler=ShapeBucketScheduler(policy),
            clock=clock, chunk_prefill=True, paged=True,
            prefill_slots=p["prefill_slots"],
            step_token_budget=p["step_token_budget"],
            tracer=tracer, instance=name)

    engines = {name: make_engine(name, hw) for name, hw in names}
    script = FaultScript()
    for ev in script_events:
        if ev.get("action") == "join":
            hw = ev.pop("hardware")
            name = ev["instance"]
            ev["make_engine"] = lambda name=name, hw=hw: make_engine(name, hw)
        script.add(FaultEvent(**ev))
    injector = FaultInjector(script)
    router = FleetRouter(engines, policy, tracer=tracer,
                         watchdog_threshold=WATCHDOG_THRESHOLD,
                         retry_budget=RETRY_BUDGET, injector=injector)
    trace = make_trace(p, cfg.vocab_size)
    drive(router, clock, injector, trace, p, costs)
    if tracer is not None:
        tracer.flush()

    samples: List[float] = []
    for eng in router.engines.values():
        eng.pool.check_balanced()   # force-evicted residents included
        samples.extend(eng.metrics.ttft_since(None))
    from repro.serve.metrics import nearest_rank

    return dict(
        label=label,
        results=router.results(),
        router=router,
        tracer=tracer,
        wall=clock.t,
        p95=nearest_rank(samples, 0.95),
        p99=nearest_rank(samples, 0.99),
        n_samples=len(samples),
    )


def fleet_events(tracer, name: Optional[str] = None) -> List[dict]:
    evs = [e for e in tracer.events if e.get("cat") == "fleet"]
    return [e for e in evs if e["name"] == name] if name else evs


def run(smoke: bool = False, plans_path: Optional[str] = None,
        trace_out: Optional[str] = None, print_fn=print) -> int:
    import jax

    from repro import configs, kernels
    from repro.models import api
    from repro.obs import write_trace

    kernels.register_all()
    p = SMOKE if smoke else FULL
    edges = p["edges"]
    cfg = configs.get_smoke(ARCH)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    max_len = max(edges) + p["new_tokens"] + 8
    plan = load_or_compile_plan(plans_path, edges, p["slots"], max_len,
                                print_fn)
    costs = step_cost_model(p["slots"], max_len)
    cost_summary = {h: f"{c[0]:.2e}s/tok, {c[1]:.2e}s/step"
                    for h, c in costs.items()}
    print_fn(f"# fleet: {dict(FLEET)}; per-hw step costs: {cost_summary}")

    failures = 0
    common = (p, cfg, params, plan, edges)

    def check(cond: bool, msg: str) -> None:
        nonlocal failures
        if not cond:
            failures += 1
            print_fn(f"FAIL: {msg}")

    # -- baseline ----------------------------------------------------------
    base = run_scenario("baseline", *common, [], costs)
    n_req = len(p["lens"])
    check(len(base["results"]) == n_req,
          f"baseline finished {len(base['results'])}/{n_req} requests")
    print_fn(f"baseline: {len(base['results'])} requests, "
             f"wall={base['wall'] * 1e3:.2f}ms virtual, "
             f"p95 TTFT={base['p95'] * 1e3:.3f}ms "
             f"p99={base['p99'] * 1e3:.3f}ms")

    def check_parity(sc) -> None:
        r = sc["router"]
        check(set(sc["results"]) == set(base["results"]),
              f"{sc['label']}: fid set differs from baseline "
              f"(lost={sorted(set(base['results']) - set(sc['results']))}, "
              f"extra={sorted(set(sc['results']) - set(base['results']))})")
        check(r.lost == 0, f"{sc['label']}: {r.lost} request(s) lost")
        mismatch = [fid for fid in base["results"]
                    if sc["results"].get(fid) != base["results"][fid]]
        check(not mismatch,
              f"{sc['label']}: token parity broken for fids {mismatch}")
        check(sc["p95"] <= TTFT_P95_BOUND * base["p95"],
              f"{sc['label']}: pooled p95 TTFT {sc['p95']:.4f}s exceeds "
              f"{TTFT_P95_BOUND}x baseline {base['p95']:.4f}s")
        check(sc["p99"] <= TTFT_P99_BOUND * base["p99"],
              f"{sc['label']}: pooled p99 TTFT {sc['p99']:.4f}s exceeds "
              f"{TTFT_P99_BOUND}x baseline {base['p99']:.4f}s")
        print_fn(f"{sc['label']}: {len(sc['results'])} requests, "
                 f"wall={sc['wall'] * 1e3:.2f}ms virtual, "
                 f"p95={sc['p95'] * 1e3:.3f}ms "
                 f"(x{sc['p95'] / max(base['p95'], 1e-12):.2f}), "
                 f"recoveries={r.recoveries} steals={r.steals} "
                 f"status={dict(sorted(r.status.items()))}")

    # -- kill --------------------------------------------------------------
    kill_script = [dict(step=6, action="kill", instance="b")]
    kill = run_scenario("kill", *common, [dict(e) for e in kill_script],
                        costs)
    check_parity(kill)
    check(kill["router"].status["b"] == "dead",
          "kill: instance b not marked dead")
    check(kill["router"].recoveries >= 1,
          "kill: no request was recovered onto the survivor")
    detected = fleet_events(kill["tracer"], "fault_detected")
    check(any(e["args"]["via"] == "liveness" and e["args"]["instance"] == "b"
              for e in detected),
          "kill: no liveness fault_detected event for b in the fleet lane")
    check(bool(fleet_events(kill["tracer"], "recover")),
          "kill: no recover events in the fleet lane")

    # -- stall (watchdog) + scripted recovery ------------------------------
    stall = run_scenario("stall", *common, [
        dict(step=4, action="stall", instance="b"),
        dict(step=16, action="recover", instance="b"),
    ], costs)
    check_parity(stall)
    detected = fleet_events(stall["tracer"], "fault_detected")
    check(any(e["args"]["via"] == "watchdog" and e["args"]["instance"] == "b"
              for e in detected),
          "stall: watchdog did not flag b in the fleet lane")
    check(stall["router"].status["b"] == "live",
          "stall: b did not rejoin after scripted recovery")

    # -- drain (graceful) under degraded partner ---------------------------
    drain = run_scenario("drain", *common, [
        dict(step=2, action="degrade", instance="a", factor=2.0),
        dict(step=5, action="drain", instance="b"),
    ], costs)
    check_parity(drain)
    check(drain["router"].status["b"] == "drained",
          "drain: b did not reach drained")
    check(drain["router"].recoveries == len(
              fleet_events(drain["tracer"], "recover")),
          "drain: recover event count disagrees with router counter")
    for ev_name in ("drain_begin", "drain_done"):
        check(bool(fleet_events(drain["tracer"], ev_name)),
              f"drain: no {ev_name} event in the fleet lane")
    # Drain is not a failure: no retry budget consumed anywhere.
    check(all(fr.retries == 0
              for fr in drain["router"]._fleet.values()),
          "drain: graceful handoff consumed retry budget")

    # -- join (heterogeneous, mid-run) -------------------------------------
    join = run_scenario("join", *common, [
        dict(step=3, action="join", instance="b", hardware=dict(FLEET)["b"]),
    ], costs, names=FLEET[:1])
    check_parity(join)
    check(bool(fleet_events(join["tracer"], "join")),
          "join: no join event in the fleet lane")
    b_eng = join["router"].engines.get("b")
    check(b_eng is not None and len(b_eng._finished) >= 1,
          "join: the joined instance served no requests")
    if b_eng is not None:
        b_pid = next(pr["pid"] for pr in join["tracer"].procs
                     if pr["name"] == "b")
        resolves = [e for e in join["tracer"].events
                    if e["name"] == "plan_resolve" and e["pid"] == b_pid]
        check(bool(resolves),
              "join: no plan_resolve audit events on the joiner's pid")
        check(all(e["args"]["source"] in ("exact", "nearest_shape")
                  for e in resolves),
              "join: joiner fell back off the plan "
              f"({sorted({e['args']['source'] for e in resolves})})")
        print_fn(f"# join: b ({b_eng.hardware.name}) finished "
                 f"{len(b_eng._finished)} request(s), "
                 f"{len(resolves)} plan_resolve audit event(s)")

    # The joiner's hardware really wants different tiles: chunk length
    # diverges across the two fleet models at full dims.
    from repro.core import HARDWARE_REGISTRY, Autotuner
    from repro.core.plans import compile_entry
    from repro.launch.specs import kernel_problems

    cfg_full = configs.get_arch(ARCH)
    prob = kernel_problems(cfg_full, 1, FULL_REF_LEN,
                           "chunked_prefill")["chunked_prefill"]
    chunk_by_hw = {}
    for _, hw_name in FLEET:
        entry = compile_entry("chunked_prefill", prob, "float32",
                              HARDWARE_REGISTRY[hw_name],
                              autotuner=Autotuner())
        chunk_by_hw[hw_name] = entry.tile[0]
        print_fn(f"# chunked_prefill @ sq={FULL_REF_LEN} on {hw_name}: "
                 f"tile {entry.tile} ({entry.dominant}-bound)")
    check(len(set(chunk_by_hw.values())) >= 2,
          f"chunk length does not diverge across fleet hardware: "
          f"{chunk_by_hw}")

    # -- determinism: replay the kill scenario, byte-identical trace -------
    rerun = run_scenario("kill-rerun", *common,
                         [dict(e) for e in kill_script], costs)
    check(rerun["results"] == kill["results"],
          "determinism: kill replay produced different results")
    if trace_out:
        stem, suffix = os.path.splitext(trace_out)
        rerun_out = f"{stem}.rerun{suffix or '.json'}"
        write_trace(kill["tracer"], trace_out)
        write_trace(rerun["tracer"], rerun_out)
        with open(trace_out, "rb") as f:
            b1 = f.read()
        with open(rerun_out, "rb") as f:
            b2 = f.read()
        check(b1 == b2,
              "determinism: kill replay trace is not byte-identical")
        print_fn(f"# trace written to {trace_out} "
                 f"({len(kill['tracer'].events)} events; replay at "
                 f"{rerun_out} is byte-identical)")

        # Trace self-check: pooled nearest-rank p95 over the kill trace's
        # submit-anchored ttft spans == the pooled metrics p95 (recovered
        # requests keep their original submit anchor in both).
        from repro.obs import load_trace
        from repro.serve.metrics import nearest_rank

        reloaded = load_trace(trace_out)
        durs = [ev.get("dur", 0.0) for ev in reloaded["events"]
                if ev.get("name") == "ttft"]
        trace_p95 = nearest_rank(durs, 0.95)
        check(bool(durs) and np.isclose(trace_p95, kill["p95"], rtol=1e-9,
                                        atol=0.0),
              f"kill trace ttft p95 {trace_p95:.6e}s != pooled metrics "
              f"p95 {kill['p95']:.6e}s")

    print_fn("PASS" if not failures else f"{failures} FAILURES")
    return failures


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="scaled trace for CI (seconds, not minutes)")
    ap.add_argument("--plans", default=None,
                    help="compiled TilePlan artifact to reuse (falls back "
                         "to compiling the bench's own serving cells for "
                         "both fleet hardware models)")
    ap.add_argument("--trace-out", default=None,
                    help="write the kill scenario's deterministic trace "
                         "here (the replay lands at <stem>.rerun<suffix>; "
                         "the bench asserts byte equality and CI diffs the "
                         "pair with trace_report --diff)")
    args = ap.parse_args()
    sys.exit(1 if run(smoke=args.smoke, plans_path=args.plans,
                      trace_out=args.trace_out)
             else 0)


if __name__ == "__main__":
    main()
