"""Kernel micro-benchmarks: wall-clock of the jnp reference paths on CPU
(the Pallas kernels target TPU; interpret-mode timing is not meaningful),
plus the analytic v5e cost of the autotuned tile for each kernel.

CSV: name,us_per_call,derived
"""
import time

import jax
import jax.numpy as jnp

import repro.kernels.bilinear.ops as bops
import repro.kernels.flash_attention.ops  # noqa: F401
import repro.kernels.matmul.ops  # noqa: F401
import repro.kernels.rglru.ops  # noqa: F401
import repro.kernels.ssd.ops  # noqa: F401
from repro.core import Autotuner, TPU_V5E
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.rglru.ref import rglru_ref
from repro.kernels.ssd.ref import ssd_chunked_ref


def _time(fn, *args, n=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def run(print_fn=print):
    key = jax.random.PRNGKey(0)
    at = Autotuner()
    rows = []

    src = jax.random.uniform(key, (256, 256), jnp.float32)
    us = _time(jax.jit(lambda s: bops.upscale_ref(s, 4)), src)
    t = at.best_tile("bilinear", dict(src_h=256, src_w=256, scale=4),
                     "float32", TPU_V5E)
    rows.append(("bilinear_ref_cpu_256x4", us, f"v5e_tile={t}"))

    a = jax.random.normal(key, (512, 512), jnp.bfloat16)
    b = jax.random.normal(key, (512, 512), jnp.bfloat16)
    us = _time(jax.jit(matmul_ref), a, b)
    t = at.best_tile("matmul", dict(m=512, k=512, n=512), "bfloat16", TPU_V5E)
    rows.append(("matmul_ref_cpu_512", us, f"v5e_tile={t}"))

    q = jax.random.normal(key, (1, 4, 512, 64), jnp.float32)
    us = _time(
        lambda q: flash_attention_ref(q, q, q, causal=True, chunk=128), q)
    t = at.best_tile("flash_attention",
                     dict(sq=512, skv=512, d=64, hq=4, hkv=4, window=0),
                     "bfloat16", TPU_V5E)
    rows.append(("flash_ref_cpu_512", us, f"v5e_tile={t}"))

    x = jax.random.normal(key, (2, 512, 512), jnp.float32)
    r = jax.nn.sigmoid(x)
    ap = jax.random.normal(key, (512,))
    us = _time(jax.jit(lambda x, r, ap: rglru_ref(x, r, r, ap)[0]), x, r, ap)
    t = at.best_tile("rglru", dict(s=512, f=512), "bfloat16", TPU_V5E)
    rows.append(("rglru_ref_cpu_512", us, f"v5e_tile={t}"))

    xs = jax.random.normal(key, (1, 256, 4, 32), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(key, (1, 256, 4)))
    A = -jnp.exp(jax.random.normal(key, (4,)))
    Bm = jax.random.normal(key, (1, 256, 16)) * 0.5
    us = _time(
        lambda *a: ssd_chunked_ref(*a, chunk=64)[0], xs, dt, A, Bm, Bm)
    t = at.best_tile("ssd", dict(s=256, h=4, p=32, n=16), "bfloat16", TPU_V5E)
    rows.append(("ssd_ref_cpu_256", us, f"v5e_tile={t}"))

    print_fn("name,us_per_call,derived")
    for name, us, extra in rows:
        print_fn(f"{name},{us:.1f},{extra}")
    return rows


if __name__ == "__main__":
    run()
