"""Live plan refinement: a wrongly-planned fleet recovers native p95 TTFT.

The closed-loop scenario the refinement subsystem (``repro.serve.refine``)
exists for: a serving fleet starts on a plan artifact compiled for the
WRONG hardware model (every resolution is a cross-hardware transfer —
``PlanTransferWarning`` — re-ranked by an analytic model that no longer
matches reality), while the *measured truth* on the floor has shifted: this
bench models changed conditions as a VMEM-contention penalty on top of the
analytic cost (``+ vmem_bytes / CONTENTION_BW``), which reorders every
cell's optimum toward smaller tiles. The fleet then:

1. **shadow-measures** candidate tiles from the plan's stored sensitivity
   curves during live service (``shadow_fraction=1`` here so CI converges
   in seconds; production uses ~1/32) — served tokens untouched;
2. **re-ranks** confidently-better cells through the shared
   :class:`~repro.serve.refine.PlanRefiner` into a schema-v3 artifact with
   full provenance;
3. **rolls** the refined artifact across the fleet one instance at a time
   through ``FleetRouter.roll_plans``'s p95-TTFT rollback guard.

All arms drive real ``ServeEngine``s on a **cost-model virtual clock**
priced by the same measured-truth function the shadow path samples, so the
TTFT comparison is deterministic and hardware-independent: each lockstep
round advances the clock by the max per-engine step cost (prefill segments
x the engine's *resolved-tile* truth cost + one decode-batch step).

Asserted invariants (exit 1 on violation; CI runs ``--smoke``):
  1. the wrong-plan fleet resolves via cross-hardware transfer
     (``PlanTransferWarning`` fires) and the refined artifact resolves
     every re-ranked cell EXACTLY on the believed hardware (no transfer);
  2. refinement finds re-ranked cells, the refined artifact round-trips
     through save/load at schema v3 with its provenance intact;
  3. rollout guard: rolling the refined artifact onto the wrong fleet does
     NOT roll back (it is genuinely better), and rolling a sabotaged
     artifact (worst-truth tiles injected for the small-bucket prefill
     cells) DOES roll back on every instance, leaving the fleet on the
     refined artifact;
  4. recovery: the refined fleet's small-bucket p95 TTFT is within
     ``RECOVERY_TOL`` of a natively-tuned fleet (plan compiled for the
     believed hardware with the truth as its measure hook) and strictly
     better than the wrong-plan fleet;
  5. token parity: all three arms emit identical greedy tokens per trace
     position — refinement changes the schedule's cost, never the math.

``--plans plans.json`` reuses a compiled artifact (CI passes the
compile-plans job's upload), filtered to the donor hardware's entries so a
multi-hardware artifact still yields a genuinely wrong starting plan.
``--refined-out``/``--drift-out`` write the refined artifact and the
incumbent-vs-refined drift report (the CI ``plan-drift-report`` artifact).
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import warnings
from typing import Dict, List, Optional, Tuple

import numpy as np

import traces as trace_lib

SMOKE = dict(
    edges=(64, 1024),
    small_lens=(10, 24, 40, 60, 18, 33, 51, 12, 45, 28),
    long_lens=(900, 980),
    new_tokens=3,
    slots=2,
    arrivals_per_step=3,
    max_rounds=60,
)
FULL = dict(
    edges=(64, 1024),
    small_lens=(10, 24, 40, 60, 18, 33, 51, 12, 45, 28,
                55, 21, 37, 48, 15, 30, 62, 26, 42, 19),
    long_lens=(900, 980, 1010),
    new_tokens=4,
    slots=2,
    arrivals_per_step=3,
    max_rounds=80,
)
ARCH = "qwen2-1.5b"
BELIEVED_HW = "tpu_v5e"      # what every fleet engine believes it runs on
DONOR_HW = "tpu_v6e"         # the wrong plan's only hardware model
STEP_OVERHEAD_S = 20e-6
CONTENTION_BW = 2e9          # B/s: the VMEM-contention truth penalty
RECOVERY_TOL = 1.25          # refined p95 TTFT vs natively-tuned p95
ROLL_TOLERANCE = 1.10        # roll_plans p95 regression guard
MIN_SAMPLES = 3              # refiner confidence gate


class VirtualClock:
    """Injectable engine clock; the driver advances it between steps."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def make_truth():
    """The measured truth: analytic cost on the believed hardware plus a
    VMEM-contention penalty. The penalty is what "conditions changed"
    means here — it reorders each cell's optimum toward smaller tiles, so
    neither the donor plan's ranking nor the believed-hardware analytic
    re-ranking matches what shadow measurement observes."""
    from repro.core import HARDWARE_REGISTRY, registry
    from repro.core.plans import score_tile
    from repro.core.tiling import TileShape

    hw = HARDWARE_REGISTRY[BELIEVED_HW]

    def truth(kernel: str, problem, dtype: str, tile) -> float:
        t = TileShape(tuple(int(x) for x in tile))
        base = score_tile(kernel, t, dict(problem), dtype, hw)
        vmem = registry.get(kernel).vmem_bytes(t, dict(problem), dtype)
        return base + vmem / CONTENTION_BW

    return truth


def build_plans(plans_path: Optional[str], edges, slots: int, max_len: int,
                truth, print_fn):
    """(wrong plan, natively-tuned plan) for the bench's serving cells.

    The wrong plan holds ONLY the donor hardware's entries (a reused CI
    artifact is filtered down to them), so every resolution on the
    believed hardware is a cross-hardware transfer. The native plan is
    compiled for the believed hardware with the truth as its measurement
    hook — the paper-faithful re-tune the refinement loop is measured
    against.
    """
    from repro.core import HARDWARE_REGISTRY, Autotuner
    from repro.core.plans import TilePlan, compile_plan
    from repro.launch.compile_plans import (
        load_or_compile_cells, serve_bucket_cells,
    )

    cells = serve_bucket_cells([ARCH], edges, slots, max_len, smoke=True)
    donor = load_or_compile_cells(
        plans_path, cells, (DONOR_HW,),
        meta={"generated_by": "bench_plan_refinement"}, print_fn=print_fn)
    wrong = TilePlan(
        entries=[e for e in donor.entries() if e.hardware == DONOR_HW],
        meta={"generated_by": "bench_plan_refinement:wrong"})

    jobs = [(k, p, "float32", HARDWARE_REGISTRY[BELIEVED_HW])
            for k, p in cells]
    native = compile_plan(
        jobs, autotuner=Autotuner(),
        measure_fn_factory=lambda kernel, problem, dtype, hw: (
            lambda tile: truth(kernel, problem, dtype, tuple(tile))),
        meta={"generated_by": "bench_plan_refinement:native"})
    return wrong, native


class TruthPricer:
    """Virtual-clock step pricing from the measured truth of the tiles an
    engine actually resolved — so a plan swap changes the price."""

    def __init__(self, cfg, slots: int, max_len: int, truth):
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.truth = truth
        self._cache: Dict[Tuple, float] = {}

    def _resolved_cost(self, eng, kind: str, batch: int, length: int
                       ) -> float:
        key = (id(eng.plans), kind, length)
        hit = self._cache.get(key)
        if hit is not None:
            return hit
        from repro.core import registry
        from repro.core.plans import PlanTransferWarning
        from repro.launch.specs import kernel_problems

        total = 0.0
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", PlanTransferWarning)
            for kernel, problem in kernel_problems(
                    self.cfg, batch, length, kind).items():
                res = (eng.plans.resolve(kernel, problem, "float32",
                                         eng.hardware)
                       if eng.plans is not None else None)
                tile = (res.tile if res is not None
                        else registry.get(kernel).default_tile(problem,
                                                               "float32"))
                total += self.truth(kernel, problem, "float32",
                                    tuple(tile))
        self._cache[key] = total
        return total

    def step_cost(self, eng) -> float:
        stats = eng.last_step_stats
        cost = STEP_OVERHEAD_S
        for length, take in stats.get("prefill_segments", ()):
            cost += (self._resolved_cost(eng, "prefill", 1, length)
                     * take / length)
        if stats["decode_tokens"]:
            cost += self._resolved_cost(eng, "decode", self.slots,
                                        self.max_len)
        return cost


def make_fleet(plan, cfg, params, policy, slots: int, max_len: int,
               clock: VirtualClock, shadow_fraction: float = 0.0,
               shadow_measure=None, refiner=None, tracer=None):
    from repro.core import HARDWARE_REGISTRY
    from repro.serve import FleetRouter, ServeEngine, ShapeBucketScheduler

    hw = HARDWARE_REGISTRY[BELIEVED_HW]
    engines = {
        name: ServeEngine(
            cfg, params, max_len=max_len, slots=slots, plans=plan,
            hardware=hw, scheduler=ShapeBucketScheduler(policy),
            clock=clock, shadow_fraction=shadow_fraction,
            shadow_measure=shadow_measure, refiner=refiner,
            tracer=tracer, instance=name)
        for name in ("v5e-a", "v5e-b")
    }
    return FleetRouter(engines, policy, tracer=tracer)


def drive_fleet(router, clock: VirtualClock, pricer: TruthPricer, trace,
                new_tokens: int, arrivals_per_step: int,
                max_steps: int = 50000) -> List[Tuple[str, int]]:
    """Open-loop lockstep drive on the shared virtual clock; each round
    advances by the max per-engine step cost (engines run in parallel).
    Returns the (instance, rid) placement per trace position."""
    placed: List[Tuple[str, int]] = []
    i = 0
    for tick in range(max_steps):
        while i < len(trace) and i < arrivals_per_step * (tick + 1):
            decision = router.route(trace[i], max_new_tokens=new_tokens)
            assert decision is not None, f"trace request {i} rejected"
            placed.append((decision.instance, decision.rid))
            i += 1
        active = 0
        round_cost = 0.0
        for name in sorted(router.engines):
            eng = router.engines[name]
            active += eng.step()
            round_cost = max(round_cost, pricer.step_cost(eng))
        clock.t += round_cost
        if not active and not router.pending() and i >= len(trace):
            break
    return placed


def fleet_tokens(router, placed) -> Dict[int, Tuple[int, ...]]:
    """trace position -> greedy output tokens (parity unit across arms —
    placements may differ between arms, tokens must not)."""
    by_engine = {
        name: {r.rid: tuple(r.out_tokens) for r in eng._finished}
        for name, eng in router.engines.items()
    }
    return {i: by_engine[name][rid]
            for i, (name, rid) in enumerate(placed)}


def small_p95(router, edge: int) -> float:
    """Nearest-rank p95 TTFT over the small bucket, pooled fleet-wide."""
    xs: List[float] = []
    for eng in router.engines.values():
        stat = eng.metrics.ttft.get(edge)
        if stat is not None:
            xs.extend(stat.recent(stat.count))
    xs.sort()
    if not xs:
        return 0.0
    return xs[max(0, math.ceil(0.95 * len(xs)) - 1)]


def shadow_ticks_needed(router) -> int:
    """Diverted steps needed fleet-wide so every candidate of every shadow
    cell reaches the refiner's ``MIN_SAMPLES``: the round-robin gives each
    cell an equal share of the ticks, and each cell needs a full candidate
    cycle per sample."""
    needed = 0
    for eng in router.engines.values():
        n_cells, max_cands = 0, 0
        for key in eng._shadow_order:
            view = eng._shadow_view(key)
            if view is None:
                continue
            n_cells += 1
            max_cands = max(max_cands, len(view[1]))
        needed = max(needed, n_cells * max_cands * MIN_SAMPLES)
    return needed


def make_probe(router, clock: VirtualClock, pricer: TruthPricer, cfg,
               n_prompts: int = 6):
    """Rollout probe traffic for ``roll_plans``: a fixed burst of
    small-bucket prompts pushed through ONE instance, priced on the
    virtual clock — enough first-token samples to arm the p95 guard."""
    rng = np.random.default_rng(7)
    prompts = [rng.integers(2, cfg.vocab_size,
                            size=int(length)).astype(np.int32)
               for length in np.linspace(10, 40, n_prompts)]

    def probe(name: str) -> None:
        eng = router.engines[name]
        for prompt in prompts:
            rid = eng.add_request(prompt, max_new_tokens=2)
            assert rid is not None, "probe request rejected"
        for _ in range(5000):
            if not (eng.step() or eng.scheduler.pending()):
                break
            clock.t += pricer.step_cost(eng)

    return probe


def sabotage_plan(refined, truth, cfg, small_edge: int):
    """The rollback-guard scenario: the refined artifact with the
    small-bucket prefill cells' tiles replaced by their WORST measured
    candidates (exact believed-hardware entries, so they win resolution).
    Rolling this must regress the probe p95 and trip the guard."""
    from repro.core import HARDWARE_REGISTRY
    from repro.core.plans import PlanEntry, PlanTransferWarning, TilePlan
    from repro.core.tiling import TileShape
    from repro.launch.specs import kernel_problems

    hw = HARDWARE_REGISTRY[BELIEVED_HW]
    bad_cells = kernel_problems(cfg, 1, small_edge, "prefill")
    bad_keys = {(kernel, tuple(sorted(problem.items())))
                for kernel, problem in bad_cells.items()}
    entries = [e for e in refined.entries()
               if not (e.hardware == BELIEVED_HW
                       and (e.kernel, tuple(e.problem)) in bad_keys)]
    sabotaged = TilePlan(
        entries=entries,
        meta={"generated_by": "bench_plan_refinement:sabotaged"})
    for kernel, problem in bad_cells.items():
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", PlanTransferWarning)
            res = refined.resolve(kernel, problem, "float32", hw)
        assert res is not None
        worst = max((tuple(int(x) for x in dims) for dims, _ in
                     res.entry.curve),
                    key=lambda d: truth(kernel, problem, "float32", d))
        worst_s = truth(kernel, problem, "float32", worst)
        sabotaged.add(PlanEntry(
            kernel=kernel, hardware=BELIEVED_HW, dtype="float32",
            problem=tuple(sorted(problem.items())),
            tile=TileShape(worst), score_s=worst_s, dominant="measured",
            sensitivity=1.0, curve=((worst, worst_s),)))
    return sabotaged


def run(smoke: bool = False, plans_path: Optional[str] = None,
        refined_out: Optional[str] = None, drift_out: Optional[str] = None,
        trace_out: Optional[str] = None, print_fn=print) -> int:
    import jax

    from repro import configs, kernels
    from repro.core.plans import (
        PLAN_SCHEMA_VERSION, PlanTransferWarning, TilePlan,
    )
    from repro.serve import BucketPolicy, PlanRefiner, drift_report

    kernels.register_all()
    p = SMOKE if smoke else FULL
    edges, slots = p["edges"], p["slots"]
    new_tokens = p["new_tokens"]
    small_edge, top = min(edges), max(edges)
    max_len = top + new_tokens + 8
    cfg = configs.get_smoke(ARCH)
    from repro.models import api
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    lens = trace_lib.head_of_line_lengths(p["small_lens"], p["long_lens"])
    trace = trace_lib.prompts(lens, rng, cfg.vocab_size)

    truth = make_truth()
    wrong, native = build_plans(plans_path, edges, slots, max_len, truth,
                                print_fn)
    pricer = TruthPricer(cfg, slots, max_len, truth)
    print_fn(f"# trace: {trace_lib.trace_summary(trace, edges)}; wrong plan "
             f"= {len(wrong)} {DONOR_HW} cells, believed hw {BELIEVED_HW}, "
             f"truth = analytic + vmem/{CONTENTION_BW:.0e}")

    failures = 0

    def policy():
        return BucketPolicy(edges, max_queue=len(trace) + 16)

    # -- phase 1: shadow measurement on the wrongly-planned live fleet -----
    # The main trace records the whole closed loop on the live fleet's
    # virtual clock: transfer-sourced resolutions, every shadow sample,
    # the refine decisions, and both roll_plans passes (kept + reverted).
    refiner = PlanRefiner(min_samples=MIN_SAMPLES)
    clock = VirtualClock()
    tracer = None
    if trace_out:
        from repro.obs import Tracer

        tracer = Tracer(clock=clock)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        fleet = make_fleet(
            wrong, cfg, params, policy(), slots, max_len, clock,
            shadow_fraction=1.0,
            shadow_measure=lambda kernel, problem, dtype, tile: truth(
                kernel, problem, dtype, tile),
            refiner=refiner, tracer=tracer)
    n_transfer = sum(issubclass(w.category, PlanTransferWarning)
                     for w in caught)
    if not n_transfer:
        failures += 1
        print_fn("FAIL: wrong-plan fleet resolved without a single "
                 "PlanTransferWarning — the starting plan is not wrong")

    needed = shadow_ticks_needed(fleet)
    rounds = 0
    for rounds in range(1, p["max_rounds"] + 1):
        drive_fleet(fleet, clock, pricer, trace, new_tokens,
                    p["arrivals_per_step"])
        ticks = sum(eng.metrics.shadow_steps
                    for eng in fleet.engines.values())
        needed = shadow_ticks_needed(fleet)   # prefill cells appear lazily
        if ticks >= needed:
            break
    ticks = sum(eng.metrics.shadow_steps for eng in fleet.engines.values())
    print_fn(f"# shadow: {ticks} diverted steps over {rounds} trace "
             f"round(s) (target {needed}), {refiner.n_samples()} samples "
             f"across {len(refiner.cells())} cells")
    if ticks < needed:
        failures += 1
        print_fn(f"FAIL: shadow sampling did not reach the confidence "
                 f"target in {p['max_rounds']} rounds ({ticks}/{needed})")

    # -- phase 2: re-rank + provenance round-trip --------------------------
    refined = refiner.refine(
        wrong, trace=(tracer.attach("refiner", kind="refiner")
                      if tracer is not None else None))
    report = drift_report(refined)
    print_fn(f"# refined {report['n_refined']} cell(s):")
    for cell in report["cells"]:
        print_fn(f"#   {cell['cell']}: {cell['incumbent']} -> "
                 f"{cell['refined']} ({cell['speedup']:.2f}x over the "
                 f"measured incumbent, n={cell['samples']})")
    if report["n_refined"] < 3:
        failures += 1
        print_fn(f"FAIL: expected >= 3 confidently re-ranked cells, got "
                 f"{report['n_refined']}")

    import os
    import tempfile

    out_path = refined_out
    if out_path is None:
        fd, out_path = tempfile.mkstemp(suffix=".json")
        os.close(fd)
    refined.save(out_path)
    reloaded = TilePlan.load(out_path)
    if refined_out is None:
        os.unlink(out_path)
    if reloaded.meta.get("refined_from", {}).get(
            "schema_version") != PLAN_SCHEMA_VERSION:
        failures += 1
        print_fn("FAIL: refinement provenance did not survive the "
                 "schema-v3 save/load round-trip")
    if len(reloaded.meta.get("measurements", ())) != report["n_refined"]:
        failures += 1
        print_fn("FAIL: measurement provenance lost in save/load")
    from repro.core import HARDWARE_REGISTRY
    hw = HARDWARE_REGISTRY[BELIEVED_HW]
    for m in refined.meta["measurements"]:
        res = reloaded.resolve(m["kernel"], m["problem"], m["dtype"], hw)
        if res is None or res.source != "exact":
            failures += 1
            print_fn(f"FAIL: refined cell {m['kernel']} does not resolve "
                     f"exactly on {BELIEVED_HW} after reload "
                     f"(source={getattr(res, 'source', None)})")
    if drift_out:
        with open(drift_out, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print_fn(f"# drift report written to {drift_out}")
    if refined_out:
        print_fn(f"# refined artifact written to {refined_out}")

    # -- phase 3: guarded rollout across the live fleet --------------------
    probe = make_probe(fleet, clock, pricer, cfg)
    decisions = fleet.roll_plans(refined, drive_fn=probe,
                                 tolerance=ROLL_TOLERANCE)
    for d in decisions:
        print_fn(f"# roll {d.instance}: pre p95 {d.pre_p95 * 1e3:.3f}ms -> "
                 f"post {d.post_p95 * 1e3:.3f}ms "
                 f"{'ROLLED BACK' if d.rolled_back else 'kept'}")
        if d.rolled_back:
            failures += 1
            print_fn(f"FAIL: refined artifact rolled back on {d.instance} "
                     f"— refinement should improve the probe p95")
    if any(eng.plans is not refined for eng in fleet.engines.values()):
        failures += 1
        print_fn("FAIL: fleet is not on the refined artifact after rollout")

    # -- phase 4: clean-fleet TTFT comparison (wrong / native / refined) ---
    # Each arm writes its own deterministic trace file next to --trace-out
    # ({stem}.{arm}{suffix}); CI diffs the refined arm against the wrong
    # arm through trace_report, which must flag the TTFT regression.
    results = {}
    arm_traces = {}
    for arm, plan in (("wrong", wrong), ("native", native),
                      ("refined", refined)):
        clock_a = VirtualClock()
        tracer_a = None
        if trace_out:
            from repro.obs import Tracer

            tracer_a = Tracer(clock=clock_a)
        fleet_a = make_fleet(plan, cfg, params, policy(), slots, max_len,
                             clock_a, tracer=tracer_a)
        placed = drive_fleet(fleet_a, clock_a, pricer, trace, new_tokens,
                             p["arrivals_per_step"])
        if tracer_a is not None:
            import os

            from repro.obs import write_trace

            stem, suffix = os.path.splitext(trace_out)
            arm_traces[arm] = f"{stem}.{arm}{suffix or '.json'}"
            write_trace(tracer_a, arm_traces[arm])
        results[arm] = dict(
            p95=small_p95(fleet_a, small_edge),
            tokens=fleet_tokens(fleet_a, placed),
            wall=clock_a.t,
        )
        print_fn(f"{arm}: small-bucket p95 TTFT "
                 f"{results[arm]['p95'] * 1e3:.3f}ms, total "
                 f"{clock_a.t * 1e3:.2f}ms virtual")
    if results["refined"]["p95"] > RECOVERY_TOL * results["native"]["p95"]:
        failures += 1
        print_fn(f"FAIL: refined p95 {results['refined']['p95']:.6f}s not "
                 f"within {RECOVERY_TOL}x of natively-tuned "
                 f"{results['native']['p95']:.6f}s")
    if not results["refined"]["p95"] < results["wrong"]["p95"]:
        failures += 1
        print_fn(f"FAIL: refined p95 {results['refined']['p95']:.6f}s not "
                 f"below the wrong plan's {results['wrong']['p95']:.6f}s")
    for arm in ("native", "refined"):
        if results[arm]["tokens"] != results["wrong"]["tokens"]:
            failures += 1
            print_fn(f"FAIL: {arm} greedy outputs differ from the wrong "
                     f"arm (token parity broken)")

    # -- phase 5: the rollback guard actually guards -----------------------
    sabotaged = sabotage_plan(refined, truth, cfg, small_edge)
    decisions = fleet.roll_plans(sabotaged, drive_fn=probe,
                                 tolerance=ROLL_TOLERANCE)
    for d in decisions:
        print_fn(f"# sabotage roll {d.instance}: pre p95 "
                 f"{d.pre_p95 * 1e3:.3f}ms -> post "
                 f"{d.post_p95 * 1e3:.3f}ms "
                 f"{'ROLLED BACK' if d.rolled_back else 'kept'}")
        if not d.rolled_back:
            failures += 1
            print_fn(f"FAIL: sabotaged artifact NOT rolled back on "
                     f"{d.instance} — the p95 guard is not guarding")
    if any(eng.plans is not refined for eng in fleet.engines.values()):
        failures += 1
        print_fn("FAIL: fleet did not revert to the refined artifact "
                 "after the sabotaged roll")

    if tracer is not None:
        from repro.obs import write_trace

        write_trace(tracer, trace_out)
        print_fn(f"# trace written to {trace_out} "
                 f"({len(tracer.events)} events); per-arm traces: "
                 + ", ".join(f"{a}={arm_traces[a]}" for a in
                             sorted(arm_traces)))

    print_fn("PASS" if not failures else f"{failures} FAILURES")
    return failures


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="scaled trace for CI (seconds, not minutes)")
    ap.add_argument("--plans", default=None,
                    help="compiled TilePlan artifact to reuse for the "
                         "donor cells (falls back to compiling them)")
    ap.add_argument("--refined-out", default=None,
                    help="write the refined schema-v3 artifact here")
    ap.add_argument("--drift-out", default=None,
                    help="write the incumbent-vs-refined drift report "
                         "(JSON) here — the CI plan-drift artifact")
    ap.add_argument("--trace-out", default=None,
                    help="write the live fleet's closed-loop trace here, "
                         "plus one clean-arm trace per phase-4 arm at "
                         "{stem}.{wrong|native|refined}{suffix} — CI diffs "
                         "refined vs wrong through trace_report")
    args = ap.parse_args()
    sys.exit(1 if run(smoke=args.smoke, plans_path=args.plans,
                      refined_out=args.refined_out,
                      drift_out=args.drift_out, trace_out=args.trace_out)
             else 0)


if __name__ == "__main__":
    main()
