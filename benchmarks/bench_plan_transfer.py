"""Plan-transfer penalty: tuned on model A, run on model B.

The paper's Fig. 3 cross-model comparison, productized: for each kernel we
take the tile a plan compiled FOR hardware A would pick, run it unchanged on
hardware B ("naive" transfer — what you get by shipping one tuned config to
a mixed fleet), and compare against B's own optimum. We then show what the
plan store's ``cross_hardware`` resolution recovers by re-ranking the
donor's candidate curve with B's cost model.

CSV: kernel,problem,src_hw,dst_hw,naive_penalty_pct,reranked_penalty_pct
"""
import warnings

from repro import kernels
from repro.core import (
    GEFORCE_8800GTS, GTX260, TPU_V5E, TPU_V6E, Autotuner,
)
from repro.core.plans import compile_plan, _rescore

CASES = [
    # (kernel, problem, dtype, tuned-on, run-on)
    ("bilinear_cuda", dict(src_h=800, src_w=800, scale=2), "float32",
     GTX260, GEFORCE_8800GTS),
    ("bilinear_cuda", dict(src_h=800, src_w=800, scale=6), "float32",
     GTX260, GEFORCE_8800GTS),
    ("bilinear_cuda", dict(src_h=800, src_w=800, scale=10), "float32",
     GEFORCE_8800GTS, GTX260),
    ("matmul", dict(m=8192, k=4096, n=4096), "bfloat16", TPU_V5E, TPU_V6E),
    ("flash_attention",
     dict(sq=4096, skv=4096, d=128, hq=16, hkv=8, window=0), "bfloat16",
     TPU_V6E, TPU_V5E),
    ("rglru", dict(s=4096, f=4096), "bfloat16", TPU_V5E, TPU_V6E),
]


def run(print_fn=print):
    kernels.register_all()
    at = Autotuner()
    print_fn("kernel,problem,src_hw,dst_hw,naive_penalty_pct,"
             "reranked_penalty_pct")
    for kernel, prob, dtype, src, dst in CASES:
        src_best = at.sweep(kernel, prob, dtype, src).best.tile
        dst_best_s = at.sweep(kernel, prob, dtype, dst).best.score
        # Naive: ship A's winner to B unchanged.
        naive_s = _rescore(kernel, src_best, prob, dtype, dst)
        # Plan store: compile only on A, resolve on B (re-ranked transfer).
        plan = compile_plan([(kernel, prob, dtype, src)], autotuner=at)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")  # the transfer warning, expected
            res = plan.resolve(kernel, prob, dtype, dst)
        reranked_s = res.score_s if res is not None else float("inf")
        naive_pct = 100.0 * (naive_s / dst_best_s - 1.0)
        rerank_pct = 100.0 * (reranked_s / dst_best_s - 1.0)
        pk = ";".join(f"{k}={v}" for k, v in sorted(prob.items()))
        print_fn(f"{kernel},{pk},{src.name},{dst.name},"
                 f"{naive_pct:.1f},{rerank_pct:.1f}")
