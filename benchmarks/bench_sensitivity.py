"""Paper §IV.C reproduction: tile-shape sensitivity vs core count.

"The more cores the less dependence on tiling dimensions": we sweep the
bilinear tile space over a family of synthetic GPUs that differ ONLY in SM
count (the paper's 2-SM vs 20-SM thought experiment), plus the two real
models, and report worst/best cost ratio (sensitivity).

CSV: gpu,num_sm,total_cores,sensitivity
"""
import dataclasses
import itertools

import repro.kernels.bilinear.ops  # noqa: F401
from repro.core import Autotuner, GEFORCE_8800GTS, GTX260
from repro.core.tiling import TileShape

SWEEP = [TileShape((h, w)) for h, w in itertools.product((4, 8, 16, 32),
                                                         repeat=2)]


def run(print_fn=print):
    at = Autotuner()
    prob = dict(src_h=800, src_w=800, scale=6)
    print_fn("gpu,num_sm,total_cores,sensitivity")
    results = []
    # Synthetic family: GTX260-like chips with varying SM counts. Total
    # bandwidth/flops scale with SM count so per-SM resources are constant —
    # isolating the paper's parallelism argument.
    for n_sm in (2, 6, 12, 24, 48):
        hw = dataclasses.replace(
            GTX260, name=f"synthetic_{n_sm}sm", num_sm=n_sm,
            num_cores=8 * n_sm,
            peak_flops_bf16=GTX260.peak_flops_bf16 * n_sm / 24,
            hbm_bw=GTX260.hbm_bw * n_sm / 24,
        )
        sens = at.sweep("bilinear_cuda", prob, "float32", hw,
                        tiles=SWEEP).sensitivity()
        results.append((hw.name, n_sm, hw.num_cores, sens))
        print_fn(f"{hw.name},{n_sm},{hw.num_cores},{sens:.3f}")
    for hw in (GEFORCE_8800GTS, GTX260):
        sens = at.sweep("bilinear_cuda", prob, "float32", hw,
                        tiles=SWEEP).sensitivity()
        results.append((hw.name, hw.num_sm, hw.num_cores, sens))
        print_fn(f"{hw.name},{hw.num_sm},{hw.num_cores},{sens:.3f}")
    return results


if __name__ == "__main__":
    run()
