"""Shape-bucketed vs naive-FIFO serving admission, across a hardware fleet.

A synthetic open-loop load generator (arrivals follow a fixed schedule, not
completions) drives the real ``ServeEngine`` on the smoke config with a
mixed-shape request trace, once with naive FIFO admission (raw prompt
shapes) and once with the shape-bucketed scheduler (prompts padded to the
plan's bucket edges), for each modelled hardware target. The AOT plan is
compiled for exactly the scheduler's shape family, so the comparison
quantifies the subsystem's core claim:

* **plan hit rate** — bucketed admission lands every prefill on an exact
  plan cell; FIFO shapes degrade to nearest-shape/fallback resolutions;
* **throughput / TTFT / TPOT** — shape binding also collapses the number of
  distinct compiled prefill programs (a real wall-clock effect on every
  backend);
* **fleet placement** — the router prices each (bucket, hardware) pair with
  the per-model resolved plan; memory-bound buckets and compute-bound
  buckets pick different hardware, and the per-model tiles differ (the
  paper's claim at fleet granularity).

Asserted invariants (exit 1 on violation; CI runs ``--smoke``):
  1. bucketed exact-hit rate > FIFO exact-hit rate on BOTH hardware targets;
  2. the fleet placement table uses >= 2 distinct instances across buckets;
  3. >= 1 bucket resolves different tiles on the two hardware models;
  4. every engine's decode step resolves its flash-decode KV split from the
     plan (exact or nearest) and the split legally applies — no
     ``tile_fallback`` events on the decode path.
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Dict, List, Tuple

import numpy as np

import traces as trace_lib


SMOKE = dict(
    edges=(16, 64, 256, 1024),
    lengths=[5, 9, 20, 40, 60, 200, 230, 650, 12, 700],
    new_tokens=3,
    slots=2,
    arrivals_per_step=2,
)
FULL = dict(
    edges=(32, 128, 512, 1024),
    lengths=None,          # sampled: 24 requests from three length bands
    # Short generations keep the (compute-bound, bandwidth-model-sensitive)
    # prefill term visible in the routing score next to the memory-bound
    # decode term — the regime where per-model placement differs.
    new_tokens=4,
    slots=4,
    arrivals_per_step=2,
)
HARDWARE = ("tpu_v4", "tpu_v5e")
ARCH = "qwen2-1.5b"


def make_trace(params: dict, rng: np.random.Generator,
               vocab: int) -> List[np.ndarray]:
    lengths = params["lengths"]
    if lengths is None:
        lengths = trace_lib.banded_lengths(rng)
    return trace_lib.prompts(lengths, rng, vocab)


def compile_serving_plan(edges, slots: int, max_len: int,
                         plans_path=None, print_fn=print):
    """AOT plan covering exactly the scheduler's shape family on the fleet.

    ``plans_path`` reuses a compiled artifact (CI passes the compile-plans
    job's upload) when it covers every serving cell on both hardware
    targets; otherwise the bench compiles its own.
    """
    from repro.launch.compile_plans import (
        load_or_compile_cells, serve_bucket_cells,
    )

    cells = serve_bucket_cells([ARCH], edges, slots, max_len, smoke=True)
    return load_or_compile_cells(
        plans_path, cells, HARDWARE,
        meta={"generated_by": "bench_serve_scheduler"}, print_fn=print_fn)


def drive_open_loop(submit, step, trace, new_tokens: int,
                    arrivals_per_step: int, max_steps: int = 5000) -> float:
    """Open-loop: submit ``arrivals_per_step`` per engine step regardless of
    completions; returns wall seconds to fully drain.

    Raises RuntimeError when ``max_steps`` elapse with work still pending
    (mirrors ``FleetRouter.run_until_done``'s ``FleetExhausted``): a bench
    that silently measures a partial drain reports fantasy throughput."""
    t0 = time.perf_counter()
    i = 0
    for tick in range(max_steps):
        while i < len(trace) and i < arrivals_per_step * (tick + 1):
            submit(trace[i], new_tokens)
            i += 1
        residue = step()
        if not residue and i >= len(trace):
            break
    else:
        raise RuntimeError(
            f"drive_open_loop: not drained after {max_steps} steps "
            f"({residue} units still pending, {len(trace) - i} unsubmitted)")
    return time.perf_counter() - t0


def run(smoke: bool = False, plans_path=None, trace_family=None,
        trace_out=None, print_fn=print) -> int:
    import jax

    from repro import configs, kernels
    from repro.core import HARDWARE_REGISTRY
    from repro.models import api
    from repro.serve import (
        BucketPolicy, FifoScheduler, FleetRouter, ServeEngine,
        ShapeBucketScheduler,
    )

    kernels.register_all()
    p = SMOKE if smoke else FULL
    edges = p["edges"]
    new_tokens, slots = p["new_tokens"], p["slots"]
    max_len = max(edges) + new_tokens + 8
    cfg = configs.get_smoke(ARCH)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    if trace_family:
        # Seed-pinned adversarial family shared with the conformance suite
        # (benchmarks/traces.py). Overflow lengths are clipped to the top
        # edge: this bench's policies reject over-length prompts.
        trace = [pr[:max(edges)] for pr in trace_lib.make_trace(
            trace_family, seed=0, vocab=cfg.vocab_size, edges=edges)]
    else:
        trace = make_trace(p, rng, cfg.vocab_size)
    plan = compile_serving_plan(edges, slots, max_len,
                                plans_path=plans_path, print_fn=print_fn)
    print_fn(f"# plan: {len(plan)} cells, hardware={plan.hardware_names()}, "
             f"buckets={list(edges)}, trace={len(trace)} requests")

    tracer = None
    if trace_out:
        from repro.obs import Tracer

        tracer = Tracer()  # wall clock, same as drive_open_loop's timing

    failures = 0
    hit_rates: Dict[Tuple[str, str], float] = {}
    print_fn("scheduler,hardware,requests,tokens,wall_s,tok_per_s,"
             "exact_hit_rate,prefill_sources")
    for hw_name in HARDWARE:
        hw = HARDWARE_REGISTRY[hw_name]
        for sched_name in ("fifo", "bucket"):
            if sched_name == "fifo":
                scheduler = FifoScheduler()
            else:
                scheduler = ShapeBucketScheduler(
                    BucketPolicy(edges, max_queue=len(trace) + 1))
            eng = ServeEngine(cfg, params, max_len=max_len, slots=slots,
                              plans=plan, hardware=hw, scheduler=scheduler,
                              tracer=tracer,
                              instance=f"{sched_name}/{hw_name}")
            dres = eng.tile_resolutions.get("flash_decode")
            if (dres is None
                    or dres.source not in ("exact", "nearest_shape")):
                failures += 1
                print_fn(f"FAIL: {hw_name} decode flash_decode tile not "
                         f"plan-resolved: "
                         f"{dres.source if dres else 'missing'}")
            wall = drive_open_loop(
                lambda pr, n, e=eng: e.add_request(pr, max_new_tokens=n),
                lambda e=eng: e.step() or e.scheduler.pending(),
                trace, new_tokens, p["arrivals_per_step"])
            m = eng.metrics
            if m.plan_counts[("decode", "tile_fallback")]:
                failures += 1
                print_fn(f"FAIL: {hw_name}/{sched_name}: decode tile did "
                         f"not legally apply (tile_fallback recorded)")
            hit = m.plan_hit_rate("prefill")
            hit_rates[(sched_name, hw_name)] = hit
            srcs = m.as_dict()["plan"]["by_phase"].get("prefill", {})
            srcs = {k: v for k, v in srcs.items() if v}
            print_fn(f"{sched_name},{hw_name},{m.completed},{m.tokens_out},"
                     f"{wall:.2f},{m.tokens_out / max(wall, 1e-9):.1f},"
                     f"{hit:.2f},{srcs}")

    for hw_name in HARDWARE:
        if not hit_rates[("bucket", hw_name)] > hit_rates[("fifo", hw_name)]:
            failures += 1
            print_fn(f"FAIL: bucketed exact-hit rate not strictly above FIFO "
                     f"on {hw_name}: {hit_rates[('bucket', hw_name)]:.2f} vs "
                     f"{hit_rates[('fifo', hw_name)]:.2f}")

    # ---- fleet routing across both hardware models -------------------------
    policy = BucketPolicy(edges, max_queue=len(trace) + 1)
    engines = {
        hw_name: ServeEngine(
            cfg, params, max_len=max_len, slots=slots, plans=plan,
            hardware=HARDWARE_REGISTRY[hw_name],
            scheduler=ShapeBucketScheduler(policy),
            tracer=tracer, instance=f"fleet/{hw_name}")
        for hw_name in HARDWARE
    }
    router = FleetRouter(engines, policy, tracer=tracer)

    table = router.placement_table(new_tokens)
    print_fn(f"# fleet placement table (pure cost, {new_tokens} new tokens): "
             + ", ".join(f"{b}->{n}" for b, n in sorted(table.items())))
    for b in sorted(table):
        scores = {n: router.service_score(n, b, new_tokens)
                  for n in sorted(engines)}
        print_fn(f"#   bucket {b}: " + ", ".join(
            f"{n}={s:.3e}s" for n, s in scores.items()))
    if len(set(table.values())) < 2:
        failures += 1
        print_fn("FAIL: fleet placement table is uniform — no bucket routes "
                 "to a different hardware model")

    tile_diff_buckets = []
    for b in edges:
        tiles = router.tile_table(b)
        per_hw = [tuple(sorted(tiles.get(n, {}).items())) for n in HARDWARE]
        if len(set(per_hw)) > 1:
            tile_diff_buckets.append(b)
        print_fn(f"# tiles@bucket {b}: " + " | ".join(
            f"{n}:{tiles.get(n, {})}" for n in HARDWARE))
    if not tile_diff_buckets:
        failures += 1
        print_fn("FAIL: no bucket resolves different tiles across the two "
                 "hardware models")

    wall = drive_open_loop(
        lambda pr, n: router.route(pr, max_new_tokens=n),
        lambda: router.step_all() or router.pending(),
        trace, new_tokens, p["arrivals_per_step"])
    done = sum(eng.metrics.completed for eng in engines.values())
    toks = sum(eng.metrics.tokens_out for eng in engines.values())
    print_fn(f"# fleet run: {done} requests, {toks} tokens in {wall:.2f}s; "
             f"placements={ {str(b): v for b, v in sorted(router.placements().items())} }")

    if tracer is not None:
        from repro.obs import write_trace

        write_trace(tracer, trace_out)
        print_fn(f"# trace written to {trace_out} "
                 f"({len(tracer.events)} events)")

    print_fn("PASS" if not failures else f"{failures} FAILURES")
    return failures


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small trace for CI (fewer requests/tokens)")
    ap.add_argument("--plans", default=None,
                    help="compiled TilePlan artifact to reuse (falls back "
                         "to compiling the bench's own serving cells)")
    ap.add_argument("--trace", default=None, choices=trace_lib.FAMILIES,
                    help="replace the default banded trace with a "
                         "seed-pinned family from benchmarks/traces.py "
                         "(shared with the packing conformance suite)")
    ap.add_argument("--trace-out", default=None,
                    help="write a wall-clock lifecycle/plan-audit trace of "
                         "every arm (and the fleet run) to this path")
    args = ap.parse_args()
    sys.exit(1 if run(smoke=args.smoke, plans_path=args.plans,
                      trace_family=args.trace, trace_out=args.trace_out)
             else 0)


if __name__ == "__main__":
    main()
