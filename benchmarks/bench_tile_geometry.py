"""Paper Fig. 4 reproduction: wide vs tall tiles at fixed thread count.

The paper's Fig. 4 compares a 4x8 and an 8x4 arrangement of 32 threads:
crossing fewer image rows (wider along x) is faster. We sweep width/height
factorizations of 32, 128 and 512 threads on both GPU models.

CSV: gpu,threads,tile_wxh,cost_ms
"""
import repro.kernels.bilinear.ops  # noqa: F401
from repro.core import GEFORCE_8800GTS, GTX260, estimate
from repro.core import registry
from repro.core.tiling import TileShape


def run(print_fn=print):
    spec = registry.get("bilinear_cuda")
    prob = dict(src_h=800, src_w=800, scale=8)
    print_fn("gpu,threads,tile,cost_ms")
    out = {}
    for hw in (GTX260, GEFORCE_8800GTS):
        for threads in (32, 128, 512):
            rows = []
            w = 4
            while w <= min(threads, 512):
                h = threads // w
                if h >= 1 and w * h == threads:
                    t = TileShape((h, w))
                    c = estimate(hw, spec.workload(t, prob, "float32"),
                                 spec.n_tiles(t, prob), 0.0).total_s
                    rows.append((w, h, c))
                    print_fn(f"{hw.name},{threads},{w}x{h},{c*1e3:.3f}")
                w *= 2
            out[(hw.name, threads)] = rows
    return out


if __name__ == "__main__":
    run()
