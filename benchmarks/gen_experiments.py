"""Regenerate the auto tables in EXPERIMENTS.md from dryrun_results/.

Rewrites the blocks between the AUTO-DRYRUN / AUTO-ROOFLINE markers.
Usage: PYTHONPATH=src python -m benchmarks.gen_experiments
"""
import glob
import json
import os
import re

ROOT = os.path.join(os.path.dirname(__file__), "..")
RESULTS = os.path.join(ROOT, "dryrun_results")
EXP = os.path.join(ROOT, "EXPERIMENTS.md")


def load(tag=""):
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        has_tag = len(parts) == 3 and "." in parts[2]
        if tag and not base.endswith("." + tag):
            continue
        if not tag and has_tag:
            continue
        with open(path) as f:
            rows.append(json.load(f))
    key = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], key.get(r["shape"], 9), r["mesh"]))
    return rows


def dryrun_table():
    lines = [
        "| arch | shape | mesh | status | mb | compile_s | peak GiB | fits |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in load():
        if r["status"] == "ok":
            m = r["memory"]
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
                f"| {r.get('microbatches', 1)} | {r['compile_s']} "
                f"| {m['peak_bytes']/2**30:.2f} | {'Y' if m['fits'] else 'N'} |")
        elif r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | skipped "
                f"| — | — | — | — |")
        else:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR "
                f"| — | — | — | — |")
    return "\n".join(lines)


def roofline_table():
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant "
        "| roofline frac | useful ratio |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in load():
        if r["status"] != "ok" or r["mesh"] != "single":
            continue
        f = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {f['compute_s']:.4g} "
            f"| {f['memory_s']:.4g} | {f['collective_s']:.4g} "
            f"| {f['dominant']} | {f['roofline_fraction']:.3f} "
            f"| {f['useful_flops_ratio']:.2f} |")
    return "\n".join(lines)


def replace_block(text, marker, payload):
    pat = re.compile(
        rf"(<!-- AUTO-{marker} -->\n).*?(\n<!-- /AUTO-{marker} -->)",
        re.DOTALL)
    return pat.sub(lambda m: m.group(1) + payload + m.group(2), text)


def main():
    with open(EXP) as f:
        text = f.read()
    text = replace_block(text, "DRYRUN", dryrun_table())
    text = replace_block(text, "ROOFLINE", roofline_table())
    with open(EXP, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md tables regenerated "
          f"({len(load())} cells)")


if __name__ == "__main__":
    main()
