"""Aggregate dryrun_results/*.json into the EXPERIMENTS.md roofline table.

CSV: arch,shape,mesh,status,dominant,compute_s,memory_s,collective_s,
     roofline_fraction,useful_ratio,peak_GiB,fits
"""
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "dryrun_results")


def rows(tag: str = ""):
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        has_tag = len(parts) == 3 and "." in parts[2]
        if tag:
            if not base.endswith("." + tag):
                continue
        elif has_tag:
            continue
        with open(path) as f:
            out.append(json.load(f))
    return out


def run(print_fn=print, tag: str = ""):
    print_fn("arch,shape,mesh,status,dominant,compute_s,memory_s,"
             "collective_s,frac,useful,peak_GiB,fits")
    for r in rows(tag):
        if r["status"] != "ok":
            print_fn(f"{r['arch']},{r['shape']},{r['mesh']},{r['status']},"
                     f",,,,,,,{r.get('reason', r.get('error', ''))[:60]}")
            continue
        if r["mesh"] != "single":
            # Roofline terms are exact-probe-derived for single-pod only;
            # multi-pod cells are compile/memory proofs (see §Dry-run).
            continue
        rf = r["roofline"]
        m = r["memory"]
        print_fn(
            f"{r['arch']},{r['shape']},{r['mesh']},ok,{rf['dominant']},"
            f"{rf['compute_s']:.4f},{rf['memory_s']:.4f},"
            f"{rf['collective_s']:.4f},{rf['roofline_fraction']:.3f},"
            f"{rf['useful_flops_ratio']:.3f},"
            f"{m['peak_bytes']/2**30:.2f},{int(m['fits'])}"
        )


if __name__ == "__main__":
    import sys
    run(tag=sys.argv[1] if len(sys.argv) > 1 else "")
