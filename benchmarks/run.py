"""Benchmark aggregator — one section per paper table/figure.

Prints ``name,us_per_call,derived`` style CSV blocks:
  fig3        — tile sweep x scales x 2 GPU models (paper Fig. 3)
  fig4        — wide-vs-tall geometry (paper Fig. 4)
  sensitivity — tile sensitivity vs core count (paper §IV.C)
  transfer    — tuned-on-A/run-on-B plan-transfer penalties (Fig. 3 across models)
  kernels     — kernel reference timings + autotuned v5e tiles
  roofline    — the 40-cell dry-run roofline table (if results exist)
"""


def main() -> None:
    from benchmarks import (
        bench_bilinear_fig3, bench_kernels, bench_plan_transfer,
        bench_sensitivity, bench_tile_geometry, roofline_table,
    )

    print("== fig3: tile sweep x scale x GPU model (paper Fig. 3) ==")
    bench_bilinear_fig3.run()
    print()
    print("== fig4: wide-vs-tall tile geometry (paper Fig. 4) ==")
    bench_tile_geometry.run()
    print()
    print("== sensitivity vs core count (paper §IV.C) ==")
    bench_sensitivity.run()
    print()
    print("== plan transfer: tuned-on-A, run-on-B penalty ==")
    bench_plan_transfer.run()
    print()
    print("== kernel micro-benchmarks ==")
    bench_kernels.run()
    print()
    print("== roofline table (from dry-run results) ==")
    roofline_table.run()


if __name__ == "__main__":
    main()
