"""Seed-pinned request-trace generation shared by the serving benches and
the packing conformance suite.

``bench_chunked_prefill`` and ``bench_serve_scheduler`` used to each carry
their own trace builder — a drift risk: the differential suites only prove
anything when every arm (and every CI leg) replays the SAME trace. This
module is the single source: the head-of-line pattern (long prompt admitted
just before a burst of shorts), the mixed-shape banded trace, and the
adversarial families the packing conformance suite sweeps
(``all_long`` / ``all_short`` / ``bimodal`` / ``overflow_heavy``).

Everything is a pure function of ``(family, seed, edges)`` — no module
state — so a trace named on one bench's ``--trace`` flag is bit-identical
to the same name in ``tests/test_serve_packing.py``.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

# Adversarial length families, as fractions of the bucket-edge family:
# values <= 1.0 scale the SMALLEST edge (single-chunk shorts), values
# keyed "top" scale the LARGEST edge (multi-chunk longs / overflows).
FAMILIES = ("head_of_line", "all_short", "all_long", "bimodal",
            "overflow_heavy")


def prompts(lengths: Sequence[int], rng: np.random.Generator,
            vocab: int) -> List[np.ndarray]:
    """Random-token prompts of the given lengths (ids 2..vocab-1; 0/1 are
    reserved for pad/bos by convention)."""
    return [rng.integers(2, vocab, size=int(n)).astype(np.int32)
            for n in lengths]


def head_of_line_lengths(small_lens: Sequence[int],
                         long_lens: Sequence[int]) -> List[int]:
    """The chunked-prefill bench's pattern: a long prompt first, then half
    the small burst, the second long, then the rest — the head-of-line
    scenario chunking (and packing) exists for."""
    half = len(small_lens) // 2
    return [long_lens[0], *small_lens[:half],
            long_lens[1], *small_lens[half:]]


def banded_lengths(rng: np.random.Generator, n: int = 24,
                   bands: Sequence = ((5, 30), (100, 450), (520, 1000)),
                   ) -> List[int]:
    """The scheduler bench's mixed-shape trace: round-robin over length
    bands so every bucket stays populated."""
    return [int(rng.integers(*bands[i % len(bands)])) for i in range(n)]


def adversarial_lengths(family: str, edges: Sequence[int], n: int,
                        rng: np.random.Generator) -> List[int]:
    """Length sequence for one adversarial family, scaled to ``edges``.

    * ``all_short``     — everything fits the smallest bucket (the pure
      packing regime: many single-chunk prefills compete for each step).
    * ``all_long``      — everything lands in the top bucket (multi-chunk;
      exercises the one-long-in-flight rule and aging under packing).
    * ``bimodal``       — alternating short/long (the starvation trap:
      shorts must overtake, longs must still progress).
    * ``overflow_heavy``— mostly longer than the top edge (requires
      ``allow_overflow``; overflow chunks must stay packable).
    * ``head_of_line``  — the classic long-first-then-burst pattern at
      edge-derived lengths.
    """
    lo, top = min(edges), max(edges)
    if family == "all_short":
        return [int(rng.integers(1, lo + 1)) for _ in range(n)]
    if family == "all_long":
        return [int(rng.integers(max(lo + 1, top // 2), top + 1))
                for _ in range(n)]
    if family == "bimodal":
        return [int(rng.integers(1, lo + 1)) if i % 2 else
                int(rng.integers(max(lo + 1, top // 2), top + 1))
                for i in range(n)]
    if family == "overflow_heavy":
        return [int(rng.integers(top + 1, 2 * top + 1)) if i % 3 != 2 else
                int(rng.integers(1, lo + 1)) for i in range(n)]
    if family == "head_of_line":
        smalls = [int(rng.integers(1, lo + 1)) for _ in range(max(2, n - 2))]
        longs = [int(rng.integers(max(lo + 1, top // 2), top + 1))
                 for _ in range(2)]
        return head_of_line_lengths(smalls, longs)[:n]
    raise ValueError(f"unknown trace family {family!r} (known: {FAMILIES})")


def make_trace(family: str, seed: int, vocab: int, edges: Sequence[int],
               n: int = 12) -> List[np.ndarray]:
    """The seed-pinned named trace: same (family, seed, edges, n, vocab)
    -> bit-identical prompts everywhere (benches' ``--trace`` mode and the
    conformance suite both call this)."""
    rng = np.random.default_rng(seed)
    return prompts(adversarial_lengths(family, edges, n, rng), rng, vocab)


# -- scaled open-loop arrivals (autoscale bench) ----------------------------
# Traffic mixes for the open-loop generator. Each entry is
# (bucket_order, new_tokens_range): ``bucket_order`` picks whether the
# Zipf head lands on the SHORTEST edge ("asc" — prefill-light) or the
# LONGEST ("desc" — prefill-heavy); the range bounds per-request decode
# tokens. "compute_heavy" = long prefills + few decode steps (FLOPs-bound
# service); "memory_heavy" = short prefills + many decode steps
# (bandwidth-bound service). The autoscale bench uses the pair to show
# the policy joining DIFFERENT hardware models per mix.
OPEN_LOOP_MIXES: Dict[str, tuple] = {
    "balanced": ("asc", (8, 64)),
    "compute_heavy": ("desc", (4, 16)),
    "memory_heavy": ("asc", (96, 256)),
}

#: Open-loop load phases, in order: diurnal ramp up, flash-crowd spike,
#: decay back to trough.
OPEN_LOOP_PHASES = ("ramp", "spike", "decay")


def zipf_weights(n: int, a: float = 1.2) -> np.ndarray:
    """Normalized Zipf weights ``rank^-a`` over ``n`` ranks."""
    w = np.arange(1, n + 1, dtype=np.float64) ** (-float(a))
    return w / w.sum()


def open_loop_arrivals(seed: int, edges: Sequence[int], total: int, *,
                       peak_rate: float = 64.0, ramp_frac: float = 0.35,
                       spike_frac: float = 0.15, spike_mult: float = 3.0,
                       zipf_a: float = 1.2, mix: str = "balanced"):
    """Streaming open-loop arrival schedule at production scale.

    Yields ``(tick, phase, batch)`` per virtual tick, where ``batch`` is a
    list of ``(prompt_len, new_tokens)`` pairs arriving that tick —
    requests are generated tick by tick, so a ~10^6-request run never
    materializes in memory at once. Pure function of the arguments
    (seed-pinned ``np.random.default_rng``): same inputs, bit-identical
    schedule on every replay.

    Shape: lengths are Zipf-bucketed over ``edges`` (head bucket per
    ``mix``, uniform within the chosen bucket); rate follows a diurnal
    ramp (linear 0.1 -> 1.0 of ``peak_rate`` over the first ``ramp_frac``
    of requests), a flash-crowd spike (``spike_mult`` x peak for the next
    ``spike_frac``), then a decay (linear 1.0 -> 0.05) until ``total``
    requests have been emitted. Per-tick counts are Poisson draws at the
    phase rate.
    """
    if mix not in OPEN_LOOP_MIXES:
        raise ValueError(
            f"unknown mix {mix!r} (known: {sorted(OPEN_LOOP_MIXES)})")
    order, (nt_lo, nt_hi) = OPEN_LOOP_MIXES[mix]
    edges = sorted(int(e) for e in edges)
    ranked = edges if order == "asc" else edges[::-1]
    weights = zipf_weights(len(ranked), zipf_a)
    lows = {edge: ([1] + [e + 1 for e in edges])[i]
            for i, edge in enumerate(edges)}
    rng = np.random.default_rng(seed)
    emitted, tick = 0, 0
    while emitted < total:
        p = emitted / total
        if p < ramp_frac:
            phase = "ramp"
            rate = peak_rate * (0.1 + 0.9 * (p / ramp_frac))
        elif p < ramp_frac + spike_frac:
            phase = "spike"
            rate = peak_rate * spike_mult
        else:
            phase = "decay"
            q = (p - ramp_frac - spike_frac) / max(
                1.0 - ramp_frac - spike_frac, 1e-9)
            rate = peak_rate * (1.0 - 0.95 * q)
        k = min(int(rng.poisson(rate)), total - emitted)
        batch = []
        for _ in range(k):
            edge = ranked[int(rng.choice(len(ranked), p=weights))]
            length = int(rng.integers(lows[edge], edge + 1))
            batch.append((length, int(rng.integers(nt_lo, nt_hi + 1))))
        emitted += k
        yield tick, phase, batch
        tick += 1


def trace_summary(trace: Sequence[np.ndarray],
                  edges: Sequence[int]) -> Dict[str, int]:
    """Small/long/overflow composition of a trace (for bench logs)."""
    lo, top = min(edges), max(edges)
    lens = [len(p) for p in trace]
    return {
        "requests": len(lens),
        "small": sum(l <= lo for l in lens),
        "long": sum(lo < l <= top for l in lens),
        "overflow": sum(l > top for l in lens),
    }
