"""Quickstart: the paper's workflow end-to-end in five minutes on CPU.

1. Upscale an image with the tile-parameterized Pallas bilinear kernel
   (validated in interpret mode against the paper's Eq. 1-5 oracle).
2. Sweep tile shapes per hardware model with the autotuner — the paper's
   Fig. 3 experiment — and see the per-model optima differ.
3. Ask the TilingPolicy for robust (worst-case-fleet) defaults (paper §V).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np

import repro.kernels.bilinear.ops as bilinear  # registers kernels
from repro.core import (
    Autotuner, GEFORCE_8800GTS, GTX260, TPU_V5E, TilingPolicy,
)
from repro.core.tiling import TileShape

# -- 1. run the kernel ------------------------------------------------------
src = jax.random.uniform(jax.random.PRNGKey(0), (64, 128), jnp.float32)
out = bilinear.upscale(src, scale=4, tile=(128, 512), interpret=True)
ref = bilinear.upscale_ref(src, 4)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                           atol=1e-5)
print(f"bilinear upscale {src.shape} -> {out.shape}: matches oracle")

# -- 2. the paper's per-model sweep ------------------------------------------
at = Autotuner()
sweep = [TileShape((h, w))
         for h, w in itertools.product((4, 8, 16, 32), repeat=2)]
prob = dict(src_h=800, src_w=800, scale=6)
for hw in (GTX260, GEFORCE_8800GTS):
    res = at.sweep("bilinear_cuda", prob, "float32", hw, tiles=sweep)
    b = res.best
    print(f"{hw.name:18s} best tile {b.tile[1]}x{b.tile[0]} "
          f"({b.score*1e3:.2f} ms model-time, "
          f"sensitivity {res.sensitivity():.1f}x)")

# -- 3. robust fleet default (paper §V) --------------------------------------
pol = TilingPolicy(mode="robust", fleet=(GTX260, GEFORCE_8800GTS))
t = pol.tile_for("bilinear_cuda", prob, "float32")
print(f"robust fleet tile: {t[1]}x{t[0]}  (the paper's 32x4 principle)")

# -- and the TPU side: autotuned matmul tile for v5e --------------------------
import repro.kernels.matmul.ops  # noqa: F401
mm_tile = at.best_tile("matmul", dict(m=4096, k=4096, n=4096), "bfloat16",
                       TPU_V5E)
print(f"v5e matmul tile (bm, bk, bn) = {tuple(mm_tile)}")
