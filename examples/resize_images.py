"""The paper's application: batch image upscaling with autotuned tiles.

Generates a batch of synthetic images, picks the tile for the current
hardware via TilingPolicy, and upscales — the bilinear kernel at work.

Run:  PYTHONPATH=src python examples/resize_images.py --scale 4
"""
import argparse
import time

import jax
import jax.numpy as jnp

import repro.kernels.bilinear.ops as bilinear
from repro.core import TPU_V5E, TilingPolicy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=4)
    ap.add_argument("--size", type=int, default=128)
    ap.add_argument("--count", type=int, default=4)
    args = ap.parse_args()

    pol = TilingPolicy(mode="tuned", hardware=TPU_V5E)
    prob = dict(src_h=args.size, src_w=args.size, scale=args.scale)
    tile = pol.tile_for("bilinear", prob, "float32")
    # On CPU we execute the oracle (jit-fused); on TPU the Pallas kernel
    # runs with the autotuned tile.
    on_tpu = jax.devices()[0].platform == "tpu"
    print(f"hardware={'tpu' if on_tpu else 'cpu'} "
          f"autotuned v5e tile={tuple(tile)}")

    keys = jax.random.split(jax.random.PRNGKey(0), args.count)
    t0 = time.perf_counter()
    for i, k in enumerate(keys):
        img = jax.random.uniform(k, (args.size, args.size), jnp.float32)
        if on_tpu:
            out = bilinear.upscale(img, args.scale, tile=tuple(tile))
        else:
            out = bilinear.upscale_ref(img, args.scale)
        out.block_until_ready()
        print(f"image {i}: {img.shape} -> {out.shape} "
              f"mean={float(out.mean()):.4f}")
    print(f"total {time.perf_counter() - t0:.3f}s")


if __name__ == "__main__":
    main()
