"""Batched serving example: continuous-batching engine over a small LM.

Run:  PYTHONPATH=src python examples/serve_lm.py --requests 8
"""
import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import api
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b",
                    choices=configs.list_archs())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_len=128, slots=args.slots)

    rng = np.random.default_rng(1)
    lengths = rng.integers(4, 16, size=args.requests)
    t0 = time.perf_counter()
    for n in lengths:
        engine.add_request(rng.integers(2, cfg.vocab_size, size=n),
                           max_new_tokens=args.new_tokens)
    done = engine.run_until_done()
    dt = time.perf_counter() - t0

    total = sum(len(r.out_tokens) for r in done)
    print(f"arch={cfg.name} slots={args.slots}")
    for r in sorted(done, key=lambda r: r.rid):
        print(f"  req {r.rid} (prompt {len(r.prompt)} tok) "
              f"-> {len(r.out_tokens)} new tokens")
    print(f"{len(done)} requests, {total} tokens, {dt:.2f}s "
          f"({total/dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
