"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

A mid-size config (not the tiny smoke config): 8 layers, d_model 512,
GQA 8/2, vocab 32768 — about 100M params when counted with embeddings.
Synthetic Zipf data, AdamW + warmup-cosine, async checkpoints, straggler
monitor. Loss should drop from ~10.4 to well under 8 within 200 steps.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses
import logging

import numpy as np

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig


def make_100m_config() -> ArchConfig:
    return ArchConfig(
        name="demo-100m", family="dense",
        n_layers=12, d_model=640, n_heads=10, n_kv_heads=2, head_dim=64,
        d_ff=2560, vocab_size=50304, tie_embeddings=True,
    ).validate()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_100m")
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    cfg = make_100m_config()
    import jax
    from repro.models import api as _api
    shapes = jax.eval_shape(
        lambda: _api.init_params(cfg, jax.random.PRNGKey(0)))
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    print(f"config {cfg.name}: {n_params/1e6:.0f}M params")

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                          global_batch=args.global_batch)
    tcfg = TrainerConfig(
        steps=args.steps, checkpoint_every=100,
        checkpoint_dir=args.checkpoint_dir,
        peak_lr=3e-4, warmup_steps=20, log_every=10,
    )
    trainer = Trainer(cfg, data_cfg, tcfg,
                      opt_cfg=adamw.AdamWConfig(weight_decay=0.01))
    out = trainer.run(fail_at=args.fail_at)
    first, last = out["losses"][0], out["losses"][-1]
    print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"(restarts={out['restarts']})")
    if args.steps >= 100:
        assert last < first - 1.0, "training did not make progress"


if __name__ == "__main__":
    main()
