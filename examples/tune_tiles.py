"""Autotune every registered kernel for a hardware fleet and dump the cache.

This is the paper's methodology as an operational tool: run once per
hardware model, ship the cache with the binary.

Run:  PYTHONPATH=src python examples/tune_tiles.py --cache /tmp/tiles.json

With ``--compile-plans OUT.json`` the same sweep is packaged as a portable,
schema-versioned TilePlan artifact (best tile per hardware + the full
sensitivity curve) instead of a bare cache — the input to
``ServeEngine(plans=...)`` / ``TrainerConfig.tile_plans``. The full-fleet
compiler with shape-family problems is ``python -m repro.launch.compile_plans``.
"""
import argparse
import json

import repro.kernels.bilinear.ops  # noqa: F401
import repro.kernels.flash_attention.ops  # noqa: F401
import repro.kernels.matmul.ops  # noqa: F401
import repro.kernels.rglru.ops  # noqa: F401
import repro.kernels.ssd.ops  # noqa: F401
from repro.core import Autotuner, HARDWARE_REGISTRY

PROBLEMS = {
    "matmul": [dict(m=4096, k=4096, n=4096), dict(m=65536, k=4096, n=1536)],
    "flash_attention": [
        dict(sq=4096, skv=4096, d=128, hq=16, hkv=8, window=0),
        dict(sq=32768, skv=32768, d=128, hq=16, hkv=8, window=4096),
    ],
    "rglru": [dict(s=4096, f=4096)],
    "ssd": [dict(s=4096, h=80, p=64, n=128)],
    "bilinear": [dict(src_h=800, src_w=800, scale=s) for s in (2, 6, 10)],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cache", default="/tmp/repro_tiles.json")
    ap.add_argument("--hardware", nargs="*",
                    default=["tpu_v4", "tpu_v5e", "tpu_v5p", "tpu_v6e"])
    ap.add_argument("--compile-plans", default=None, metavar="OUT",
                    help="write a portable TilePlan artifact instead of a "
                         "bare autotuner cache")
    args = ap.parse_args()

    if args.compile_plans:
        from repro.core.plans import PLAN_SCHEMA_VERSION, compile_plan

        # dtype is part of the plan key, so cover what consumers actually
        # run (ServeEngine/Trainer default to float32, production uses
        # bfloat16); the shared policy pins image kernels to float32.
        from repro.launch.compile_plans import kernel_dtypes

        jobs = [
            (kernel, prob, dtype, HARDWARE_REGISTRY[hw_name])
            for hw_name in args.hardware
            for kernel, problems in PROBLEMS.items()
            for prob in problems
            for dtype in kernel_dtypes(kernel, ("bfloat16", "float32"))
        ]
        plan = compile_plan(jobs, meta={"generated_by": "examples.tune_tiles"})
        plan.save(args.compile_plans)
        for e in sorted(plan.entries(), key=lambda e: e.key):
            print(f"{e.hardware:10s} {e.kernel:16s} "
                  f"{str(e.problem_dict)[:48]:50s} -> {e.tile}")
        print(f"\nplan artifact (schema v{PLAN_SCHEMA_VERSION}, "
              f"{len(plan)} entries) written to {args.compile_plans}")
        return

    at = Autotuner(cache_path=args.cache)
    for hw_name in args.hardware:
        hw = HARDWARE_REGISTRY[hw_name]
        for kernel, problems in PROBLEMS.items():
            for prob in problems:
                tile = at.best_tile(kernel, prob, "bfloat16", hw)
                print(f"{hw_name:10s} {kernel:16s} "
                      f"{str(dict(prob))[:48]:50s} -> {tile}")
    print(f"\ncache written to {args.cache}")
    with open(args.cache) as f:
        print(f"{len(json.load(f))} entries")


if __name__ == "__main__":
    main()
