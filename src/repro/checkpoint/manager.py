"""Checkpoint manager: async, atomic, retention, elastic restore.

Fault-tolerance contract (DESIGN.md §6):
  * saves are ATOMIC — written to ``<dir>/tmp.<step>`` then renamed, so a
    crash mid-save never corrupts the latest checkpoint;
  * saves are ASYNC — a background thread serializes device arrays after
    they are fetched to host, keeping the train loop running;
  * retention keeps the newest K checkpoints;
  * ``restore`` reshards onto the CURRENT mesh (elastic scaling): arrays are
    loaded as host numpy and ``jax.device_put`` with the new sharding, so a
    job checkpointed on 512 chips restarts on 256 (or 1, for tests).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_into(template, flat):
    def walk(t, prefix=""):
        if isinstance(t, dict):
            return {k: walk(v, f"{prefix}{k}/") for k, v in t.items()}
        if isinstance(t, tuple):
            return tuple(walk(v, f"{prefix}{i}/") for i, v in enumerate(t))
        if isinstance(t, list):
            return [walk(v, f"{prefix}{i}/") for i, v in enumerate(t)]
        return flat[prefix[:-1]]
    return walk(template)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        """Snapshot ``tree`` at ``step``. Returns immediately if async."""
        self.wait()  # at most one in-flight save
        host_flat = {
            k: np.asarray(v) for k, v in _flatten(tree).items()
        }
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_flat, extra or {}),
                daemon=True,
            )
            self._thread.start()
        else:
            self._write(step, host_flat, extra or {})

    def _write(self, step: int, flat: Dict[str, np.ndarray], extra: Dict):
        tmp = os.path.join(self.dir, f"tmp.{step}")
        final = os.path.join(self.dir, f"step_{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, **extra}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)           # atomic publish
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "meta.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Load into the structure of ``template``; reshard if given.

        ``shardings``: optional pytree of jax.sharding.Sharding matching
        ``template`` — enables elastic restore onto a different mesh.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:010d}", "arrays.npz")
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten_into(template, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda arr, sh: jax.device_put(arr, sh), tree, shardings
            )
        return tree

    def meta(self, step: Optional[int] = None) -> Dict:
        step = step if step is not None else self.latest_step()
        with open(os.path.join(self.dir, f"step_{step:010d}", "meta.json")) as f:
            return json.load(f)
