"""Architecture registry: the ten assigned configs + the paper's workload."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig
from repro.configs.shapes import SHAPES, ShapeSpec, applicable, get_shape

_MODULES: Dict[str, str] = {
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "command-r-35b": "repro.configs.command_r_35b",
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    "qwen2-1.5b": "repro.configs.qwen2_1_5b",
    "gemma2-9b": "repro.configs.gemma2_9b",
    "internvl2-1b": "repro.configs.internvl2_1b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
}


def list_archs() -> List[str]:
    return sorted(_MODULES)


def get_arch(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_smoke(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    return importlib.import_module(_MODULES[name]).smoke_config()


__all__ = [
    "ArchConfig", "SHAPES", "ShapeSpec", "applicable", "get_shape",
    "list_archs", "get_arch", "get_smoke",
]
