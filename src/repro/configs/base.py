"""Architecture configuration schema.

One :class:`ArchConfig` describes any of the ten assigned architectures; the
generic stack builder in ``models/transformer.py`` consumes it. Layers are a
sequence of :class:`LayerSpec` (mixer + feed-forward choice); consecutive
identical specs are grouped and scanned, so a 94-layer homogeneous model
compiles as one scanned block.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.tiling import round_up

# Vocab is padded to lcm(model-shards, lanes) so the embedding shards evenly.
VOCAB_PAD_MULTIPLE = 2048
# Head counts pad up to the TP degree where needed (masked, see DESIGN.md).
TP_DEGREE = 16


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer: a sequence mixer plus an optional feed-forward."""

    mixer: str          # "attn" | "local_attn" | "rglru" | "ssd"
    ff: Optional[str]   # "dense" | "moe" | None (mamba2 has no FF)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int               # per-expert FF width
    n_shared_experts: int = 0   # deepseek: always-on shared experts
    d_shared: int = 0           # shared-expert FF width (total)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    renorm_gates: bool = True


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class RecurrentConfig:
    lru_width: int = 0          # 0 => d_model
    conv_width: int = 4
    c: float = 8.0              # RG-LRU decay sharpness


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec (whisper) or a frontend stub (internvl).

    The modality frontend (conv / ViT patching) is a STUB per the task spec:
    ``input_specs`` provides precomputed frame/patch embeddings.
    """

    n_layers: int
    n_heads: int
    seq_len: int                # e.g. 1500 whisper frames, 256 vit patches
    kind: str                   # "audio" | "vision"


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 => d_model // n_heads
    layer_pattern: Tuple[LayerSpec, ...] = ()
    # Attention options -----------------------------------------------------
    attn_window: int = 0        # sliding window for "local_attn" (0 = none)
    attn_softcap: float = 0.0   # gemma2 logit softcap (0 = off)
    final_softcap: float = 0.0  # gemma2 final-logit softcap
    qkv_bias: bool = False      # qwen2 QKV bias
    use_qk_norm: bool = False   # qwen3 per-head q/k RMSNorm
    query_scale: float = 0.0    # 0 => 1/sqrt(head_dim)
    rope_theta: float = 10000.0
    # Embedding / head ------------------------------------------------------
    tie_embeddings: bool = False
    scale_embeddings: bool = False  # gemma: embed * sqrt(d_model)
    norm_eps: float = 1e-6
    norm_kind: str = "rms"      # rms | layernorm (command-r, whisper)
    parallel_block: bool = False  # command-r: attn and ff in parallel
    act: str = "silu"           # silu | gelu | gelu_tanh
    post_norms: bool = False    # gemma2 post-attention/post-ffw norms
    # Substructures ---------------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    recurrent: Optional[RecurrentConfig] = None
    encoder: Optional[EncoderConfig] = None
    # Long-context capability (drives long_500k applicability).
    subquadratic: bool = False

    # ----- derived ---------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        return round_up(self.vocab_size, VOCAB_PAD_MULTIPLE)

    @property
    def padded_heads(self) -> int:
        """Query heads padded so TP_DEGREE divides them (masked heads)."""
        if self.n_heads == 0:
            return 0
        if self.n_heads % TP_DEGREE == 0:
            return self.n_heads
        if self.n_heads < TP_DEGREE:
            return TP_DEGREE
        return round_up(self.n_heads, TP_DEGREE)

    @property
    def padded_kv_heads(self) -> int:
        """KV heads: pad to TP degree when shardable, else replicate as-is.

        kv < TP stays unpadded (replicated across model shards); kv >= TP
        pads up so the cache shards evenly.
        """
        if self.n_kv_heads >= TP_DEGREE and self.n_kv_heads % TP_DEGREE:
            return round_up(self.n_kv_heads, TP_DEGREE)
        return self.n_kv_heads

    @property
    def gqa_ratio(self) -> int:
        return max(1, self.padded_heads // max(self.padded_kv_heads, 1))

    def layers(self) -> Tuple[LayerSpec, ...]:
        if self.layer_pattern:
            if len(self.layer_pattern) != self.n_layers:
                raise ValueError(
                    f"{self.name}: pattern length {len(self.layer_pattern)} "
                    f"!= n_layers {self.n_layers}"
                )
            return self.layer_pattern
        return tuple(LayerSpec("attn", "dense") for _ in range(self.n_layers))

    def validate(self) -> "ArchConfig":
        if self.n_heads and self.n_kv_heads and self.n_heads % self.n_kv_heads:
            raise ValueError(f"{self.name}: heads {self.n_heads} % kv {self.n_kv_heads}")
        for spec in self.layers():
            if spec.mixer in ("rglru",) and self.recurrent is None:
                raise ValueError(f"{self.name}: rglru layer without recurrent cfg")
            if spec.mixer == "ssd" and self.ssm is None:
                raise ValueError(f"{self.name}: ssd layer without ssm cfg")
            if spec.ff == "moe" and self.moe is None:
                raise ValueError(f"{self.name}: moe layer without moe cfg")
            if spec.mixer == "local_attn" and not self.attn_window:
                raise ValueError(f"{self.name}: local_attn without attn_window")
        return self


def repeat_pattern(unit: Tuple[LayerSpec, ...], n_layers: int) -> Tuple[LayerSpec, ...]:
    """Tile ``unit`` to ``n_layers``, truncating the last repeat if needed."""
    reps = (n_layers + len(unit) - 1) // len(unit)
    return (unit * reps)[:n_layers]
