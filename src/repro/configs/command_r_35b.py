"""command-r-35b [dense] — GQA, no bias, parallel attn+FF block, LayerNorm.

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000
[hf:CohereForAI/c4ai-command-r-v01]. Tied embeddings, rope theta 8e6.
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    norm_kind="layernorm",
    norm_eps=1e-5,
    parallel_block=True,
    tie_embeddings=True,
    rope_theta=8000000.0,
).validate()


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=192, vocab_size=256,
    ).validate()
