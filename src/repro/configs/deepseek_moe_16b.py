"""deepseek-moe-16b [moe] — fine-grained experts: 2 shared + 64 routed top-6.

28L d_model=2048 16H (MHA kv=16) expert d_ff=1408 vocab=102400
[arXiv:2401.06066]. Layer 0 uses a dense FF (width 10944); layers 1..27 are
MoE with 2 shared experts (width 2x1408) and 64 routed, top-6, gates not
renormalized (softmax-then-topk).
"""
import dataclasses

from repro.configs.base import ArchConfig, LayerSpec, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,  # dense layer-0 FF width
    vocab_size=102400,
    layer_pattern=(LayerSpec("attn", "dense"),)
    + tuple(LayerSpec("attn", "moe") for _ in range(27)),
    rope_theta=10000.0,
    moe=MoEConfig(
        n_experts=64, top_k=6, d_expert=1408,
        n_shared_experts=2, d_shared=2816, renorm_gates=False,
    ),
).validate()


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=192, vocab_size=256,
        layer_pattern=(LayerSpec("attn", "dense"),)
        + tuple(LayerSpec("attn", "moe") for _ in range(2)),
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=32,
                      n_shared_experts=2, d_shared=64, renorm_gates=False,
                      capacity_factor=2.0),
    ).validate()
