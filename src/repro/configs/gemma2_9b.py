"""gemma2-9b [dense] — local/global alternating attention with logit softcaps.

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000 [arXiv:2408.00118].
Window 4096 on local layers; attn softcap 50, final softcap 30; sandwich
(post) norms; GeGLU; tied + scaled embeddings; head_dim 256. Global layers
are full attention => long_500k skipped.
"""
import dataclasses

from repro.configs.base import ArchConfig, LayerSpec, repeat_pattern

_UNIT = (LayerSpec("local_attn", "dense"), LayerSpec("attn", "dense"))

CONFIG = ArchConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    layer_pattern=repeat_pattern(_UNIT, 42),
    attn_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norms=True,
    act="gelu_tanh",
    tie_embeddings=True,
    scale_embeddings=True,
    rope_theta=10000.0,
).validate()


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=192, vocab_size=256, attn_window=16,
        layer_pattern=repeat_pattern(_UNIT, 4),
    ).validate()
