"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention.

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000 [arXiv:2401.16818].
SWA window 4096 on every layer => subquadratic, long_500k runs (ring cache).
"""
import dataclasses

from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab_size=32000,
    layer_pattern=tuple(LayerSpec("local_attn", "dense") for _ in range(24)),
    attn_window=4096,
    rope_theta=10000.0,
    subquadratic=True,
).validate()


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=192, vocab_size=256, attn_window=16,
        layer_pattern=tuple(LayerSpec("local_attn", "dense") for _ in range(2)),
    ).validate()
