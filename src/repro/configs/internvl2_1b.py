"""internvl2-1b [vlm] — Qwen2-0.5B LM backbone + InternViT frontend stub.

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655 [arXiv:2404.16821].
The vision frontend is a STUB per the task spec: input_specs provide
precomputed patch embeddings [B, 256, 1024] that a linear projector maps
into the LM embedding space. Heads pad 14 -> 16 for TP=16 (DESIGN.md §5).
"""
import dataclasses

from repro.configs.base import ArchConfig, EncoderConfig

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1000000.0,
    encoder=EncoderConfig(n_layers=0, n_heads=0, seq_len=256, kind="vision"),
).validate()


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=192, vocab_size=256,
        encoder=EncoderConfig(n_layers=0, n_heads=0, seq_len=8, kind="vision"),
    ).validate()
