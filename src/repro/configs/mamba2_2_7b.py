"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free.

64L d_model=2560 (attn-free) vocab=50280, ssm_state=128 [arXiv:2405.21060].
d_inner = 2*2560 = 5120, head_dim 64 => 80 SSD heads. No FF (the SSD block
is the whole layer). subquadratic => long_500k runs (constant state).
"""
import dataclasses

from repro.configs.base import ArchConfig, LayerSpec, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    layer_pattern=tuple(LayerSpec("ssd", None) for _ in range(64)),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4),
    subquadratic=True,
).validate()


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=3, d_model=64, vocab_size=256,
        layer_pattern=tuple(LayerSpec("ssd", None) for _ in range(3)),
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_width=4),
    ).validate()
