"""qwen2-1.5b [dense] — GQA with QKV bias, tied embeddings.

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936 [arXiv:2407.10671].
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1000000.0,
).validate()


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=192, vocab_size=256,
    ).validate()
