"""qwen3-moe-235b-a22b [moe] — 128 experts, top-8, q/k norms.

94L d_model=4096 64H (GQA kv=4) expert d_ff=1536 vocab=151936
[hf:Qwen/Qwen3-30B-A3B scaled family]. All layers MoE, no shared experts,
normalized top-k gates, head_dim 128, RoPE theta 1e6.
"""
import dataclasses

from repro.configs.base import ArchConfig, LayerSpec, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=0,
    vocab_size=151936,
    layer_pattern=tuple(LayerSpec("attn", "moe") for _ in range(94)),
    use_qk_norm=True,
    rope_theta=1000000.0,
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=1536, renorm_gates=True),
).validate()


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        vocab_size=256,
        layer_pattern=tuple(LayerSpec("attn", "moe") for _ in range(3)),
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, renorm_gates=True,
                      capacity_factor=2.0),
    ).validate()
