"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, Griffin 1:2 pattern.

38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000 [arXiv:2402.19427].
Pattern unit (rglru, rglru, local_attn); 38 layers = 12 full units + 2
trailing rglru layers. Local attention window 2048. Gemma-family details:
GeGLU MLP, RMSNorm, tied + scaled embeddings. subquadratic => long_500k runs.
"""
import dataclasses

from repro.configs.base import (
    ArchConfig, LayerSpec, RecurrentConfig, repeat_pattern,
)

_UNIT = (
    LayerSpec("rglru", "dense"),
    LayerSpec("rglru", "dense"),
    LayerSpec("local_attn", "dense"),
)

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    layer_pattern=repeat_pattern(_UNIT, 38),
    attn_window=2048,
    act="gelu_tanh",
    tie_embeddings=True,
    scale_embeddings=True,
    rope_theta=10000.0,
    recurrent=RecurrentConfig(lru_width=4096, conv_width=4, c=8.0),
    subquadratic=True,
).validate()


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=256, attn_window=16,
        layer_pattern=repeat_pattern(_UNIT, 5),
        recurrent=RecurrentConfig(lru_width=64, conv_width=4, c=8.0),
    ).validate()
