"""The four assigned input shapes. Each (arch x shape) cell is a dry-run unit.

``train_*`` lowers train_step; ``prefill_*`` lowers the serve prefill;
``decode_*``/``long_*`` lower serve_step (one new token against a KV cache of
``seq_len``). ``long_500k`` requires sub-quadratic attention
(cfg.subquadratic); pure full-attention archs skip it (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def get_shape(name: str) -> ShapeSpec:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; known: {[s.name for s in SHAPES]}")


def applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment rules."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, (
            f"{cfg.name} has full-attention layers; 500k-KV decode is "
            "quadratic-cost — skipped per shape definition (DESIGN.md §5)"
        )
    return True, ""
