"""whisper-large-v3 [audio] — encoder-decoder backbone, conv frontend stub.

32L (decoder; +32 encoder) d_model=1280 20H (MHA kv=20) d_ff=5120
vocab=51866 [arXiv:2212.04356]. The conv1d audio frontend is a STUB:
input_specs provide precomputed frame embeddings [B, 1500, 1280]. Decoder
positions are configurable (the assigned decode shapes exercise the decoder
beyond whisper's 448-token deployment limit; backbone-only per spec).
Heads pad 20 -> 32 for TP=16 (DESIGN.md §5). long_500k skipped (full attn).
"""
import dataclasses

from repro.configs.base import ArchConfig, EncoderConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    norm_kind="layernorm",
    norm_eps=1e-5,
    act="gelu",
    tie_embeddings=True,
    encoder=EncoderConfig(n_layers=32, n_heads=20, seq_len=1500, kind="audio"),
).validate()


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256,
        encoder=EncoderConfig(n_layers=2, n_heads=4, seq_len=24, kind="audio"),
    ).validate()
