"""Core: hardware-model-aware tiling — the paper's contribution, generalized.

Public surface:
    HardwareModel descriptors  (core.hardware)
    TileShape / constraints    (core.tiling)
    analytic cost model        (core.cost_model)
    Autotuner                  (core.autotuner)
    TilingPolicy               (core.policy)
    kernel registry            (core.registry)
    AOT tile plans             (core.plans)
"""
from repro.core.autotuner import Autotuner, SweepResult
from repro.core.cost_model import CostBreakdown, TileWorkload, estimate
from repro.core.hardware import (
    GEFORCE_8800GTS,
    GTX260,
    PRODUCTION_TARGET,
    REGISTRY as HARDWARE_REGISTRY,
    TPU_V4,
    TPU_V5E,
    TPU_V5P,
    TPU_V6E,
    HardwareModel,
)
from repro.core.plans import (
    PLAN_SCHEMA_VERSION,
    PlanEntry,
    PlanError,
    PlanResolution,
    PlanSchemaError,
    PlanTransferWarning,
    PlanVersionWarning,
    TilePlan,
    compile_plan,
)
from repro.core.policy import TilingPolicy, default_policy, set_default_policy
from repro.core.tiling import TileConstraints, TileShape, cdiv, round_up

__all__ = [
    "Autotuner", "SweepResult", "CostBreakdown", "TileWorkload", "estimate",
    "HardwareModel", "HARDWARE_REGISTRY", "PRODUCTION_TARGET",
    "TPU_V4", "TPU_V5E", "TPU_V5P", "TPU_V6E", "GTX260", "GEFORCE_8800GTS",
    "TilingPolicy", "default_policy", "set_default_policy",
    "TileConstraints", "TileShape", "cdiv", "round_up",
    "PLAN_SCHEMA_VERSION", "PlanEntry", "PlanError", "PlanResolution",
    "PlanSchemaError", "PlanTransferWarning", "PlanVersionWarning",
    "TilePlan", "compile_plan",
]
