"""Tile autotuner — the paper's sweep methodology as a framework service.

The paper's experiment: for each GPU model, run the kernel over a grid of
tile dims, pick the fastest, observe that optima differ across models. This
module does exactly that, per :class:`~repro.core.hardware.HardwareModel`:

* ``sweep`` evaluates every legal tile (via the registry's constraint system)
  with the analytic cost model — and, when a ``measure_fn`` is supplied (real
  TPU present), with wall-clock timing, which takes precedence.
* results are cached persistently keyed by
  ``(kernel, problem, dtype, hardware)`` so tuning amortizes across runs, and
  the cache doubles as the cross-model comparison table of the paper's Fig. 3.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
from typing import TYPE_CHECKING, Callable, Dict, List, Mapping, Optional, Tuple

from repro.core import registry
from repro.core.cost_model import CostBreakdown, estimate
from repro.core.hardware import HardwareModel
from repro.core.tiling import TileShape, enumerate_tiles

if TYPE_CHECKING:  # avoid a cycle: plans.compile_entry uses Autotuner
    from repro.core.plans import TilePlan

MeasureFn = Callable[[TileShape], float]  # returns seconds per call


@dataclasses.dataclass(frozen=True)
class SweepEntry:
    tile: TileShape
    cost: CostBreakdown
    measured_s: Optional[float] = None

    @property
    def score(self) -> float:
        return self.measured_s if self.measured_s is not None else self.cost.total_s


@dataclasses.dataclass
class SweepResult:
    kernel: str
    hardware: str
    dtype: str
    problem: Mapping[str, int]
    entries: List[SweepEntry]

    @property
    def best(self) -> SweepEntry:
        # Wall-clock measurements outrank model estimates: never compare a
        # measured time against an (optimistic) analytic one directly.
        measured = [e for e in self.entries if e.measured_s is not None]
        pool = measured if measured else self.entries
        return min(pool, key=lambda e: e.score)

    def sensitivity(self) -> float:
        """Spread of the sweep: worst/best ratio over finite entries.

        The paper's §IV.C principle predicts this shrinks as core count
        grows; `benchmarks/bench_sensitivity.py` asserts exactly that.
        """
        finite = [e.score for e in self.entries if e.score != float("inf")]
        if not finite:
            return float("inf")
        return max(finite) / min(finite)


class Autotuner:
    """Sweep + select + persistent cache.

    With ``plans`` (a compiled :class:`~repro.core.plans.TilePlan`), the
    resolution order of :meth:`best_tile` becomes cache -> plan lookup
    (exact / nearest-shape / cross-hardware, see ``TilePlan.resolve``) ->
    sweep, so pre-compiled fleets never sweep on the hot path.
    """

    def __init__(self, cache_path: Optional[str] = None,
                 plans: Optional["TilePlan"] = None):
        self._cache_path = cache_path
        self._cache: Dict[str, dict] = {}
        self._lock = threading.Lock()
        self.plans = plans
        self.sweep_count = 0  # observability: hot paths assert this stays 0
        if cache_path and os.path.exists(cache_path):
            try:
                with open(cache_path) as f:
                    self._cache = json.load(f)
            except (json.JSONDecodeError, OSError):
                self._cache = {}

    @staticmethod
    def _key(kernel: str, problem: Mapping[str, int], dtype: str, hw: str) -> str:
        pk = ",".join(f"{k}={v}" for k, v in sorted(problem.items()))
        return f"{kernel}|{pk}|{dtype}|{hw}"

    def sweep(
        self,
        kernel: str,
        problem: Mapping[str, int],
        dtype: str,
        hw: HardwareModel,
        measure_fn: Optional[MeasureFn] = None,
        max_candidates: int = 512,
        measure_top_k: int = 8,
        tiles: Optional[List[TileShape]] = None,
    ) -> SweepResult:
        """Sweep ``tiles`` (or the auto-enumerated legal space) on ``hw``.

        Passing ``tiles`` explicitly pins the candidate set — used by the
        paper-reproduction benchmarks to sweep the paper's own Fig. 3 axis.
        """
        self.sweep_count += 1
        spec = registry.get(kernel)
        if tiles is None:
            constraints = spec.constraints(problem)
            tiles = enumerate_tiles(
                constraints, hw, dtype,
                vmem_bytes_fn=lambda t: spec.vmem_bytes(t, problem, dtype),
                max_candidates=max_candidates,
            )
        if not tiles:
            raise ValueError(
                f"no legal tiles for {kernel} problem={dict(problem)} on {hw.name}"
            )
        entries = []
        for t in tiles:
            work = spec.workload(t, problem, dtype)
            cost = estimate(
                hw, work, spec.n_tiles(t, problem),
                vmem_bytes=spec.vmem_bytes(t, problem, dtype),
            )
            entries.append(SweepEntry(tile=t, cost=cost))
        # If real hardware timing is available, measure the analytically-best
        # top-k (the paper measured everything; we prune with the model first).
        if measure_fn is not None:
            entries.sort(key=lambda e: e.cost.total_s)
            timed = []
            for e in entries[:measure_top_k]:
                timed.append(
                    SweepEntry(e.tile, e.cost, measured_s=measure_fn(e.tile))
                )
            entries = timed + entries[measure_top_k:]
        return SweepResult(kernel, hw.name, dtype, dict(problem), entries)

    def best_tile(
        self,
        kernel: str,
        problem: Mapping[str, int],
        dtype: str,
        hw: HardwareModel,
        measure_fn: Optional[MeasureFn] = None,
    ) -> TileShape:
        key = self._key(kernel, problem, dtype, hw.name)
        with self._lock:
            hit = self._cache.get(key)
        if hit is not None:
            return TileShape(tuple(hit["tile"]))
        if self.plans is not None:
            res = self.plans.resolve(kernel, problem, dtype, hw)
            if res is not None:
                with self._lock:
                    self._cache[key] = {
                        "tile": list(res.tile.dims),
                        "score_s": res.score_s,
                        "dominant": res.entry.dominant,
                        "source": f"plan:{res.source}",
                    }
                    self._flush_locked()
                return res.tile
        result = self.sweep(kernel, problem, dtype, hw, measure_fn=measure_fn)
        best = result.best
        with self._lock:
            self._cache[key] = {
                "tile": list(best.tile.dims),
                "score_s": best.score,
                "dominant": best.cost.dominant(),
            }
            self._flush_locked()
        return best.tile

    def _flush_locked(self) -> None:
        if not self._cache_path:
            return
        # Approximate plan resolutions (nearest-shape clamps, cross-hardware
        # transfers) are provisional: never durable, whoever triggers the
        # flush, so a corrected plan artifact with an exact entry wins on
        # the next process start. Swept/measured results and exact plan hits
        # persist.
        durable = {
            k: v for k, v in self._cache.items()
            if v.get("source") in (None, "plan:exact")
        }
        tmp = self._cache_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(durable, f, indent=1, sort_keys=True)
        os.replace(tmp, self._cache_path)

    def cached(self) -> Dict[str, dict]:
        with self._lock:
            return dict(self._cache)
