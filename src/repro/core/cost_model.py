"""Analytic tile cost model — formalizing the paper's §IV reasoning.

The paper explains Fig. 3/4 with three effects, all of which are encoded here:

1. **Row-crossing cost** (their Fig. 4): a tile of height ``h`` issues ``h``
   strided row segments; crossing a row costs time that *grows with the image
   width* (their observation that scale 6/8/10 makes 32x4 dominant). We charge
   ``row_penalty = dma_row_latency * stride_bytes / DRAM_PAGE`` per crossing,
   consuming real bandwidth time (DRAM page switches), so wide tiles win at
   large widths.
2. **Occupancy** (their §III.B 32x16 example): blocks-per-SM is bounded by
   the hardware's active-thread ceiling; a tile of 512 threads fits twice on
   GTX260 (1024 active) but once on the 8800GTS (768) => utilization 2/3.
3. **Sensitivity vs core count** (their §IV.C): with more parallel units, a
   tile inefficiency divides over more hardware; the cost model reproduces
   this because waves = ceil(tiles / (num_sm * blocks_per_sm)) flattens as
   num_sm grows.

The TPU estimator replaces occupancy with VMEM-fit + DMA double-buffering and
warp padding with lane/sublane/MXU padding — see DESIGN.md §2 for the mapping.
Both estimators return a :class:`CostBreakdown` whose fields are the same
three roofline terms reported in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Tuple

from repro.core.hardware import HardwareModel
from repro.core.tiling import TileShape, cdiv, dtype_bytes

DRAM_PAGE_BYTES = 4096
GPU_MAX_BLOCKS_PER_SM = 8
GPU_WARP = 32


@dataclasses.dataclass(frozen=True)
class TileWorkload:
    """What one grid-step (one tile) of a kernel does.

    Kernels construct this from (problem, tile, dtype); the estimators below
    turn it into time. ``row_segments`` is the number of distinct strided
    segments the tile reads/writes (the paper's row crossings);
    ``row_stride_bytes`` is the stride between segments (image width * bpp).
    """

    flops: float                 # useful FLOPs in the tile
    hbm_bytes: float             # HBM bytes moved (reads + writes)
    row_segments: int            # strided segment count (paper Fig. 4)
    row_stride_bytes: float      # stride between segments
    threads: int = 0             # GPU: threads per block (= tile pixels)
    pad_waste: float = 1.0       # >=1: padded work / useful work (lane/MXU pad)


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    compute_s: float
    memory_s: float
    overhead_s: float
    utilization: float           # 0..1 parallel-unit utilization
    total_s: float

    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "overhead": self.overhead_s,
        }
        return max(terms, key=terms.get)


def row_penalty_s(hw: HardwareModel, stride_bytes: float) -> float:
    """Bandwidth-consuming cost of one strided row crossing.

    Scales with stride so wider final images amplify the penalty — this is
    the mechanism behind the paper's scale-6/8/10 observations.
    """
    pages = max(1.0, stride_bytes / DRAM_PAGE_BYTES)
    return hw.dma_row_latency * pages


def estimate_gpu(
    hw: HardwareModel,
    work: TileWorkload,
    n_tiles: int,
) -> CostBreakdown:
    """Throughput model for the paper's CUDA GPUs (reproduction only)."""
    if work.threads <= 0:
        raise ValueError("GPU estimate requires threads per block")
    if work.threads > hw.max_threads_per_block:
        return CostBreakdown(math.inf, math.inf, math.inf, 0.0, math.inf)

    # Occupancy: the paper's §III.B active-thread ceiling.
    blocks_per_sm = min(
        hw.max_active_threads // work.threads, GPU_MAX_BLOCKS_PER_SM
    )
    if blocks_per_sm == 0:
        return CostBreakdown(math.inf, math.inf, math.inf, 0.0, math.inf)
    active_threads = blocks_per_sm * work.threads
    utilization = active_threads / hw.max_active_threads

    # Little's law: DRAM bandwidth saturates only with enough resident
    # threads. This is the mechanism behind the paper's §III.B example —
    # a 32x16 tile (512 threads) fits twice on GTX260 (1024 active) but
    # once on the 8800GTS (768 ceiling), leaving bandwidth on the table.
    bw_frac = 1.0
    if hw.saturation_threads:
        bw_frac = min(1.0, active_threads / hw.saturation_threads)

    # Warp granularity: a 16-thread block still occupies whole warps.
    warp_pad = cdiv(work.threads, GPU_WARP) * GPU_WARP / work.threads

    # DRAM bank thrash: a tile touching more strided rows than there are
    # open banks re-opens pages superlinearly (tall tiles at large image
    # widths — the paper's Fig. 4 / scale 6-10 effect).
    segs = work.row_segments
    seg_eff = segs * max(1.0, segs / hw.dram_banks)

    sm_flops = hw.peak_flops_bf16 / hw.num_sm
    sm_bw = hw.hbm_bw / hw.num_sm

    per_block_compute = work.flops * warp_pad * work.pad_waste / sm_flops
    per_block_memory = (
        work.hbm_bytes / (sm_bw * bw_frac)
        + seg_eff * row_penalty_s(hw, work.row_stride_bytes)
    )

    # One resident set = blocks_per_sm blocks co-scheduled on an SM: compute
    # serializes on the cores, memory serializes on the SM's bandwidth share;
    # whichever is larger bounds the set (latency of the other is hidden).
    # Block dispatch (GigaThread) adds a small fixed cost per block, which is
    # why very small blocks lose even at full occupancy.
    set_compute = blocks_per_sm * per_block_compute
    set_memory = blocks_per_sm * per_block_memory
    set_time = max(set_compute, set_memory) + blocks_per_sm * hw.sched_overhead

    waves = cdiv(n_tiles, hw.num_sm * blocks_per_sm)
    total = waves * set_time + hw.launch_overhead
    frac = set_time if set_time > 0 else 1.0
    return CostBreakdown(
        compute_s=waves * set_compute,
        memory_s=waves * set_memory,
        overhead_s=hw.launch_overhead,
        utilization=utilization,
        total_s=total,
    )


def estimate_tpu(
    hw: HardwareModel,
    work: TileWorkload,
    n_tiles: int,
    vmem_bytes: float,
) -> CostBreakdown:
    """Pallas grid-step model: double-buffered DMA overlapping MXU compute.

    The analogue of GPU occupancy is whether the working set leaves room to
    double-buffer in VMEM; the analogue of warp padding is lane/sublane/MXU
    padding (``pad_waste``); the row-crossing term survives unchanged — a
    tile whose minor dim is narrower than the full row still issues one DMA
    descriptor per sublane row.
    """
    if vmem_bytes > hw.vmem_bytes:
        return CostBreakdown(math.inf, math.inf, math.inf, 0.0, math.inf)
    double_buffered = vmem_bytes <= hw.vmem_bytes * 0.5

    core_flops = hw.peak_flops_bf16 / hw.num_cores
    per_tile_compute = work.flops * work.pad_waste / core_flops
    per_tile_memory = (
        work.hbm_bytes / hw.hbm_bw
        + work.row_segments * row_penalty_s(hw, work.row_stride_bytes)
    )
    if double_buffered:
        per_tile = max(per_tile_compute, per_tile_memory)
        utilization = min(
            1.0,
            per_tile_compute / per_tile if per_tile > 0 else 1.0,
        )
    else:
        # No room to overlap: DMA and compute serialize.
        per_tile = per_tile_compute + per_tile_memory
        utilization = 0.5
    total = n_tiles * per_tile + hw.launch_overhead
    return CostBreakdown(
        compute_s=n_tiles * per_tile_compute,
        memory_s=n_tiles * per_tile_memory,
        overhead_s=hw.launch_overhead,
        utilization=utilization,
        total_s=total,
    )


def estimate(
    hw: HardwareModel,
    work: TileWorkload,
    n_tiles: int,
    vmem_bytes: float = 0.0,
) -> CostBreakdown:
    if hw.family == "gpu":
        return estimate_gpu(hw, work, n_tiles)
    return estimate_tpu(hw, work, n_tiles, vmem_bytes)
