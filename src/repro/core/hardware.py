"""Hardware model descriptors — the framework's analogue of the paper's Table I.

The paper's central observation is that tiling decisions must be made
relative to a *hardware descriptor* (their Table I: registers/SM, active
warps, active threads, SP count, SM count, memory). On TPU the relevant
descriptor fields are different (VMEM capacity, MXU geometry, lane/sublane
tiling, HBM and ICI bandwidth) but the role is identical: every tile-shape
decision in this framework is a function of ``(kernel, problem, HardwareModel)``.

We keep the paper's two GPUs as calibrated descriptors so the reproduction
benchmarks (Fig. 3, Fig. 4, the sensitivity principle) can be evaluated with
the paper's own hardware parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """A single accelerator model's performance-relevant parameters.

    TPU-oriented fields; the GPU entries (used only by the paper-reproduction
    cost model) reinterpret them as documented per-field.
    """

    name: str
    family: str                    # "tpu" | "gpu"
    # Compute ----------------------------------------------------------------
    peak_flops_bf16: float         # FLOP/s per chip (bf16 MXU; GPUs: fp32 MAD)
    num_cores: int                 # TensorCores per chip (GPUs: total SPs)
    mxu_dim: int                   # MXU systolic array dim (128); GPUs: warp size
    # Memory hierarchy -------------------------------------------------------
    hbm_bytes: int                 # device memory capacity
    hbm_bw: float                  # bytes/s HBM <-> chip
    vmem_bytes: int                # per-core fast scratch (VMEM); GPUs: shared mem/SM
    vmem_bw: float                 # bytes/s VMEM (modelled, >> hbm_bw)
    # Layout geometry --------------------------------------------------------
    lane_count: int                # minor-dim register tiling (128 on TPU; GPUs: coalesce width)
    sublane_fp32: int              # second-minor tiling for fp32 (8)
    sublane_bf16: int              # second-minor tiling for bf16 (16)
    # Interconnect -----------------------------------------------------------
    ici_bw_per_link: float         # bytes/s per ICI link
    ici_links: int                 # links per chip (torus degree)
    # Scheduling (GPU-only legacy fields, used by the paper reproduction) ----
    max_active_threads: int = 0    # per SM (paper Table I); 0 on TPU
    max_threads_per_block: int = 0 # 512 for cc<=1.3; 0 on TPU
    num_sm: int = 0                # streaming multiprocessors; 0 on TPU
    # Little's-law knob: resident threads/SM needed to saturate DRAM BW.
    saturation_threads: int = 0
    # DRAM banks: concurrently-open rows before page thrash sets in.
    dram_banks: int = 8
    # Per-block scheduling cost (GigaThread dispatch), seconds.
    sched_overhead: float = 0.0
    # Fixed overheads (calibrated, seconds) ----------------------------------
    dma_row_latency: float = 0.0   # cost of crossing a row (strided step) per tile row
    launch_overhead: float = 0.0   # per-grid-step fixed cost

    @property
    def sublane(self) -> Dict[str, int]:
        return {"float32": self.sublane_fp32, "bfloat16": self.sublane_bf16}

    def arithmetic_intensity_knee(self) -> float:
        """FLOP/byte at which the chip transitions memory- to compute-bound."""
        return self.peak_flops_bf16 / self.hbm_bw


# ---------------------------------------------------------------------------
# TPU generations (public spec-sheet numbers).
# ---------------------------------------------------------------------------

TPU_V4 = HardwareModel(
    name="tpu_v4", family="tpu",
    peak_flops_bf16=275e12, num_cores=2, mxu_dim=128,
    hbm_bytes=32 * 2**30, hbm_bw=1228e9,
    vmem_bytes=16 * 2**20, vmem_bw=20e12,
    lane_count=128, sublane_fp32=8, sublane_bf16=16,
    ici_bw_per_link=50e9, ici_links=6,
)

TPU_V5E = HardwareModel(
    name="tpu_v5e", family="tpu",
    peak_flops_bf16=197e12, num_cores=1, mxu_dim=128,
    hbm_bytes=16 * 2**30, hbm_bw=819e9,
    vmem_bytes=16 * 2**20, vmem_bw=20e12,
    lane_count=128, sublane_fp32=8, sublane_bf16=16,
    ici_bw_per_link=50e9, ici_links=4,
)

TPU_V5P = HardwareModel(
    name="tpu_v5p", family="tpu",
    peak_flops_bf16=459e12, num_cores=2, mxu_dim=128,
    hbm_bytes=95 * 2**30, hbm_bw=2765e9,
    vmem_bytes=16 * 2**20, vmem_bw=40e12,
    lane_count=128, sublane_fp32=8, sublane_bf16=16,
    ici_bw_per_link=100e9, ici_links=6,
)

TPU_V6E = HardwareModel(
    name="tpu_v6e", family="tpu",
    peak_flops_bf16=918e12, num_cores=1, mxu_dim=256,
    hbm_bytes=32 * 2**30, hbm_bw=1640e9,
    vmem_bytes=32 * 2**20, vmem_bw=40e12,
    lane_count=128, sublane_fp32=8, sublane_bf16=16,
    ici_bw_per_link=90e9, ici_links=4,
)

# ---------------------------------------------------------------------------
# The paper's two GPUs (Table I), calibrated for the Fig. 3 reproduction.
#
# peak_flops: SPs x clock x 2 (MAD) — GTX260: 192 x 1.242GHz x 2 = 477 GFLOP/s
#             8800GTS(320MB, G80): 96 x 1.2GHz x 2 = 230 GFLOP/s
# hbm_bw:     GTX260 448-bit GDDR3 ~111.9 GB/s; 8800GTS 320-bit ~64 GB/s
# dma_row_latency / launch_overhead are calibrated so the cost model
# reproduces Fig. 3's qualitative ordering (see benchmarks/bench_bilinear_fig3).
# ---------------------------------------------------------------------------

GTX260 = HardwareModel(
    name="gtx260", family="gpu",
    peak_flops_bf16=477e9, num_cores=192, mxu_dim=32,
    hbm_bytes=1 * 2**30, hbm_bw=111.9e9,
    vmem_bytes=16 * 2**10, vmem_bw=1.4e12,
    lane_count=32, sublane_fp32=1, sublane_bf16=1,
    ici_bw_per_link=0.0, ici_links=0,
    max_active_threads=1024, max_threads_per_block=512, num_sm=24,
    saturation_threads=512, dram_banks=16, sched_overhead=4.0e-7,
    dma_row_latency=2.0e-8, launch_overhead=3.0e-6,
)

GEFORCE_8800GTS = HardwareModel(
    name="geforce_8800gts", family="gpu",
    peak_flops_bf16=230e9, num_cores=96, mxu_dim=32,
    hbm_bytes=320 * 2**20, hbm_bw=64e9,
    vmem_bytes=16 * 2**10, vmem_bw=0.7e12,
    lane_count=32, sublane_fp32=1, sublane_bf16=1,
    ici_bw_per_link=0.0, ici_links=0,
    max_active_threads=768, max_threads_per_block=512, num_sm=12,
    saturation_threads=640, dram_banks=8, sched_overhead=5.0e-7,
    dma_row_latency=3.5e-8, launch_overhead=5.0e-6,
)


REGISTRY: Dict[str, HardwareModel] = {
    m.name: m
    for m in (TPU_V4, TPU_V5E, TPU_V5P, TPU_V6E, GTX260, GEFORCE_8800GTS)
}

# The roofline target for the multi-pod dry-run (per the task spec).
PRODUCTION_TARGET = TPU_V5E


def get(name: str) -> HardwareModel:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown hardware model {name!r}; known: {sorted(REGISTRY)}"
        ) from None
