"""Ahead-of-time tile plans: compile once per hardware fleet, resolve anywhere.

The paper's central result is that the best tile on one GPU model is not the
best on another — tuning is a per-hardware-model activity. The Autotuner
already does the per-model sweep, but lazily: the first request/step on a new
``(kernel, problem, dtype, hardware)`` cell pays the sweep on the hot path.
This module moves that cost ahead of time, the way "Comprehensive
Optimization of Parametric Kernels for GPUs" compiles parametric plans
offline and selects at run time:

* :func:`compile_plan` sweeps a set of ``(kernel, problem, dtype, hardware)``
  jobs and records, per cell, the best tile *and* the full sensitivity curve
  (every candidate's score), so downstream consumers can re-rank without
  re-sweeping.
* :class:`TilePlan` is the portable, schema-versioned artifact (JSON on
  disk). Loading validates the schema; a corrupt or stale artifact degrades
  to "no plan" rather than crashing the server.
* :meth:`TilePlan.resolve` is the run-time lookup with a three-step
  fallback order:

  1. **exact** — ``(kernel, problem, dtype, hardware)`` hit.
  2. **nearest_shape** — same kernel/dtype/hardware, nearest problem shape
     in log-space; the donor tile is clamped to the target problem and
     legality-checked.
  3. **cross_hardware** — the paper's Fig. 3 situation productized: a plan
     tuned on model A is transferred to model B by re-ranking the donor's
     candidate tiles with B's analytic cost model, and a
     :class:`PlanTransferWarning` is emitted because transferred optima are
     not trustworthy without re-measurement.
"""
from __future__ import annotations

import dataclasses
import json
import logging
import math
import os
import warnings
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple, Union

from repro.core import registry
from repro.core.cost_model import estimate
from repro.core.hardware import HardwareModel
from repro.core.hardware import get as get_hardware
from repro.core.tiling import TileShape

log = logging.getLogger("repro.plans")

# Bump on any change to the artifact layout or to the cell families an
# artifact is expected to cover. v1 -> v2: serving artifacts gained the
# ``packed_prefill`` step-packing cells (compile_plans --serve-buckets).
# v2 -> v3: artifacts may carry live-refinement provenance
# (``meta["refined_from"]`` / ``meta["measurements"]``, written by
# ``repro.serve.refine.PlanRefiner``) and measured per-cell entries whose
# scores came from shadow execution rather than the analytic model.
# Versions in COMPAT_SCHEMA_VERSIONS still load — their entry layout is
# forward-compatible — but emit :class:`PlanVersionWarning` so operators
# recompile (a v1 artifact cannot resolve pack widths, and neither v1 nor
# v2 carries refinement provenance). Anything else is rejected: a stale
# artifact must not silently misconfigure tiles.
PLAN_SCHEMA_VERSION = 3
COMPAT_SCHEMA_VERSIONS = (1, 2)


class PlanError(ValueError):
    """Base error for plan artifacts."""


class PlanSchemaError(PlanError):
    """Artifact exists but is not a valid plan (bad version / missing fields)."""


class PlanVersionWarning(UserWarning):
    """An artifact from an older (still-readable) schema version was loaded.

    The entries resolve fine, but the artifact predates cell families the
    current code expects (e.g. the packed_prefill serving cells), so those
    lookups fall back to heuristics — recompile with
    ``repro.launch.compile_plans`` to silence this.
    """


class PlanTransferWarning(UserWarning):
    """A tile tuned on one hardware model was transferred to another.

    The paper's cross-model comparison shows transferred optima can be far
    from the true optimum; the resolution re-ranks with the target's cost
    model, but consumers should re-tune on the real hardware when possible.
    """


def problem_key(problem: Mapping[str, int]) -> str:
    return ",".join(f"{k}={v}" for k, v in sorted(problem.items()))


def plan_key(kernel: str, problem: Mapping[str, int], dtype: str,
             hardware: str) -> str:
    # Same layout as Autotuner._key so the two caches stay interchangeable.
    return f"{kernel}|{problem_key(problem)}|{dtype}|{hardware}"


# ---------------------------------------------------------------------------
# Artifact entries.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlanEntry:
    """One compiled cell: the best tile plus its full sensitivity curve."""

    kernel: str
    hardware: str
    dtype: str
    problem: Tuple[Tuple[str, int], ...]      # sorted items (hashable)
    tile: TileShape
    score_s: float
    dominant: str                             # compute | memory | overhead
    sensitivity: float                        # worst/best over finite entries
    # ((dims...), score_s) ascending by score; [0] is the best tile.
    curve: Tuple[Tuple[Tuple[int, ...], float], ...] = ()

    @property
    def problem_dict(self) -> Dict[str, int]:
        return dict(self.problem)

    @property
    def key(self) -> str:
        return plan_key(self.kernel, self.problem_dict, self.dtype,
                        self.hardware)

    def to_dict(self) -> dict:
        return {
            "kernel": self.kernel,
            "hardware": self.hardware,
            "dtype": self.dtype,
            "problem": self.problem_dict,
            "tile": list(self.tile.dims),
            "score_s": self.score_s,
            "dominant": self.dominant,
            "sensitivity": self.sensitivity,
            "curve": [[list(dims), score] for dims, score in self.curve],
        }

    @staticmethod
    def from_dict(d: Mapping) -> "PlanEntry":
        if not isinstance(d, Mapping):
            raise PlanSchemaError(
                f"plan entry must be an object, got {type(d).__name__}")
        required = ("kernel", "hardware", "dtype", "problem", "tile",
                    "score_s")
        for field in required:
            if field not in d:
                raise PlanSchemaError(f"plan entry missing field {field!r}")
        problem = d["problem"]
        if (not isinstance(problem, Mapping)
                or not all(isinstance(v, int) for v in problem.values())):
            raise PlanSchemaError(f"bad problem in plan entry: {problem!r}")
        tile = d["tile"]
        if (not isinstance(tile, (list, tuple)) or not tile
                or not all(isinstance(x, int) and x > 0 for x in tile)):
            raise PlanSchemaError(f"bad tile in plan entry: {tile!r}")
        try:
            curve = []
            for point in d.get("curve", ()):
                dims, score = point
                curve.append((tuple(int(x) for x in dims), float(score)))
            return PlanEntry(
                kernel=str(d["kernel"]),
                hardware=str(d["hardware"]),
                dtype=str(d["dtype"]),
                problem=tuple(sorted(problem.items())),
                tile=TileShape(tuple(int(x) for x in tile)),
                score_s=float(d["score_s"]),
                dominant=str(d.get("dominant", "")),
                sensitivity=float(d.get("sensitivity", 1.0)),
                curve=tuple(curve),
            )
        except (TypeError, ValueError) as e:
            # Field coercion failed: a malformed artifact must surface as a
            # schema error so load_or_none degrades instead of crashing.
            raise PlanSchemaError(f"malformed plan entry: {e}") from e


@dataclasses.dataclass(frozen=True)
class PlanResolution:
    """How a tile request was satisfied by the plan store."""

    tile: TileShape
    source: str                    # exact | nearest_shape | cross_hardware
    entry: PlanEntry               # the donor entry
    score_s: float                 # (re-)estimated score on the target hw
    distance: float = 0.0          # problem-shape distance (0 for exact)
    donor_hardware: Optional[str] = None   # set for cross_hardware


# ---------------------------------------------------------------------------
# Resolution helpers.
# ---------------------------------------------------------------------------

def _shape_distance(a: Mapping[str, int], b: Mapping[str, int]) -> Optional[float]:
    """Log-space L1 distance between two problems; None if incomparable."""
    if set(a) != set(b):
        return None
    return sum(
        abs(math.log2(max(a[k], 1) / max(b[k], 1))) for k in a
    )


def _fit_tile(tile: TileShape, kernel: str, problem: Mapping[str, int],
              dtype: str, hw: HardwareModel) -> Optional[TileShape]:
    """Clamp a donor tile to the target problem and legality-check it."""
    try:
        spec = registry.get(kernel)
    except KeyError:
        return tile  # unknown kernel: trust the donor dims as-is
    constraints = spec.constraints(problem)
    if len(tile) != constraints.rank:
        return None
    fitted = TileShape(tuple(
        min(d, m) for d, m in zip(tile.dims, constraints.max_dims)
    ))
    budget = hw.vmem_bytes * constraints.vmem_fraction
    if spec.vmem_bytes(fitted, problem, dtype) > budget:
        return None
    return fitted


def _rescore(kernel: str, tile: TileShape, problem: Mapping[str, int],
             dtype: str, hw: HardwareModel) -> float:
    """Cost-model score of a tile on a (possibly different) hardware model."""
    try:
        spec = registry.get(kernel)
        cost = estimate(
            hw, spec.workload(tile, problem, dtype), spec.n_tiles(tile, problem),
            vmem_bytes=spec.vmem_bytes(tile, problem, dtype),
        )
        return cost.total_s
    except (KeyError, ValueError):
        return math.inf


def score_tile(kernel: str, tile: TileShape, problem: Mapping[str, int],
               dtype: str, hw: HardwareModel) -> float:
    """Public cost-model score of one tile on one hardware model (seconds).

    Used by consumers that need a comparable score for cells the plan could
    not resolve (e.g. the fleet router pricing a heuristic-default tile);
    returns +inf when the kernel is unknown or the tile is illegal.
    """
    return _rescore(kernel, tile, problem, dtype, hw)


# ---------------------------------------------------------------------------
# The portable plan artifact.
# ---------------------------------------------------------------------------

class TilePlan:
    """A set of compiled :class:`PlanEntry` cells plus artifact metadata."""

    def __init__(self, entries: Iterable[PlanEntry] = (),
                 meta: Optional[Mapping] = None):
        self._entries: Dict[str, PlanEntry] = {}
        self.meta: Dict = dict(meta or {})
        for e in entries:
            self.add(e)

    # -- container ----------------------------------------------------------
    def add(self, entry: PlanEntry) -> None:
        self._entries[entry.key] = entry

    def entries(self) -> List[PlanEntry]:
        return list(self._entries.values())

    def __len__(self) -> int:
        return len(self._entries)

    def kernels(self) -> List[str]:
        return sorted({e.kernel for e in self._entries.values()})

    def hardware_names(self) -> List[str]:
        return sorted({e.hardware for e in self._entries.values()})

    # -- lookup -------------------------------------------------------------
    def lookup(self, kernel: str, problem: Mapping[str, int], dtype: str,
               hardware: str) -> Optional[PlanEntry]:
        return self._entries.get(plan_key(kernel, problem, dtype, hardware))

    def resolve(
        self,
        kernel: str,
        problem: Mapping[str, int],
        dtype: str,
        hw: Union[HardwareModel, str],
        allow_nearest: bool = True,
        allow_transfer: bool = True,
        transfer_candidates: int = 8,
    ) -> Optional[PlanResolution]:
        """Lookup-then-fallback tile resolution. Never sweeps.

        Order: exact hit -> nearest problem shape on the same hardware ->
        cross-hardware transfer re-ranked with the target's cost model (with
        a :class:`PlanTransferWarning`). Returns None when the plan has
        nothing usable — callers fall back to heuristics or a sweep.
        """
        hw_model = get_hardware(hw) if isinstance(hw, str) else hw
        problem = dict(problem)

        entry = self.lookup(kernel, problem, dtype, hw_model.name)
        if entry is not None:
            return PlanResolution(entry.tile, "exact", entry, entry.score_s)

        pool = [e for e in self._entries.values()
                if e.kernel == kernel and e.dtype == dtype]

        if allow_nearest:
            res = self._nearest_shape(pool, kernel, problem, dtype, hw_model)
            if res is not None:
                return res

        if allow_transfer:
            res = self._transfer(pool, kernel, problem, dtype, hw_model,
                                 transfer_candidates)
            if res is not None:
                return res
        return None

    def _nearest_shape(self, pool, kernel, problem, dtype,
                       hw: HardwareModel) -> Optional[PlanResolution]:
        ranked = []
        for e in pool:
            if e.hardware != hw.name:
                continue
            dist = _shape_distance(e.problem_dict, problem)
            if dist is not None:
                ranked.append((dist, e.key, e))
        for dist, _, e in sorted(ranked):
            # Walk the donor's curve best-first until a tile fits the target.
            for dims, _score in ((tuple(e.tile.dims), e.score_s), *e.curve):
                tile = _fit_tile(TileShape(tuple(dims)), kernel, problem,
                                 dtype, hw)
                if tile is None:
                    continue
                score = _rescore(kernel, tile, problem, dtype, hw)
                if math.isfinite(score):
                    log.info(
                        "plan %s/%s: nearest-shape hit from %s (distance %.2f)",
                        kernel, hw.name, problem_key(e.problem_dict), dist,
                    )
                    return PlanResolution(tile, "nearest_shape", e, score,
                                          distance=dist)
        return None

    def _transfer(self, pool, kernel, problem, dtype, hw: HardwareModel,
                  transfer_candidates: int) -> Optional[PlanResolution]:
        pk = problem_key(problem)
        donors = [e for e in pool if e.hardware != hw.name]
        exact_problem = [e for e in donors
                         if problem_key(e.problem_dict) == pk]
        if exact_problem:
            ranked = [(0.0, e.key, e) for e in exact_problem]
        else:
            ranked = []
            for e in donors:
                dist = _shape_distance(e.problem_dict, problem)
                if dist is not None:
                    ranked.append((dist, e.key, e))
        ranked.sort()
        min_dist = ranked[0][0] if ranked else 0.0
        best: Optional[Tuple[float, TileShape, PlanEntry, float]] = None
        for dist, _, e in ranked:
            if best is not None and dist > min_dist:
                # All equally-near donors have been scored; don't dilute the
                # re-rank with farther-away problem shapes.
                break
            # Re-rank the donor's top candidates with the TARGET's cost
            # model — the donor's ordering is exactly what the paper shows
            # cannot be trusted across models.
            candidates = ((tuple(e.tile.dims), e.score_s),
                          *e.curve[:transfer_candidates])
            for dims, _score in candidates:
                tile = _fit_tile(TileShape(tuple(dims)), kernel, problem,
                                 dtype, hw)
                if tile is None:
                    continue
                score = _rescore(kernel, tile, problem, dtype, hw)
                if math.isfinite(score) and (best is None or score < best[0]):
                    best = (score, tile, e, dist)
        if best is None:
            return None
        score, tile, entry, dist = best
        msg = (
            f"tile plan for {kernel} ({problem_key(problem)}, {dtype}) "
            f"transferred from {entry.hardware} to {hw.name}: tile {tile} "
            f"re-ranked with the {hw.name} cost model. Per-model optima are "
            f"not portable (paper Fig. 3) — re-tune on {hw.name} to remove "
            f"this warning."
        )
        warnings.warn(PlanTransferWarning(msg), stacklevel=3)
        log.warning("%s", msg)
        return PlanResolution(tile, "cross_hardware", entry, score,
                              distance=dist, donor_hardware=entry.hardware)

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "schema_version": PLAN_SCHEMA_VERSION,
            "meta": self.meta,
            "entries": [e.to_dict() for e in self._entries.values()],
        }

    @classmethod
    def from_dict(cls, d: Mapping) -> "TilePlan":
        if not isinstance(d, Mapping):
            raise PlanSchemaError(f"plan artifact must be an object, got "
                                  f"{type(d).__name__}")
        version = d.get("schema_version")
        if version in COMPAT_SCHEMA_VERSIONS:
            msg = (
                f"loading plan artifact with old schema version {version} "
                f"(current {PLAN_SCHEMA_VERSION}): entries resolve, but "
                f"features added since (packed_prefill serving cells in v2, "
                f"refinement provenance in v3) are missing and degrade to "
                f"heuristics — recompile with repro.launch.compile_plans"
            )
            warnings.warn(PlanVersionWarning(msg), stacklevel=3)
            log.warning("%s", msg)
        elif version != PLAN_SCHEMA_VERSION:
            raise PlanSchemaError(
                f"plan schema version {version!r} unsupported "
                f"(expected {PLAN_SCHEMA_VERSION}, compat "
                f"{COMPAT_SCHEMA_VERSIONS}); recompile with "
                f"repro.launch.compile_plans"
            )
        entries = d.get("entries")
        if not isinstance(entries, list):
            raise PlanSchemaError("plan artifact missing 'entries' list")
        return cls(entries=[PlanEntry.from_dict(e) for e in entries],
                   meta=d.get("meta") or {})

    def save(self, path: str) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=True)
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "TilePlan":
        """Load and validate; raises PlanError on any problem."""
        try:
            with open(path) as f:
                data = json.load(f)
        except OSError as e:
            raise PlanError(f"cannot read plan artifact {path}: {e}") from e
        except json.JSONDecodeError as e:
            raise PlanSchemaError(
                f"plan artifact {path} is not valid JSON: {e}") from e
        return cls.from_dict(data)

    @classmethod
    def load_or_none(cls, path: Optional[str]) -> Optional["TilePlan"]:
        """Corrupt-file-tolerant load: log and return None instead of raising."""
        if not path:
            return None
        try:
            return cls.load(path)
        except PlanError as e:
            log.warning("ignoring unusable tile-plan artifact %s: %s", path, e)
            return None


# ---------------------------------------------------------------------------
# Compilation (the ahead-of-time sweep).
# ---------------------------------------------------------------------------

# (kernel, problem, dtype, hardware) — one cell to compile.
PlanJob = Tuple[str, Mapping[str, int], str, HardwareModel]


def compile_entry(
    kernel: str,
    problem: Mapping[str, int],
    dtype: str,
    hw: HardwareModel,
    autotuner=None,
    max_candidates: int = 256,
    curve_cap: Optional[int] = None,
    measure_fn=None,
) -> PlanEntry:
    """Sweep one cell and package the result as a :class:`PlanEntry`.

    ``measure_fn`` (tile -> seconds, see ``launch.measure``) adds wall-clock
    timing of the analytically-best candidates; measured scores outrank
    analytic ones in the sweep's ``best`` selection.
    """
    if autotuner is None:
        from repro.core.autotuner import Autotuner
        autotuner = Autotuner()
    result = autotuner.sweep(kernel, problem, dtype, hw,
                             max_candidates=max_candidates,
                             measure_fn=measure_fn)
    best = result.best
    if not math.isfinite(best.score):
        raise ValueError(
            f"no feasible tile for {kernel} {problem_key(problem)} on {hw.name}"
        )
    curve = sorted(
        ((tuple(e.tile.dims), e.score) for e in result.entries
         if math.isfinite(e.score)),
        key=lambda p: p[1],
    )
    if curve_cap is not None:
        curve = curve[:curve_cap]
    return PlanEntry(
        kernel=kernel,
        hardware=hw.name,
        dtype=dtype,
        problem=tuple(sorted(dict(problem).items())),
        tile=best.tile,
        score_s=best.score,
        dominant=best.cost.dominant(),
        sensitivity=result.sensitivity(),
        curve=tuple(curve),
    )


def compile_plan(
    jobs: Iterable[PlanJob],
    autotuner=None,
    max_candidates: int = 256,
    curve_cap: Optional[int] = None,
    meta: Optional[Mapping] = None,
    measure_fn_factory=None,
) -> TilePlan:
    """Compile every job into a :class:`TilePlan`.

    Infeasible cells (e.g. a TPU kernel paired with a GPU descriptor that
    cannot model it) are skipped with a log line rather than aborting the
    whole compile. ``measure_fn_factory(kernel, problem, dtype, hw)`` may
    return a wall-clock MeasureFn per cell (or None for analytic) — see
    ``launch.measure.make_measure_fn``.
    """
    plan = TilePlan(meta=meta)
    skipped = 0
    measured = 0
    for kernel, problem, dtype, hw in jobs:
        measure_fn = (measure_fn_factory(kernel, problem, dtype, hw)
                      if measure_fn_factory is not None else None)
        measured += measure_fn is not None
        try:
            entry = compile_entry(kernel, problem, dtype, hw,
                                  autotuner=autotuner,
                                  max_candidates=max_candidates,
                                  curve_cap=curve_cap,
                                  measure_fn=measure_fn)
        except (ValueError, KeyError) as e:
            skipped += 1
            log.info("plan compile: skipping %s on %s: %s", kernel, hw.name, e)
            continue
        plan.add(entry)
    plan.meta["kernels"] = plan.kernels()
    plan.meta["hardware"] = plan.hardware_names()
    plan.meta["skipped_jobs"] = skipped
    if measure_fn_factory is not None:
        plan.meta["measured_jobs"] = measured
    return plan
