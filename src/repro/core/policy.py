"""TilingPolicy — how the framework picks tiles at model-build time.

Three modes, all grounded in the paper:

* ``heuristic``  — the "32x4 principle" as a default: maximize the minor
  (lane-contiguous) tile dimension first, then grow the second-minor until
  the VMEM budget binds. Zero-cost, no sweep.
* ``tuned``      — per-hardware-model autotune (the paper's per-GPU sweep),
  cached persistently.
* ``robust``     — the paper's §V recommendation: pick the tile minimizing
  the *worst-case* cost across a fleet of hardware models ("consider more
  about the performance on the worst-case GPU").

With ``plans`` attached (a compiled :class:`~repro.core.plans.TilePlan`):
``heuristic`` consults the plan before falling back to the default tile;
``tuned`` delegates to the autotuner, whose resolution order is already
cache -> plan -> sweep (an exact, possibly hardware-measured cache entry
must outrank an approximate plan resolution); ``robust`` ignores plans —
its contract is the fleet-wide worst-case minimum, which no
single-hardware plan entry can honor.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence

from repro.core import registry
from repro.core.autotuner import Autotuner
from repro.core.cost_model import estimate
from repro.core.hardware import PRODUCTION_TARGET, HardwareModel
from repro.core.plans import TilePlan
from repro.core.tiling import TileShape, enumerate_tiles


@dataclasses.dataclass
class TilingPolicy:
    mode: str = "heuristic"                  # heuristic | tuned | robust
    hardware: HardwareModel = PRODUCTION_TARGET
    fleet: Sequence[HardwareModel] = ()      # for robust mode
    autotuner: Optional[Autotuner] = None
    plans: Optional[TilePlan] = None         # compiled AOT plans, tried first

    def __post_init__(self):
        if self.mode not in ("heuristic", "tuned", "robust"):
            raise ValueError(f"unknown policy mode {self.mode!r}")
        if self.mode == "tuned":
            if self.autotuner is None:
                self.autotuner = Autotuner(plans=self.plans)
            elif self.autotuner.plans is None:
                self.autotuner.plans = self.plans
        if self.mode == "robust" and not self.fleet:
            raise ValueError("robust mode requires a hardware fleet")

    def tile_for(
        self, kernel: str, problem: Mapping[str, int], dtype: str = "bfloat16"
    ) -> TileShape:
        spec = registry.get(kernel)
        if self.mode == "heuristic":
            if self.plans is not None:
                res = self.plans.resolve(kernel, problem, dtype,
                                         self.hardware)
                if res is not None:
                    return res.tile
            return spec.default_tile(problem, dtype)
        if self.mode == "tuned":
            # The autotuner already resolves cache -> plan -> sweep; going
            # through it keeps an exact (possibly measured) cache entry from
            # being shadowed by an approximate plan resolution.
            return self.autotuner.best_tile(kernel, problem, dtype, self.hardware)
        # Robust mode ignores plans: a single-hardware plan entry (or a
        # transfer) would silently replace the fleet worst-case minimum.
        return self._robust_tile(spec, problem, dtype)

    def _robust_tile(self, spec, problem, dtype) -> TileShape:
        # Candidate set: union of legal tiles on every fleet member (a tile
        # must be legal everywhere to be a fleet-wide default).
        per_hw = []
        for hw in self.fleet:
            constraints = spec.constraints(problem)
            tiles = enumerate_tiles(
                constraints, hw, dtype,
                vmem_bytes_fn=lambda t: spec.vmem_bytes(t, problem, dtype),
            )
            per_hw.append(set(tiles))
        common = set.intersection(*per_hw) if per_hw else set()
        if not common:
            raise ValueError("no tile legal on every fleet member")
        best_tile, best_worst = None, float("inf")
        for t in sorted(common):
            worst = 0.0
            for hw in self.fleet:
                work = spec.workload(t, problem, dtype)
                cost = estimate(
                    hw, work, spec.n_tiles(t, problem),
                    vmem_bytes=spec.vmem_bytes(t, problem, dtype),
                )
                worst = max(worst, cost.total_s)
            if worst < best_worst:
                best_worst, best_tile = worst, t
        return best_tile


# Module-level default policy used by model code; tests/benchmarks may swap it.
_DEFAULT = TilingPolicy()


def default_policy() -> TilingPolicy:
    return _DEFAULT


def set_default_policy(policy: TilingPolicy) -> None:
    global _DEFAULT
    _DEFAULT = policy
