"""Kernel registry: each Pallas kernel declares its tunable tile space here.

This is the integration point that turns the paper's manual experiment into
framework infrastructure — a kernel registers (a) how to build its legal tile
constraints for a problem, (b) the VMEM working set of a candidate tile, and
(c) the per-tile workload for the cost model. The autotuner and TilingPolicy
are generic over this interface.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Sequence, Tuple

from repro.core.cost_model import TileWorkload
from repro.core.tiling import TileConstraints, TileShape


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Declaration of one kernel's tunable space.

    problem: a kernel-defined mapping of dim names -> int (e.g. {"m":..,
    "k":.., "n":..} for matmul, {"out_h":.., "out_w":.., "scale":..} for
    bilinear). All callables are pure.
    """

    name: str
    constraints: Callable[[Mapping[str, int]], TileConstraints]
    vmem_bytes: Callable[[TileShape, Mapping[str, int], str], float]
    workload: Callable[[TileShape, Mapping[str, int], str], TileWorkload]
    n_tiles: Callable[[TileShape, Mapping[str, int]], int]
    default_tile: Callable[[Mapping[str, int], str], TileShape]


_REGISTRY: Dict[str, KernelSpec] = {}


def register(spec: KernelSpec) -> KernelSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"kernel {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> KernelSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"kernel {name!r} not registered; known: {sorted(_REGISTRY)}"
        ) from None


def names() -> Sequence[str]:
    return sorted(_REGISTRY)


def problem_key(problem: Mapping[str, int]) -> Tuple[Tuple[str, int], ...]:
    return tuple(sorted(problem.items()))
