"""Tile shapes and the constraint system that filters candidate tilings.

The paper sweeps CUDA block dims (e.g. 4x8 vs 8x4 vs 32x4) subject to the
hardware's constraints (<=512 threads/block, active-thread ceilings). The TPU
analogue implemented here: a :class:`TileShape` is a tuple of block dims for a
Pallas ``BlockSpec``; :class:`TileConstraints` encodes the hardware's legality
and efficiency rules (VMEM working-set fit, lane/sublane alignment, MXU
divisibility); :func:`enumerate_tiles` generates the legal candidate space the
autotuner sweeps — the exact counterpart of the paper's tile-dimension axis in
Fig. 3.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Iterable, List, Optional, Sequence, Tuple

from repro.core.hardware import HardwareModel

DTYPE_BYTES = {
    "float32": 4, "bfloat16": 2, "float16": 2, "int8": 1,
    "int32": 4, "uint8": 1, "float64": 8,
}


def dtype_bytes(dtype) -> int:
    return DTYPE_BYTES[str(dtype)]


@dataclasses.dataclass(frozen=True, order=True)
class TileShape:
    """A block shape for one operand-tiling decision, e.g. (bm, bk, bn)."""

    dims: Tuple[int, ...]

    def __iter__(self):
        return iter(self.dims)

    def __getitem__(self, i):
        return self.dims[i]

    def __len__(self):
        return len(self.dims)

    @property
    def size(self) -> int:
        return math.prod(self.dims)

    def __str__(self) -> str:
        return "x".join(str(d) for d in self.dims)


@dataclasses.dataclass(frozen=True)
class TileConstraints:
    """Legality/efficiency constraints for a kernel's tile space on given hw.

    ``vmem_operands`` maps a candidate tile to the per-grid-step VMEM working
    set in bytes; kernels provide it since only they know which operands a
    tile touches (e.g. matmul holds bm*bk + bk*bn + bm*bn).
    """

    rank: int
    # Per-dim upper bounds (problem dims; tiles never exceed the problem).
    max_dims: Tuple[int, ...]
    # Dims that feed the MXU contraction want multiples of mxu_dim.
    mxu_dims: Tuple[int, ...] = ()
    # The minor (lane) dim index, wants multiples of lane_count.
    lane_dim: Optional[int] = None
    # The second-minor (sublane) dim index.
    sublane_dim: Optional[int] = None
    # Fraction of VMEM the tile working set may use (double-buffering => 0.5).
    vmem_fraction: float = 0.5

    def alignment(self, hw: HardwareModel, dtype: str, dim_index: int) -> int:
        if dim_index == self.lane_dim:
            return hw.lane_count
        if dim_index == self.sublane_dim:
            return hw.sublane[dtype] if dtype in ("float32", "bfloat16") else 8
        if dim_index in self.mxu_dims:
            return hw.mxu_dim
        return 1


def _candidates_for_dim(limit: int, align: int) -> List[int]:
    """Powers-of-two multiples of ``align`` up to ``limit`` (plus limit itself)."""
    out = []
    v = align
    while v < limit:
        out.append(v)
        v *= 2
    out.append(limit)
    # Dedup while preserving order.
    seen, uniq = set(), []
    for x in out:
        if x not in seen:
            seen.add(x)
            uniq.append(x)
    return uniq


def enumerate_tiles(
    constraints: TileConstraints,
    hw: HardwareModel,
    dtype: str,
    vmem_bytes_fn,
    max_candidates: int = 512,
) -> List[TileShape]:
    """Generate the legal tile space — the sweep axis of the paper's Fig. 3.

    ``vmem_bytes_fn(tile) -> int`` gives the per-step VMEM working set.
    Candidates violating the VMEM budget are discarded, mirroring the paper's
    "threads per block <= 512" legality filter.
    """
    axes: List[List[int]] = []
    for i in range(constraints.rank):
        align = constraints.alignment(hw, dtype, i)
        limit = constraints.max_dims[i]
        if limit <= align:
            axes.append([limit])
        else:
            axes.append(_candidates_for_dim(limit, align))

    budget = hw.vmem_bytes * constraints.vmem_fraction
    tiles: List[TileShape] = []
    for dims in itertools.product(*axes):
        t = TileShape(tuple(dims))
        if vmem_bytes_fn(t) <= budget:
            tiles.append(t)
    # Prefer larger tiles first (fewer grid steps) as the tie-break ordering.
    tiles.sort(key=lambda t: (-t.size, t.dims))
    return tiles[:max_candidates]


def round_up(x: int, multiple: int) -> int:
    return ((x + multiple - 1) // multiple) * multiple


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def padded_extent(extent: int, tile: int) -> int:
    """Problem extent after padding to a whole number of tiles."""
    return cdiv(extent, tile) * tile


def grid_for(shape: Sequence[int], tile: TileShape) -> Tuple[int, ...]:
    assert len(shape) == len(tile)
    return tuple(cdiv(s, t) for s, t in zip(shape, tile))
