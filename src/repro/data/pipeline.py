"""Deterministic synthetic LM data pipeline.

Production-shaped: per-host sharding (each host generates only its slice of
the global batch), checkpointable iterator state (a step counter — the
stream is a pure function of (seed, step, host)), document packing, and a
background prefetch thread. Synthetic text is a Zipf-like token stream with
document structure so losses are non-degenerate.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    eos_id: int = 1
    num_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


def _zipf_tokens(rng: np.random.Generator, n: int, vocab: int) -> np.ndarray:
    # Zipf over the real vocab (ids >= 2; 0=pad, 1=eos).
    ranks = rng.zipf(1.3, size=n)
    return np.clip(ranks + 1, 2, vocab - 1).astype(np.int32)


def make_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Pure function of (cfg, step): host-local {"tokens", "targets"}."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id])
    )
    b, s = cfg.host_batch, cfg.seq_len
    toks = _zipf_tokens(rng, b * (s + 1), cfg.vocab_size).reshape(b, s + 1)
    # Document packing: insert EOS at geometric boundaries.
    doc_end = rng.random((b, s + 1)) < (1.0 / cfg.mean_doc_len)
    toks = np.where(doc_end, cfg.eos_id, toks)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class DataIterator:
    """Checkpointable, prefetching iterator over make_batch."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 prefetch: int = 2):
        self.cfg = cfg
        self._step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = make_batch(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self) -> Dict[str, np.ndarray]:
        step, batch = self._q.get()
        self._step = step + 1
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    @property
    def state(self) -> Dict[str, int]:
        """Checkpointable state: resume with DataIterator(cfg, state['step'])."""
        return {"step": self._step}

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
