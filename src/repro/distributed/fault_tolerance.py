"""Fault tolerance: step-time health monitoring and straggler detection.

On a real multi-host deployment each host runs a HealthMonitor; a host whose
step time exceeds ``straggler_factor`` x the EWMA is flagged (logged +
counted). The Trainer consumes flags to decide checkpoint-now / abort, and
its run loop survives worker exceptions by restoring the latest checkpoint
(see train/trainer.py and the simulated-failure test).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional


@dataclasses.dataclass
class HealthMonitor:
    ewma_alpha: float = 0.1
    straggler_factor: float = 2.5
    warmup_steps: int = 5

    _ewma: Optional[float] = None
    _steps: int = 0
    straggler_events: int = 0
    history: List[float] = dataclasses.field(default_factory=list)

    def record_step(self, seconds: float) -> bool:
        """Record one step's wall time; True if this step was a straggler."""
        self._steps += 1
        self.history.append(seconds)
        is_straggler = False
        if self._ewma is None:
            self._ewma = seconds
        else:
            if (self._steps > self.warmup_steps
                    and seconds > self.straggler_factor * self._ewma):
                self.straggler_events += 1
                is_straggler = True
                # Do not fold outliers into the EWMA — keeps the baseline honest.
            else:
                self._ewma = (
                    self.ewma_alpha * seconds
                    + (1 - self.ewma_alpha) * self._ewma
                )
        return is_straggler

    @property
    def baseline_s(self) -> Optional[float]:
        return self._ewma


class StepTimer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0
        return False
