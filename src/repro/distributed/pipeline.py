"""GPipe-style pipeline parallelism over the ``pod`` mesh axis.

The multi-pod mesh's pod axis defaults to data parallelism; this module
provides the PP alternative for models whose per-chip state does not fit at
DP (the qwen3-235B case in EXPERIMENTS §Perf): layers split into one stage
per pod, microbatches stream through the stages, and activations hop pods
via ``collective_permute`` (differentiable — its transpose is the reverse
permute, so jax.grad drives the backward pipeline automatically).

Implementation: ``shard_map`` manual over the pod axis only
(``axis_names={"pod"}``); the data/model axes stay auto, so each stage's
layer compute composes with the existing DP/TP sharding. Stage-stacked
layer parameters are sharded P("pod") on their leading axis, giving each
pod exactly its stage's weights.

Scope: homogeneous decoder stacks (one LayerSpec repeated). Embedding and
head weights are replicated; layer weights — the bulk — are stage-local.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.models.layers import init_tree


def stage_param_defs(cfg: ArchConfig, n_stages: int):
    """Layer params stacked [n_stages, layers_per_stage, ...]."""
    assert cfg.n_layers % n_stages == 0, (cfg.n_layers, n_stages)
    per = cfg.n_layers // n_stages
    spec = cfg.layers()[0]
    layer = T.layer_defs(cfg, spec)
    return {
        "embed": T.model_defs(cfg)["embed"],
        "final_norm_w": T.model_defs(cfg)["final_norm_w"],
        "stages": T._stack_defs(T._stack_defs(layer, per), n_stages),
    }


def init_pipeline_params(cfg: ArchConfig, key, n_stages: int,
                         dtype=jnp.float32):
    return init_tree(stage_param_defs(cfg, n_stages), key, dtype)


def pipeline_shardings(params, mesh):
    """Stage axis -> pod; embed/head replicated (demo scale)."""
    def spec(path, leaf):
        name = path[0].key if hasattr(path[0], "key") else None
        if name == "stages":
            return NamedSharding(mesh, P("pod"))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map_with_path(spec, params)


def make_pipeline_loss(cfg: ArchConfig, mesh, n_stages: int,
                       n_microbatches: int):
    """Returns loss_fn(params, tokens, targets) running the GPipe schedule.

    tokens/targets: [B, S] with B divisible by n_microbatches.
    """
    spec = cfg.layers()[0]
    per = cfg.n_layers // n_stages

    def stage_body(stage_p, cfg_, x, positions, first, last, tokens_mb,
                   embed, norm_w):
        # First stage: swap in the embedded tokens (x arrives as zeros).
        emb = embed[tokens_mb]
        if cfg_.scale_embeddings:
            emb = emb * jnp.asarray(cfg_.d_model ** 0.5, emb.dtype)
        x = jnp.where(first, emb, x)

        def body(carry, lp):
            xc, _ = carry
            xo, _, aux = T.layer_forward(lp, cfg_, spec, xc, positions,
                                         None, None)
            return (xo, aux), None

        (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                 stage_p)
        return x

    def loss_fn(params, tokens, targets):
        b, s = tokens.shape
        assert b % n_microbatches == 0
        mb = b // n_microbatches
        positions = jnp.broadcast_to(jnp.arange(s)[None], (mb, s))
        tok_mbs = tokens.reshape(n_microbatches, mb, s)
        tgt_mbs = targets.reshape(n_microbatches, mb, s)

        def pod_program(stages_local, embed, norm_w, tok_mbs, tgt_mbs):
            stage = jax.lax.axis_index("pod")
            stage_p = jax.tree.map(lambda a: a[0], stages_local)
            first = stage == 0
            last = stage == n_stages - 1
            n_ticks = n_microbatches + n_stages - 1

            x = jnp.zeros((mb, s, cfg.d_model), embed.dtype)
            total = jnp.zeros((), jnp.float32)
            count = jnp.zeros((), jnp.float32)

            def tick(carry, t):
                x_in, total, count = carry
                mb_idx = jnp.clip(t - stage, 0, n_microbatches - 1)
                active = (t - stage >= 0) & (t - stage < n_microbatches)
                tokens_mb = tok_mbs[mb_idx]
                out = stage_body(stage_p, cfg, x_in, positions, first, last,
                                 tokens_mb, embed, norm_w)
                # Last stage: loss for its active microbatch.
                h = T._apply_norm({"final_norm_w": norm_w}, cfg, out,
                                  "final_norm")
                ce = T.fused_lm_loss(embed.T, h, tgt_mbs[mb_idx], cfg,
                                     chunk=s)
                use = active & last
                total = total + jnp.where(use, ce, 0.0)
                count = count + jnp.where(use, 1.0, 0.0)
                # Ship activations to the next stage.
                perm = [(i, i + 1) for i in range(n_stages - 1)]
                x_next = jax.lax.ppermute(out, "pod", perm)
                return (x_next, total, count), None

            (x, total, count), _ = jax.lax.scan(
                tick, (x, total, count), jnp.arange(n_ticks))
            # Broadcast the last stage's mean loss to every pod.
            loss_sum = jax.lax.psum(total, "pod")
            n = jax.lax.psum(count, "pod")
            return loss_sum / jnp.maximum(n, 1.0)

        return shard_map(
            pod_program, mesh=mesh,
            in_specs=(P("pod"), P(), P(), P(), P()),
            out_specs=P(),
            axis_names=frozenset({"pod"}),
            check_vma=False,
        )(params["stages"], params["embed"], params["final_norm_w"],
          tok_mbs, tgt_mbs)

    return loss_fn


def sequential_reference_loss(cfg: ArchConfig, params, tokens, targets):
    """Same math without the pipeline (for correctness tests)."""
    n_stages = params["stages"]["norm1_w"].shape[0]
    per = params["stages"]["norm1_w"].shape[1]
    flat = jax.tree.map(
        lambda a: a.reshape((n_stages * per,) + a.shape[2:]),
        params["stages"])
    spec = cfg.layers()[0]
    x = params["embed"][tokens]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(carry, lp):
        xc, _ = carry
        xo, _, aux = T.layer_forward(lp, cfg, spec, xc, positions, None,
                                     None)
        return (xo, aux), None

    (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), flat)
    h = T._apply_norm({"final_norm_w": params["final_norm_w"]}, cfg, x,
                      "final_norm")
    return T.fused_lm_loss(params["embed"].T, h, targets, cfg, chunk=s)
