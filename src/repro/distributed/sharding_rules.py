"""Logical-axis -> mesh-axis mapping and sharding tree construction.

Parameters carry logical axes (models/layers.py ParamDef); this module turns
them into NamedShardings for a given mesh. Strategy knobs:

* ``fsdp``  — additionally shard the largest remaining parameter axis over
  the data axis (ZeRO-3 style), on top of TP. Default on: at 256+ chips
  replicated 235B optimizer state cannot fit otherwise.
* batch axes: ("pod", "data") when the mesh has a pod axis, else ("data",).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.context import DistContext

# logical axis -> model-parallel mesh axis
_MODEL_AXES = {
    "heads": "model", "kv_heads": "model", "ff": "model", "vocab": "model",
    "experts": "model", "lru": "model", "ssm_heads": "model",
}


def batch_axes_for(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def make_context(mesh: Optional[Mesh]) -> DistContext:
    if mesh is None:
        return DistContext(mesh=None)
    return DistContext(mesh=mesh, batch_axes=batch_axes_for(mesh))


def param_spec(
    logical_axes: Tuple[Optional[str], ...],
    shape: Tuple[int, ...],
    mesh: Mesh,
    fsdp: bool = True,
) -> P:
    """PartitionSpec for one parameter from its logical axes.

    TP axes map via _MODEL_AXES; with ``fsdp``, the largest axis not already
    sharded (and divisible) is additionally sharded over 'data'.
    """
    assign: list = [None] * len(shape)
    for i, ax in enumerate(logical_axes):
        mapped = _MODEL_AXES.get(ax) if ax else None
        if mapped and shape[i] % mesh.shape[mapped] == 0 and shape[i] >= mesh.shape[mapped]:
            assign[i] = mapped
    if fsdp and "data" in mesh.axis_names:
        dsize = mesh.shape["data"]
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in order:
            if assign[i] is None and shape[i] % dsize == 0 and shape[i] >= dsize:
                assign[i] = "data"
                break
    return P(*assign)


def param_shardings(
    axes_tree: Any, shape_tree: Any, mesh: Mesh, fsdp: bool = True,
) -> Any:
    """Pytree of NamedShardings matching the params pytree."""
    return jax.tree.map(
        lambda ax, sds: NamedSharding(
            mesh, param_spec(ax, sds.shape, mesh, fsdp)
        ),
        axes_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )


def batch_sharding(mesh: Mesh, ndim: int, batch_dim: int = 0) -> NamedSharding:
    spec: list = [None] * ndim
    baxes = batch_axes_for(mesh)
    spec[batch_dim] = baxes if len(baxes) > 1 else baxes[0]
    return NamedSharding(mesh, P(*spec))


def opt_state_shardings(param_shard_tree: Any, mesh: Mesh) -> Any:
    """AdamW moments shard like their parameters; step is replicated."""
    return {
        "m": param_shard_tree,
        "v": param_shard_tree,
        "step": NamedSharding(mesh, P()),
    }


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Serve-state (KV cache / recurrent state) sharding
# ---------------------------------------------------------------------------

def _batch_entry(mesh: Mesh, b: int):
    """Shard batch over as many batch axes as divide it (pods first)."""
    baxes = batch_axes_for(mesh)
    use = []
    rem = b
    for ax in baxes:
        if rem % mesh.shape[ax] == 0 and rem >= mesh.shape[ax]:
            use.append(ax)
            rem //= mesh.shape[ax]
    if not use:
        return None
    return tuple(use) if len(use) > 1 else use[0]


def serve_state_shardings(state_shapes: Any, mesh: Mesh) -> Any:
    """Shardings for an api.make_serve_state pytree (by leaf name + rank).

    KV caches [*, B, H, S, hd]: batch over batch axes; heads over 'model'
    when divisible, else the cache SEQUENCE shards over 'model' (keeps 32k+
    caches within HBM; XLA partitions the attention reduction). Recurrent
    states shard features/heads over 'model'.
    """
    msize = mesh.shape["model"]

    def div(n: int) -> bool:
        return n % msize == 0 and n >= msize

    def spec(path, leaf) -> NamedSharding:
        name = None
        for entry in reversed(path):
            if hasattr(entry, "key"):
                name = entry.key
                break
        nd, sh = leaf.ndim, leaf.shape
        out: list = [None] * nd
        if name in ("pos", "slot_pos") or nd <= 1:
            return NamedSharding(mesh, P())
        if name in ("k", "v", "self_k", "self_v", "cross"):
            # [*lead, B, H, S, hd] — heads over model if divisible, else seq.
            off = nd - 4
            out[off] = _batch_entry(mesh, sh[off])
            if div(sh[off + 1]):
                out[off + 1] = "model"
            elif div(sh[off + 2]):
                out[off + 2] = "model"
        elif name == "h" and nd >= 4:
            # SSD state [*lead, B, H, N, P] — heads over model.
            off = nd - 4
            out[off] = _batch_entry(mesh, sh[off])
            if div(sh[off + 1]):
                out[off + 1] = "model"
        elif name and name.startswith("conv"):
            # [*lead, B, W, F] — features over model.
            off = nd - 3
            out[off] = _batch_entry(mesh, sh[off])
            if div(sh[-1]):
                out[-1] = "model"
        else:
            # [*lead, B, F] recurrent vector state.
            off = nd - 2
            out[off] = _batch_entry(mesh, sh[off])
            if div(sh[-1]):
                out[-1] = "model"
        return NamedSharding(mesh, P(*out))

    return jax.tree_util.tree_map_with_path(spec, state_shapes)
