# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.


def register_all() -> None:
    """Import every kernel's ops module so its KernelSpec is registered.

    Plan resolution and compilation look kernels up in the registry; callers
    that reach it without importing the ops modules (serve engine, trainer,
    the compile-plans CLI) call this first. Idempotent.
    """
    import repro.kernels.bilinear.ops  # noqa: F401
    import repro.kernels.flash_attention.ops  # noqa: F401
    import repro.kernels.matmul.ops  # noqa: F401
    import repro.kernels.rglru.ops  # noqa: F401
    import repro.kernels.ssd.ops  # noqa: F401
