"""Pallas TPU kernel for bilinear upscaling, tile-parameterized.

Hardware adaptation (see DESIGN.md §2): the paper's CUDA implementation is a
per-thread 4-point gather. TPUs have no efficient per-element gather — the
TPU-native formulation exploits separability: bilinear resize is

    out = Wy @ src @ Wx^T

where ``Wy``/``Wx`` are banded tent-weight matrices (two non-zeros per row).
Both factors are generated *on the fly* from ``iota`` inside the kernel (never
materialized in HBM) and the contraction runs on the MXU. Row interpolation
``tmp = Wy_tile @ src`` is computed once per output-row-band (cached in VMEM
scratch, recomputed only when the row index changes), so sweeping the output
tile (bh, bw) reproduces the paper's tiling experiment:

* wide tiles (large bw) -> fewer strided row segments in the output store —
  the paper's Fig. 4 geometry;
* tile legality is bounded by VMEM (the occupancy analogue);
* the optimum depends on the HardwareModel, which is the paper's thesis.

The source image stays VMEM-resident (constant index map => single DMA), so
this kernel targets sources up to a few MiB — the paper's 800x800 test image
is 2.5 MiB in fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _tent_weights(out_start, bh: int, src_len: int, scale: int, dtype):
    """Rows [out_start, out_start+bh) of the banded interpolation matrix.

    W[r, s] = max(0, 1 - |clamp((out_start + r)/scale) - s|)  — two non-zeros
    per row; exactly the paper's (1-offset, offset) pair, built from iota.
    """
    r = jax.lax.broadcasted_iota(jnp.float32, (bh, src_len), 0)
    s = jax.lax.broadcasted_iota(jnp.float32, (bh, src_len), 1)
    pos = (r + out_start.astype(jnp.float32)) / float(scale)
    pos = jnp.minimum(pos, float(src_len - 1))
    w = jnp.maximum(0.0, 1.0 - jnp.abs(pos - s))
    return w.astype(dtype)


def _bilinear_kernel(src_ref, out_ref, tmp_ref, *, scale: int, bh: int, bw: int):
    i = pl.program_id(0)  # output row-band index
    j = pl.program_id(1)  # output col-tile index
    h_s, w_s = src_ref.shape

    # Row interpolation once per row-band: tmp = Wy[i] @ src  -> [bh, w_s].
    @pl.when(j == 0)
    def _():
        wy = _tent_weights(i * bh, bh, h_s, scale, jnp.float32)
        tmp_ref[...] = jax.lax.dot_general(
            wy, src_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    # Column interpolation per tile: out = tmp @ Wx[j]^T -> [bh, bw].
    wx = _tent_weights(j * bw, bw, w_s, scale, jnp.float32)
    out_ref[...] = jax.lax.dot_general(
        tmp_ref[...], wx,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(out_ref.dtype)


def bilinear_upscale(
    src: jnp.ndarray,
    scale: int,
    tile: tuple[int, int] = (256, 256),
    interpret: bool = False,
) -> jnp.ndarray:
    """Upscale ``src`` [H, W] by integer ``scale`` with output tile ``tile``."""
    if src.ndim != 2:
        raise ValueError(f"expected [H, W] image, got {src.shape}")
    if scale < 1:
        raise ValueError("scale must be a positive integer")
    h_s, w_s = src.shape
    oh, ow = h_s * scale, w_s * scale
    bh, bw = tile
    bh, bw = min(bh, oh), min(bw, ow)
    if oh % bh or ow % bw:
        raise ValueError(f"tile {tile} must divide output {(oh, ow)}")

    grid = (oh // bh, ow // bw)
    kernel = functools.partial(_bilinear_kernel, scale=scale, bh=bh, bw=bw)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((h_s, w_s), lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec((bh, bw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((oh, ow), src.dtype),
        scratch_shapes=[pltpu.VMEM((bh, w_s), jnp.float32)],
        interpret=interpret,
    )(src)
