"""Jit'd public op + registry declarations for the bilinear kernel.

Two KernelSpec registrations share the tile space but differ in workload:

* ``bilinear``      — the TPU Pallas implementation (separable matmul).
* ``bilinear_cuda`` — the paper's gather implementation as executed on their
  GPUs (4 reads + ~10 flops per pixel, one thread per pixel). Used only by
  the Fig. 3 / Fig. 4 reproduction benchmarks, evaluated with the GTX260 /
  8800GTS hardware descriptors.

Problem dims: {"src_h", "src_w", "scale"}; tile rank 2 = output (bh, bw).
"""
from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp

from repro.core import registry
from repro.core.cost_model import TileWorkload
from repro.core.tiling import TileConstraints, TileShape, cdiv, dtype_bytes
from repro.kernels.bilinear.bilinear import bilinear_upscale
from repro.kernels.bilinear.ref import bilinear_upscale_ref


@functools.partial(jax.jit, static_argnames=("scale", "tile", "interpret"))
def upscale(src, scale: int, tile=(256, 256), interpret: bool = False):
    return bilinear_upscale(src, scale, tile=tile, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale",))
def upscale_ref(src, scale: int):
    return bilinear_upscale_ref(src, scale)


# --------------------------------------------------------------------------
# Registry: TPU implementation.
# --------------------------------------------------------------------------

def _out_dims(problem: Mapping[str, int]):
    return problem["src_h"] * problem["scale"], problem["src_w"] * problem["scale"]


def _constraints(problem: Mapping[str, int]) -> TileConstraints:
    oh, ow = _out_dims(problem)
    return TileConstraints(
        rank=2, max_dims=(oh, ow), lane_dim=1, sublane_dim=0,
    )


def _vmem_bytes(tile: TileShape, problem: Mapping[str, int], dtype: str) -> float:
    bh, bw = tile
    b = dtype_bytes(dtype)
    src = problem["src_h"] * problem["src_w"] * b       # resident source
    tmp = bh * problem["src_w"] * 4                      # f32 row-interp scratch
    out = bh * bw * b
    return src + tmp + out


def _workload(tile: TileShape, problem: Mapping[str, int], dtype: str) -> TileWorkload:
    bh, bw = tile
    oh, ow = _out_dims(problem)
    b = dtype_bytes(dtype)
    h_s, w_s = problem["src_h"], problem["src_w"]
    n_j = cdiv(ow, bw)
    # Two MXU contractions; the row-interp matmul amortizes over the j tiles.
    flops = (2.0 * bh * h_s * w_s) / n_j + 2.0 * bh * w_s * bw
    # Source is DMA'd once for the whole grid; charge it amortized per tile.
    n_tiles = cdiv(oh, bh) * n_j
    hbm = bh * bw * b + (h_s * w_s * b) / n_tiles
    return TileWorkload(
        flops=flops,
        hbm_bytes=hbm,
        row_segments=bh,                     # output store: bh strided rows
        row_stride_bytes=float(ow * b),      # stride = final image width
        pad_waste=1.0,
    )


def _n_tiles(tile: TileShape, problem: Mapping[str, int]) -> int:
    oh, ow = _out_dims(problem)
    return cdiv(oh, tile[0]) * cdiv(ow, tile[1])


def _default_tile(problem: Mapping[str, int], dtype: str) -> TileShape:
    # The "32x4 principle": maximize the lane-contiguous minor dim first.
    oh, ow = _out_dims(problem)
    return TileShape((min(256, oh), min(512, ow)))


registry.register(registry.KernelSpec(
    name="bilinear",
    constraints=_constraints,
    vmem_bytes=_vmem_bytes,
    workload=_workload,
    n_tiles=_n_tiles,
    default_tile=_default_tile,
))


# --------------------------------------------------------------------------
# Registry: the paper's CUDA gather implementation (reproduction only).
# One thread per output pixel; 4 source reads + 1 write; ~10 flops.
# --------------------------------------------------------------------------

def _cuda_constraints(problem: Mapping[str, int]) -> TileConstraints:
    oh, ow = _out_dims(problem)
    # CUDA blocks: <=512 threads enforced by the cost model; dims bounded by
    # the paper's sweep range.
    return TileConstraints(
        rank=2, max_dims=(min(oh, 512), min(ow, 512)),
        lane_dim=None, sublane_dim=None,
    )


def _cuda_vmem(tile: TileShape, problem: Mapping[str, int], dtype: str) -> float:
    return 0.0  # the paper's kernel uses no shared memory


GPU_TRANSACTION_BYTES = 128  # G80/GT200 coalesced global transaction size


def _cuda_workload(tile: TileShape, problem: Mapping[str, int], dtype: str) -> TileWorkload:
    bh, bw = tile  # (height, width) = CUDA (blockDim.y, blockDim.x)
    oh, ow = _out_dims(problem)
    b = dtype_bytes(dtype)
    pixels = bh * bw
    s = problem["scale"]
    # Coalescing: each (warp, row) segment moves whole 128B transactions, so
    # narrow tiles (bw < 32) waste bandwidth — this is why every winner in
    # the paper's Fig. 3 is 32 wide. Output: bh segments of bw pixels.
    # Source: each output row reads its two neighbor rows (no cache on G80),
    # segments of ~bw/s + 1 pixels.
    seg = lambda width_px: max(width_px * b, GPU_TRANSACTION_BYTES)
    out_bytes = bh * seg(bw)
    src_bytes = 2 * bh * seg(bw // s + 1)
    # DRAM page switches: distinct rows touched, stride = final image width.
    segments = bh + (bh // s + 2)
    return TileWorkload(
        flops=10.0 * pixels,
        hbm_bytes=float(out_bytes + src_bytes),
        row_segments=segments,
        row_stride_bytes=float(ow * b),
        threads=pixels,
    )


registry.register(registry.KernelSpec(
    name="bilinear_cuda",
    constraints=_cuda_constraints,
    vmem_bytes=_cuda_vmem,
    workload=_cuda_workload,
    n_tiles=_n_tiles,
    default_tile=lambda p, d: TileShape((4, 32)),
))
