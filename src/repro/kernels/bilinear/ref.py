"""Pure-jnp oracle for bilinear image upscaling (the paper's Eq. 1-5).

Coordinate map follows the paper exactly: for terminal pixel (xf, yf) the
logical source point is (xf/scale, yf/scale); neighbors x1=int(xp), x2=x1+1
(clamped to the image border, replicate-edge), weights from the fractional
offsets. Note the paper's Eq. (5) has a typo — the last term's ``(1-offsetY)``
should be ``(1-offsetX)`` for the weights to sum to 1; we implement standard
bilinear, which is what their CUDA code computes.
"""
from __future__ import annotations

import jax.numpy as jnp


def bilinear_upscale_ref(src: jnp.ndarray, scale: int) -> jnp.ndarray:
    """Upscale ``src`` [H, W] by integer ``scale`` -> [H*scale, W*scale]."""
    h, w = src.shape
    oh, ow = h * scale, w * scale

    yf = jnp.arange(oh, dtype=src.dtype)
    xf = jnp.arange(ow, dtype=src.dtype)
    yp = jnp.minimum(yf / scale, h - 1)
    xp = jnp.minimum(xf / scale, w - 1)

    y1 = jnp.floor(yp).astype(jnp.int32)
    x1 = jnp.floor(xp).astype(jnp.int32)
    y2 = jnp.minimum(y1 + 1, h - 1)
    x2 = jnp.minimum(x1 + 1, w - 1)
    oy = (yp - y1.astype(src.dtype))[:, None]          # [OH, 1]
    ox = (xp - x1.astype(src.dtype))[None, :]          # [1, OW]

    f11 = src[y1][:, x1]
    f12 = src[y1][:, x2]
    f21 = src[y2][:, x1]
    f22 = src[y2][:, x2]

    top = (1 - ox) * f11 + ox * f12
    bot = (1 - ox) * f21 + ox * f22
    return (1 - oy) * top + oy * bot
