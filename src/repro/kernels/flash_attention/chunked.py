"""Chunked-prefill attention: one prompt chunk over the live KV cache.

Chunked prefill is the serving-side decomposition the warp/CUDA-tile papers
make at the kernel level: one large tiled launch (the whole-prompt prefill)
is split into schedulable sub-launches so the engine can interleave decode
steps between them. Each sub-launch is a *continuation*: chunk N's queries
sit at absolute positions ``start .. start+c-1`` and attend causally over
the KV written by chunks ``0..N-1`` plus the chunk itself — exactly the
whole-prompt computation restricted to those query rows.

Two lowerings share the math:

* **linear caches** reuse the existing ``q_offset`` continuation arithmetic
  of :mod:`repro.kernels.flash_attention.flash_attention` /
  :func:`~repro.kernels.flash_attention.ref.flash_attention_ref` — the
  caller slices the cache to the written prefix and passes
  ``q_offset=start`` (see ``models.attention.attn_prefill_chunk``);
* **ring-buffer caches** need an arbitrary slot -> absolute-position map,
  which static ``q_offset`` cannot express. :func:`flash_prefill_chunk_ref`
  below generalizes the online-softmax reference to traced ``q_pos`` /
  ``kv_pos`` arrays (the decode kernel's convention, lifted to ``Sq > 1``).

The tunable axes of the chunked-prefill *plan cell* are ``(chunk, bkv)``:
the chunk length (how much prompt one sub-launch covers — the resident
query block) and the KV split streamed under it. The cell is registered in
``ops.py``; VMEM capacity bounds the resident chunk per hardware model, so
the same prompt length compiles different chunk sizes on different models.

**Step packing** (:func:`flash_prefill_packed_ref`) lifts the chunk
continuation one level further: N independent requests' chunks are
segment-concatenated into ONE launch — queries carry a per-token segment id
next to their absolute position, keys carry the same pair, and visibility
requires segment equality on top of the causal position rule, so one
kernel invocation serves N requests without any cross-request attention.
This is the Model-Based-Warp-Overlapped-Tiling move applied at the serving
layer: independently-tiled work items overlap in one launch, and the
tunable ``(pack, bkv)`` cell (``packed_prefill`` in ``ops.py``) makes the
*pack width* — how many chunk tokens ride one step — a first-class
per-hardware-model tile axis.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.decode import paged_gather
from repro.kernels.flash_attention.ref import fit_bkv

NEG_INF = -2.0e30


def paged_prefix(k_pages, v_pages, page_table, n_prefix_pages: int, start):
    """Dense view of a chunk's visible cache prefix from the paged pool.

    Gathers the first ``n_prefix_pages`` table entries (a static count —
    ``cdiv(start, page)`` at trace time) and returns ``(k, v, kv_pos)``
    with k/v ``[1, Hkv, n_prefix_pages*page, D]`` and ``kv_pos`` marking
    slots at positions >= ``start`` as never written (-1). The mask does
    double duty: it hides the unwritten tail of a partially-filled last
    page AND a shared-prefix donor's own tokens past the shared length in
    a copy-on-write page (see serve/pool.py) — without it a prefix hit
    would attend the donor's divergent continuation.
    """
    k = paged_gather(k_pages, page_table[:n_prefix_pages])
    v = paged_gather(v_pages, page_table[:n_prefix_pages])
    span = k.shape[2]
    pos = jnp.arange(span, dtype=jnp.int32)
    kv_pos = jnp.where(pos < start, pos, -1)
    return k, v, kv_pos


def flash_prefill_chunk_paged_ref(
    q, k_chunk, v_chunk, k_pages, v_pages, page_table, *,
    q_pos, start, n_prefix_pages: int,
    window: Optional[int] = None, softcap: Optional[float] = None,
    scale: Optional[float] = None, bkv: int = 512,
):
    """``flash_prefill_chunk_ref`` over a paged cache prefix: gather the
    prefix pages, concatenate the chunk's own keys (positions ``q_pos``),
    and run the identical positioned online softmax."""
    if n_prefix_pages:
        kp, vp, pp = paged_prefix(
            k_pages, v_pages, page_table, n_prefix_pages, start)
        k_all = jnp.concatenate([kp, k_chunk.astype(kp.dtype)], axis=2)
        v_all = jnp.concatenate([vp, v_chunk.astype(vp.dtype)], axis=2)
        kv_pos = jnp.concatenate([pp, jnp.asarray(q_pos, jnp.int32)])
    else:
        k_all, v_all = k_chunk, v_chunk
        kv_pos = jnp.asarray(q_pos, jnp.int32)
    return flash_prefill_chunk_ref(
        q, k_all, v_all, q_pos=q_pos, kv_pos=kv_pos,
        window=window, softcap=softcap, scale=scale, bkv=bkv)


@functools.partial(
    jax.jit,
    static_argnames=("window", "softcap", "scale", "bkv"),
)
def flash_prefill_chunk_ref(
    q, k, v, *, q_pos, kv_pos=None, window: Optional[int] = None,
    softcap: Optional[float] = None, scale: Optional[float] = None,
    bkv: int = 512,
):
    """Online-softmax attention of a prompt chunk over positioned keys.

    q ``[B, Hq, Sq, D]`` — the chunk's queries at absolute positions
    ``q_pos`` [Sq] (traced ok). k/v ``[B, Hkv, Skv, D]`` — the keys visible
    to the chunk (cache history ++ the chunk's own keys); ``kv_pos`` [Skv]
    maps each key slot to its absolute position (``-1`` = never written;
    default linear ``arange``). A key is visible iff
    ``0 <= kv_pos <= q_pos`` (causal continuation) and, with ``window``,
    ``kv_pos > q_pos - window``.

    GQA grouped contraction (no kv-repeat materialization), scanned over KV
    splits of ``bkv`` — the same online-softmax update as
    ``flash_attention_ref`` with the static ``q_offset`` causal arithmetic
    generalized to arbitrary position maps, so ring-buffer caches chunk the
    same way linear ones do. A non-dividing ``bkv`` snaps to the largest
    divisor of ``Skv`` (``fit_bkv``).

    NOTE: ``flash_decode_ref`` (decode.py) is the ``Sq == 1`` special case
    of this scan. Those bodies are kept separate on purpose — each mirrors
    the structure of its Pallas kernel (decode: resident grouped rows;
    chunked: resident query block) — but a change to the masking or
    softmax-update rule in one almost certainly belongs in the other; the
    decode==prefill parity suites in tests/test_kernels_decode.py and
    tests/test_serve_chunked.py pin both. This single-segment case, by
    contrast, IS :func:`flash_prefill_packed_ref` with constant-zero
    segment ids (segment equality is then vacuously true), so it delegates
    rather than keeping a third hand-synced copy of the scan.
    """
    sq, skv = q.shape[2], k.shape[2]
    if kv_pos is None:
        kv_pos = jnp.arange(skv, dtype=jnp.int32)
    return flash_prefill_packed_ref(
        q, k, v, q_pos=q_pos, q_seg=jnp.zeros((sq,), jnp.int32),
        kv_pos=kv_pos, kv_seg=jnp.zeros((skv,), jnp.int32),
        window=window, softcap=softcap, scale=scale, bkv=bkv)


@functools.partial(
    jax.jit,
    static_argnames=("window", "softcap", "scale", "bkv"),
)
def flash_prefill_packed_ref(
    q, k, v, *, q_pos, q_seg, kv_pos, kv_seg,
    window: Optional[int] = None, softcap: Optional[float] = None,
    scale: Optional[float] = None, bkv: int = 512,
):
    """Segment-packed online-softmax attention: N requests, one launch.

    q ``[B, Hq, Sq, D]`` concatenates the chunks of N independent requests
    along the sequence axis; ``q_pos`` [Sq] carries each token's absolute
    position *within its own request* and ``q_seg`` [Sq] tags which request
    (segment) it belongs to. k/v ``[B, Hkv, Skv, D]`` concatenate each
    segment's visible keys (its cache history ++ its own chunk keys), with
    ``kv_pos`` / ``kv_seg`` the matching per-key position and segment maps
    (``kv_pos == -1`` = never-written ring slot). A key is visible iff it
    belongs to the SAME segment (``kv_seg == q_seg``) and the causal
    continuation rule holds (``0 <= kv_pos <= q_pos``, plus the window
    bound when given) — so request i's queries never attend request j's
    keys, and within a segment the math is exactly
    :func:`flash_prefill_chunk_ref`.

    The scan streams KV in ``bkv`` splits like the single-segment reference
    (a non-dividing ``bkv`` snaps to the largest divisor of ``Skv``); the
    resident block is the whole packed query set — the ``pack`` axis of the
    ``packed_prefill`` plan cell, which VMEM capacity bounds per hardware
    model (wider packs on bigger-VMEM models; see ``ops.py``).
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    n_rep = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    bkv = fit_bkv(bkv, skv)
    n_kv = skv // bkv

    qg = q.reshape(b, hkv, n_rep, sq, d).astype(jnp.float32) * scale
    qp = jnp.asarray(q_pos, jnp.int32)
    qs = jnp.asarray(q_seg, jnp.int32)
    kc = k.reshape(b, hkv, n_kv, bkv, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, n_kv, bkv, d).transpose(2, 0, 1, 3, 4)
    pc = jnp.asarray(kv_pos, jnp.int32).reshape(n_kv, bkv)
    sc = jnp.asarray(kv_seg, jnp.int32).reshape(n_kv, bkv)

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        k_blk, v_blk, kp, ks = xs
        s_blk = jnp.einsum(
            "bgrqd,bgkd->bgrqk", qg, k_blk.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )                                              # [B, Hkv, rep, Sq, bkv]
        if softcap is not None:
            s_blk = softcap * jnp.tanh(s_blk / softcap)
        valid = jnp.logical_and(kp[None, :] >= 0, kp[None, :] <= qp[:, None])
        valid = jnp.logical_and(valid, ks[None, :] == qs[:, None])
        if window is not None:
            valid = jnp.logical_and(valid, kp[None, :] > qp[:, None] - window)
        s_blk = jnp.where(valid[None, None, None], s_blk, NEG_INF)
        m_cur = jnp.max(s_blk, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s_blk - m_new[..., None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum(
            "bgrqk,bgkd->bgrqd", p, v_blk.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, n_rep, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, n_rep, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, n_rep, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kc, vc, pc, sc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, sq, d).astype(q.dtype)
