"""Chunked-prefill attention: one prompt chunk over the live KV cache.

Chunked prefill is the serving-side decomposition the warp/CUDA-tile papers
make at the kernel level: one large tiled launch (the whole-prompt prefill)
is split into schedulable sub-launches so the engine can interleave decode
steps between them. Each sub-launch is a *continuation*: chunk N's queries
sit at absolute positions ``start .. start+c-1`` and attend causally over
the KV written by chunks ``0..N-1`` plus the chunk itself — exactly the
whole-prompt computation restricted to those query rows.

Two lowerings share the math:

* **linear caches** reuse the existing ``q_offset`` continuation arithmetic
  of :mod:`repro.kernels.flash_attention.flash_attention` /
  :func:`~repro.kernels.flash_attention.ref.flash_attention_ref` — the
  caller slices the cache to the written prefix and passes
  ``q_offset=start`` (see ``models.attention.attn_prefill_chunk``);
* **ring-buffer caches** need an arbitrary slot -> absolute-position map,
  which static ``q_offset`` cannot express. :func:`flash_prefill_chunk_ref`
  below generalizes the online-softmax reference to traced ``q_pos`` /
  ``kv_pos`` arrays (the decode kernel's convention, lifted to ``Sq > 1``).

The tunable axes of the chunked-prefill *plan cell* are ``(chunk, bkv)``:
the chunk length (how much prompt one sub-launch covers — the resident
query block) and the KV split streamed under it. The cell is registered in
``ops.py``; VMEM capacity bounds the resident chunk per hardware model, so
the same prompt length compiles different chunk sizes on different models.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ref import fit_bkv

NEG_INF = -2.0e30


@functools.partial(
    jax.jit,
    static_argnames=("window", "softcap", "scale", "bkv"),
)
def flash_prefill_chunk_ref(
    q, k, v, *, q_pos, kv_pos=None, window: Optional[int] = None,
    softcap: Optional[float] = None, scale: Optional[float] = None,
    bkv: int = 512,
):
    """Online-softmax attention of a prompt chunk over positioned keys.

    q ``[B, Hq, Sq, D]`` — the chunk's queries at absolute positions
    ``q_pos`` [Sq] (traced ok). k/v ``[B, Hkv, Skv, D]`` — the keys visible
    to the chunk (cache history ++ the chunk's own keys); ``kv_pos`` [Skv]
    maps each key slot to its absolute position (``-1`` = never written;
    default linear ``arange``). A key is visible iff
    ``0 <= kv_pos <= q_pos`` (causal continuation) and, with ``window``,
    ``kv_pos > q_pos - window``.

    GQA grouped contraction (no kv-repeat materialization), scanned over KV
    splits of ``bkv`` — the same online-softmax update as
    ``flash_attention_ref`` with the static ``q_offset`` causal arithmetic
    generalized to arbitrary position maps, so ring-buffer caches chunk the
    same way linear ones do. A non-dividing ``bkv`` snaps to the largest
    divisor of ``Skv`` (``fit_bkv``).

    NOTE: ``flash_decode_ref`` (decode.py) is the ``Sq == 1`` special case
    of this scan. The bodies are kept separate on purpose — each reference
    mirrors the structure of its Pallas kernel (decode: resident grouped
    rows; chunked: resident query block) — but a change to the masking or
    softmax-update rule in one almost certainly belongs in the other; the
    decode==prefill parity suites in tests/test_kernels_decode.py and
    tests/test_serve_chunked.py pin both.
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    n_rep = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    bkv = fit_bkv(bkv, skv)
    n_kv = skv // bkv
    if kv_pos is None:
        kv_pos = jnp.arange(skv, dtype=jnp.int32)

    qg = q.reshape(b, hkv, n_rep, sq, d).astype(jnp.float32) * scale
    qp = jnp.asarray(q_pos, jnp.int32)
    kc = k.reshape(b, hkv, n_kv, bkv, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, n_kv, bkv, d).transpose(2, 0, 1, 3, 4)
    pc = jnp.asarray(kv_pos, jnp.int32).reshape(n_kv, bkv)

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        k_blk, v_blk, kp = xs
        s_blk = jnp.einsum(
            "bgrqd,bgkd->bgrqk", qg, k_blk.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )                                              # [B, Hkv, rep, Sq, bkv]
        if softcap is not None:
            s_blk = softcap * jnp.tanh(s_blk / softcap)
        valid = jnp.logical_and(kp[None, :] >= 0, kp[None, :] <= qp[:, None])
        if window is not None:
            valid = jnp.logical_and(valid, kp[None, :] > qp[:, None] - window)
        s_blk = jnp.where(valid[None, None, None], s_blk, NEG_INF)
        m_cur = jnp.max(s_blk, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s_blk - m_new[..., None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum(
            "bgrqk,bgkd->bgrqd", p, v_blk.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, n_rep, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, n_rep, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, n_rep, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, sq, d).astype(q.dtype)
