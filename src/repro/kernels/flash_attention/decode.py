"""Flash-decode (split-KV) attention: one query over a long KV cache.

Decode is the paper's "changed external condition" applied to attention: the
computation is the same dot-product attention as prefill, but the problem
geometry collapses to a single query row streaming over a cache of ``skv``
keys — a different cell of the (problem, hardware) grid, with its own
optimal tile. The tunable dimension here is ``bkv``, the KV split size: the
cache is processed in ``skv / bkv`` blocks with online-softmax statistics
carried across blocks and the partial results LSE-combined, exactly the
flash-decoding decomposition.

Two implementations with identical math:

``flash_decode``      — Pallas TPU kernel. Grid ``(B, Hkv, skv/bkv)`` with
    the KV dimension innermost ("arbitrary"); the grouped queries of one KV
    head ([rep, d], GQA without any kv-repeat materialization) stay resident
    in VMEM while K/V blocks stream; running max / denominator / accumulator
    live in VMEM scratch. Fully-masked KV blocks (beyond ``pos``, or left of
    the sliding window) are skipped with ``pl.when``.
``flash_decode_ref``  — the same online-softmax chunked over ``bkv`` in pure
    ``lax.scan``; differentiable, lowers on every backend, and is the decode
    lowering a resolved plan tile selects on non-TPU hosts.

Shared semantics: q ``[B, Hq, D]`` (one query per sequence), k/v caches
``[B, Hkv, S, D]``, ``pos`` the (traced) absolute position of the query.
``kv_pos`` optionally maps cache slot -> absolute key position (ring-buffer
caches; ``-1`` marks never-written slots); when omitted the cache is linear
(slot i holds position i). A key is visible iff ``0 <= kv_pos <= pos`` and,
with ``window``, ``kv_pos > pos - window``. Optional logit ``softcap``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat
from repro.kernels.flash_attention.ref import fit_bkv  # noqa: F401 (re-export)

NEG_INF = -2.0e30

# Grouped-query rows are padded up to one fp32 sublane so the [rep, bkv]
# logits block is a legal VPU/MXU operand even for MQA (rep == 1).
MIN_GROUP_ROWS = 8


def _decode_kernel(
    pos_ref, q_ref, k_ref, v_ref, kp_ref, out_ref, m_ref, l_ref, acc_ref,
    *, scale: float, window: Optional[int], softcap: Optional[float],
    bkv: int, n_kv: int, monotonic: bool,
):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0]
    k_start = ik * bkv
    # Block-level skipping needs slot order == position order; a ring cache
    # (monotonic=False) interleaves old and new positions, so every block is
    # visited and masking happens per-key.
    relevant = jnp.asarray(True)
    if monotonic:
        relevant = jnp.logical_and(relevant, k_start <= pos)
        if window is not None:
            relevant = jnp.logical_and(relevant, k_start + bkv - 1 > pos - window)

    @pl.when(relevant)
    def _():
        kp = kp_ref[0, :]                                     # [bkv] abs pos
        valid = jnp.logical_and(kp >= 0, kp <= pos)
        if window is not None:
            valid = jnp.logical_and(valid, kp > pos - window)
        q = q_ref[0, 0].astype(jnp.float32) * scale           # [rep_p, d]
        k = k_ref[0, 0].astype(jnp.float32)                   # [bkv, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                      # [rep_p, bkv]
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(valid[None, :], s, NEG_INF)

        m_prev = m_ref[:, 0]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_ref[:, 0] * alpha + jnp.sum(p, axis=-1)
        v = v_ref[0, 0].astype(jnp.float32)                   # [bkv, d]
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ik == n_kv - 1)
    def _():
        out_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        ).astype(out_ref.dtype)


def flash_decode(
    q, k, v, *, pos, kv_pos=None, window: Optional[int] = None,
    softcap: Optional[float] = None, scale: Optional[float] = None,
    bkv: int = 512, interpret: bool = False,
):
    """q [B, Hq, D] x cache k/v [B, Hkv, S, D] -> [B, Hq, D].

    ``pos`` is the query's absolute position (traced scalar is fine);
    ``kv_pos`` [S] maps cache slots to absolute positions (ring caches),
    default linear. ``bkv`` must divide the cache length S.
    """
    b, hq, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    if hq % hkv:
        raise ValueError(f"GQA requires Hq % Hkv == 0, got {hq}, {hkv}")
    n_rep = hq // hkv
    rep_p = max(n_rep, MIN_GROUP_ROWS)
    scale = scale if scale is not None else d ** -0.5
    bkv = min(bkv, s)
    if s % bkv:
        raise ValueError(f"decode tile bkv={bkv} must divide cache len {s}")
    n_kv = s // bkv

    monotonic = kv_pos is None
    if kv_pos is None:
        kv_pos = jnp.arange(s, dtype=jnp.int32)
    kp = jnp.asarray(kv_pos, jnp.int32).reshape(1, s)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)
    qg = q.reshape(b, hkv, n_rep, d)
    if rep_p != n_rep:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, rep_p - n_rep), (0, 0)))

    kernel = functools.partial(
        _decode_kernel, scale=scale, window=window, softcap=softcap,
        bkv=bkv, n_kv=n_kv, monotonic=monotonic,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, hkv, n_kv),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),             # pos [1]
            pl.BlockSpec((1, 1, rep_p, d), lambda bb, h, ik: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, bkv, d), lambda bb, h, ik: (bb, h, ik, 0)),
            pl.BlockSpec((1, 1, bkv, d), lambda bb, h, ik: (bb, h, ik, 0)),
            pl.BlockSpec((1, bkv), lambda bb, h, ik: (0, ik)),  # kv_pos
        ],
        out_specs=pl.BlockSpec((1, 1, rep_p, d), lambda bb, h, ik: (bb, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, rep_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep_p, 128), jnp.float32),   # running max
            pltpu.VMEM((rep_p, 128), jnp.float32),   # running denom
            pltpu.VMEM((rep_p, d), jnp.float32),     # output accumulator
        ],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(pos_arr, qg, k, v, kp)
    return out[:, :, :n_rep].reshape(b, hq, d)


@functools.partial(
    jax.jit,
    static_argnames=("window", "softcap", "scale", "bkv"),
)
def flash_decode_ref(
    q, k, v, *, pos, kv_pos=None, window: Optional[int] = None,
    softcap: Optional[float] = None, scale: Optional[float] = None,
    bkv: int = 512,
):
    """Chunked online-softmax decode, scanned over KV splits of ``bkv``.

    Same math as the Pallas kernel (GQA grouped contraction, no kv repeat);
    a non-dividing ``bkv`` is snapped to the largest divisor of the cache
    length — callers that care about plan fidelity check divisibility first
    (see ``models.attention.attn_decode``).
    """
    b, hq, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    n_rep = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    bkv = fit_bkv(bkv, s)
    n_kv = s // bkv
    if kv_pos is None:
        kv_pos = jnp.arange(s, dtype=jnp.int32)

    qg = q.reshape(b, hkv, n_rep, d).astype(jnp.float32) * scale
    kc = k.reshape(b, hkv, n_kv, bkv, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, n_kv, bkv, d).transpose(2, 0, 1, 3, 4)
    pc = jnp.asarray(kv_pos, jnp.int32).reshape(n_kv, bkv)

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        k_blk, v_blk, kp = xs
        s_blk = jnp.einsum(
            "bgrd,bgkd->bgrk", qg, k_blk.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )                                                  # [B,Hkv,rep,bkv]
        if softcap is not None:
            s_blk = softcap * jnp.tanh(s_blk / softcap)
        valid = jnp.logical_and(kp >= 0, kp <= pos)
        if window is not None:
            valid = jnp.logical_and(valid, kp > pos - window)
        s_blk = jnp.where(valid[None, None, None], s_blk, NEG_INF)
        m_cur = jnp.max(s_blk, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s_blk - m_new[..., None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum(
            "bgrk,bgkd->bgrd", p, v_blk.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, n_rep), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, n_rep), jnp.float32)
    acc0 = jnp.zeros((b, hkv, n_rep, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged-pool indirection (serve/pool.py). Pages hold token rows of one
# request's cache behind a page table: ``pages`` [n_pages, Hkv, page, D],
# ``page_table`` [n_pt] int32 mapping each logical page index of the request
# to its physical page id. Gather/scatter here; the attention math delegates
# to the refs above unchanged, so paged and dense lowerings cannot drift.
# ---------------------------------------------------------------------------

def paged_gather(pages, page_table):
    """Dense [1, Hkv, n_pt*page, D] cache view of one request's pages.

    Slots past the request's written length hold garbage (unallocated table
    entries point at physical page 0) — callers mask them positionally: the
    view is linear, so slot i is absolute position i and the usual
    ``kv_pos <= pos`` / ``kv_pos < start`` rules hide everything unwritten.
    """
    n_pt = page_table.shape[0]
    hkv, page, d = pages.shape[1:]
    gathered = pages[page_table]                    # [n_pt, Hkv, page, D]
    return gathered.transpose(1, 0, 2, 3).reshape(1, hkv, n_pt * page, d)


def paged_write(pages, page_table, x, start):
    """Scatter ``x`` [1, Hkv, c, D] into pages at positions
    ``start .. start+c-1`` (start may be traced — decode's ``pos``).
    Returns the updated pages array."""
    c = x.shape[2]
    page = pages.shape[2]
    idx = start + jnp.arange(c, dtype=jnp.int32)
    phys = page_table[idx // page]                  # [c] physical page ids
    offs = idx % page
    return pages.at[phys, :, offs, :].set(
        x[0].transpose(1, 0, 2).astype(pages.dtype))


def flash_decode_paged_ref(
    q, k_pages, v_pages, page_table, *, pos,
    window: Optional[int] = None, softcap: Optional[float] = None,
    scale: Optional[float] = None, bkv: int = 512,
):
    """``flash_decode_ref`` over a page-table-backed cache: gather the
    request's pages into the linear view and run the identical online
    softmax (slots beyond ``pos`` are masked by the linear position rule,
    which also hides unallocated-table garbage)."""
    k = paged_gather(k_pages, page_table)
    v = paged_gather(v_pages, page_table)
    return flash_decode_ref(q, k, v, pos=pos, window=window,
                            softcap=softcap, scale=scale, bkv=bkv)
