"""Flash attention Pallas TPU kernel with tunable (bq, bkv) block shapes.

Forward kernel: grid (B, Hq, Sq/bq, Skv/bkv) with the kv dimension innermost
("arbitrary"); online softmax carried in VMEM scratch (running max, running
denominator, f32 accumulator). Supports causal masking with a query offset,
sliding-window (local) attention, logit softcapping, and GQA via kv-head
index mapping. Fully-masked kv blocks are skipped with ``pl.when`` —
structurally visible in the lowered IR as predicated regions.

Tile roles, in the paper's terms: ``bkv`` is the lane-contiguous streaming
dimension (wide = fewer strided segments of the K/V HBM reads) and ``bq``
bounds the VMEM-resident accumulator — the same wide-first geometry as the
paper's 32x4, scaled to MXU/VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat

NEG_INF = -2.0e30


def _flash_kernel(
    q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref,
    *, scale: float, causal: bool, window: Optional[int],
    softcap: Optional[float], q_offset: int, bq: int, bkv: int, n_kv: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = q_offset + iq * bq
    k_start = ik * bkv

    # Block-level relevance: skip kv blocks entirely above the causal
    # diagonal or entirely left of the window.
    relevant = True
    if causal:
        relevant = jnp.logical_and(relevant, k_start <= q_start + bq - 1)
    if window is not None:
        relevant = jnp.logical_and(
            relevant, k_start + bkv - 1 > q_start - window
        )

    @pl.when(relevant)
    def _():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)                  # [bkv, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                     # [bq, bkv]
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = jnp.ones((bq, bkv), dtype=bool)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]                                  # [bq]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)                       # [bq]
        p = jnp.exp(s - m_new[:, None])                       # [bq, bkv]
        l_new = l_ref[:, 0] * alpha + jnp.sum(p, axis=-1)
        v = v_ref[0, 0].astype(jnp.float32)                   # [bkv, d]
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                     # [bq, d]
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ik == n_kv - 1)
    def _():
        out_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        ).astype(out_ref.dtype)


def flash_attention(
    q, k, v, *, causal: bool = True, window: Optional[int] = None,
    softcap: Optional[float] = None, scale: Optional[float] = None,
    q_offset: int = 0, tile: tuple[int, int] = (512, 512),
    interpret: bool = False,
):
    """q [B, Hq, Sq, D] x k,v [B, Hkv, Skv, D] -> [B, Hq, Sq, D]."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    if hq % hkv:
        raise ValueError(f"GQA requires Hq % Hkv == 0, got {hq}, {hkv}")
    n_rep = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    bq, bkv = min(tile[0], sq), min(tile[1], skv)
    if sq % bq or skv % bkv:
        raise ValueError(f"tile {(bq, bkv)} must divide ({sq}, {skv})")
    n_kv = skv // bkv

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, q_offset=q_offset, bq=bq, bkv=bkv, n_kv=n_kv,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, hq, sq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, h, iq, ik: (bb, h, iq, 0)),
            pl.BlockSpec(
                (1, 1, bkv, d),
                lambda bb, h, iq, ik, n_rep=n_rep: (bb, h // n_rep, ik, 0),
            ),
            pl.BlockSpec(
                (1, 1, bkv, d),
                lambda bb, h, iq, ik, n_rep=n_rep: (bb, h // n_rep, ik, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bb, h, iq, ik: (bb, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # running max (lane-bcast)
            pltpu.VMEM((bq, 128), jnp.float32),   # running denom
            pltpu.VMEM((bq, d), jnp.float32),     # output accumulator
        ],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
