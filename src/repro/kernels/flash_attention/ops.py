"""Jit'd wrapper + registry declaration for flash attention.

Problem dims: {"sq", "skv", "d", "hq", "hkv", "window"(0=none)}.
Tile rank 2 = (bq, bkv). VMEM per step: q + k + v + out tiles + f32 scratch.
"""
from __future__ import annotations

import functools
from typing import Mapping

import jax

from repro.core import registry
from repro.core.cost_model import TileWorkload
from repro.core.tiling import TileConstraints, TileShape, cdiv, dtype_bytes
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_dense_ref, flash_attention_ref


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "q_offset",
                     "tile", "interpret"),
)
def attend(q, k, v, *, causal=True, window=None, softcap=None, scale=None,
           q_offset=0, tile=(512, 512), interpret=False):
    return flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        q_offset=q_offset, tile=tile, interpret=interpret,
    )


def _constraints(problem: Mapping[str, int]) -> TileConstraints:
    return TileConstraints(
        rank=2, max_dims=(problem["sq"], problem["skv"]),
        mxu_dims=(0, 1), lane_dim=1, sublane_dim=0,
    )


def _vmem_bytes(tile: TileShape, problem: Mapping[str, int], dtype: str) -> float:
    bq, bkv = tile
    d = problem["d"]
    b = dtype_bytes(dtype)
    io_tiles = bq * d * b + 2 * bkv * d * b + bq * d * b
    scratch = bq * 128 * 4 * 2 + bq * d * 4
    logits = bq * bkv * 4  # in-register/VMEM intermediate
    return io_tiles + scratch + logits


def _workload(tile: TileShape, problem: Mapping[str, int], dtype: str) -> TileWorkload:
    bq, bkv = tile
    d = problem["d"]
    b = dtype_bytes(dtype)
    window = problem.get("window", 0)
    # Causal/window skipping halves (or more) the average visited kv blocks;
    # approximate the visited fraction analytically.
    if window:
        visit = min(1.0, (window + bkv) / problem["skv"])
    else:
        visit = 0.5 + 0.5 * bq / problem["sq"]  # causal triangle
    flops = 2.0 * bq * bkv * d * 2 * visit       # qk^T and pv
    # K/V stream dominates HBM traffic; q/out amortize over the kv loop.
    n_kv = cdiv(problem["skv"], bkv)
    hbm = (2 * bkv * d * b) * visit + (2 * bq * d * b) / n_kv
    return TileWorkload(
        flops=flops,
        hbm_bytes=hbm,
        row_segments=bkv // 8,                  # sublane segments of K stream
        row_stride_bytes=float(d * b),
        pad_waste=max(1.0, 128 / d),            # head_dim < lane pad waste
    )


def _n_tiles(tile: TileShape, problem: Mapping[str, int]) -> int:
    bq, bkv = tile
    return (
        problem["hq"] * cdiv(problem["sq"], bq) * cdiv(problem["skv"], bkv)
    )


def _default_tile(problem: Mapping[str, int], dtype: str) -> TileShape:
    bq = min(512, problem["sq"])
    bkv = min(1024, problem["skv"])
    return TileShape((bq, bkv))


registry.register(registry.KernelSpec(
    name="flash_attention",
    constraints=_constraints,
    vmem_bytes=_vmem_bytes,
    workload=_workload,
    n_tiles=_n_tiles,
    default_tile=_default_tile,
))
