"""Jit'd wrappers + registry declarations for flash attention kernels.

``flash_attention`` (full-sequence prefill/train):
    problem dims {"sq", "skv", "d", "hq", "hkv", "window"(0=none)};
    tile rank 2 = (bq, bkv). VMEM per step: q + k + v + out tiles + scratch.
``flash_decode`` (single query over a KV cache — its own plan cell, with
    its own sensitivity curve per hardware model):
    problem dims {"b", "skv", "d", "hq", "hkv", "window"(0=none)};
    tile rank 1 = (bkv,), the split-KV chunk. VMEM per step: the K/V block
    pair plus the resident grouped-query rows, stats, and logits — VMEM
    capacity is what bounds the split size per hardware model.
``chunked_prefill`` (one prompt chunk over the live KV cache — the serving
    scheduler's sub-launch unit; see kernels/flash_attention/chunked.py):
    problem dims {"sq", "skv", "d", "hq", "hkv", "window"(0=none)} where
    ``sq`` is the whole admitted prompt length;
    tile rank 2 = (chunk, bkv) — the chunk length is a first-class tile
    axis. One grid step is one whole chunk (queries resident, K/V streamed
    in ``bkv`` splits), so VMEM capacity bounds the chunk per hardware
    model and the per-chunk fixed dispatch cost penalizes tiny chunks:
    different hardware models compile different chunk lengths for the same
    prompt.
``packed_prefill`` (N requests' chunks segment-concatenated into ONE
    launch — the step-packing unit; see flash_prefill_packed_ref):
    problem dims {"sq", "skv", "d", "hq", "hkv", "window"(0=none)} where
    ``sq`` is the segment class (the bucket edge the packed short prompts
    belong to); tile rank 2 = (pack, bkv) — ``pack`` is the PACK WIDTH,
    the total packed chunk tokens resident in one step, which may exceed
    ``sq`` (that is the point: several sq-length segments ride one
    launch). The cell models serving a fixed round of PACK_ROUND_SEGS
    segments in ceil(round/pack) packed steps, each paying one fixed
    dispatch cost, so wider packs amortize dispatch while VMEM capacity
    bounds the resident pack per hardware model: different models compile
    different pack widths for the same bucket set.
"""
from __future__ import annotations

import functools
from typing import Mapping

import jax

from repro.core import registry
from repro.core.cost_model import DRAM_PAGE_BYTES, TileWorkload
from repro.core.tiling import TileConstraints, TileShape, cdiv, dtype_bytes
from repro.kernels.flash_attention.decode import MIN_GROUP_ROWS, flash_decode
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_dense_ref, flash_attention_ref


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "q_offset",
                     "tile", "interpret"),
)
def attend(q, k, v, *, causal=True, window=None, softcap=None, scale=None,
           q_offset=0, tile=(512, 512), interpret=False):
    return flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        q_offset=q_offset, tile=tile, interpret=interpret,
    )


def _constraints(problem: Mapping[str, int]) -> TileConstraints:
    return TileConstraints(
        rank=2, max_dims=(problem["sq"], problem["skv"]),
        mxu_dims=(0, 1), lane_dim=1, sublane_dim=0,
    )


def _vmem_bytes(tile: TileShape, problem: Mapping[str, int], dtype: str) -> float:
    bq, bkv = tile
    d = problem["d"]
    b = dtype_bytes(dtype)
    io_tiles = bq * d * b + 2 * bkv * d * b + bq * d * b
    scratch = bq * 128 * 4 * 2 + bq * d * 4
    logits = bq * bkv * 4  # in-register/VMEM intermediate
    return io_tiles + scratch + logits


def _workload(tile: TileShape, problem: Mapping[str, int], dtype: str) -> TileWorkload:
    bq, bkv = tile
    d = problem["d"]
    b = dtype_bytes(dtype)
    window = problem.get("window", 0)
    # Causal/window skipping halves (or more) the average visited kv blocks;
    # approximate the visited fraction analytically.
    if window:
        visit = min(1.0, (window + bkv) / problem["skv"])
    else:
        visit = 0.5 + 0.5 * bq / problem["sq"]  # causal triangle
    flops = 2.0 * bq * bkv * d * 2 * visit       # qk^T and pv
    # K/V stream dominates HBM traffic; q/out amortize over the kv loop.
    n_kv = cdiv(problem["skv"], bkv)
    hbm = (2 * bkv * d * b) * visit + (2 * bq * d * b) / n_kv
    return TileWorkload(
        flops=flops,
        hbm_bytes=hbm,
        row_segments=bkv // 8,                  # sublane segments of K stream
        row_stride_bytes=float(d * b),
        pad_waste=max(1.0, 128 / d),            # head_dim < lane pad waste
    )


def _n_tiles(tile: TileShape, problem: Mapping[str, int]) -> int:
    bq, bkv = tile
    return (
        problem["hq"] * cdiv(problem["sq"], bq) * cdiv(problem["skv"], bkv)
    )


def _default_tile(problem: Mapping[str, int], dtype: str) -> TileShape:
    bq = min(512, problem["sq"])
    bkv = min(1024, problem["skv"])
    return TileShape((bq, bkv))


registry.register(registry.KernelSpec(
    name="flash_attention",
    constraints=_constraints,
    vmem_bytes=_vmem_bytes,
    workload=_workload,
    n_tiles=_n_tiles,
    default_tile=_default_tile,
))


# ---------------------------------------------------------------------------
# flash_decode: split-KV decode attention (one query over the cache).
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("window", "softcap", "scale", "bkv", "interpret"),
)
def attend_decode(q, k, v, *, pos, kv_pos=None, window=None, softcap=None,
                  scale=None, bkv=512, interpret=False):
    return flash_decode(
        q, k, v, pos=pos, kv_pos=kv_pos, window=window, softcap=softcap,
        scale=scale, bkv=bkv, interpret=interpret,
    )


def _group_rows(problem: Mapping[str, int]) -> int:
    """Resident grouped-query rows per KV head, as the kernel pads them."""
    return max(problem["hq"] // max(problem["hkv"], 1), MIN_GROUP_ROWS)


def _decode_constraints(problem: Mapping[str, int]) -> TileConstraints:
    # bkv is the lane dim of the [rep, bkv] logits block and the N dim of
    # the q @ k^T MXU op; it wants lane (128) multiples.
    return TileConstraints(
        rank=1, max_dims=(problem["skv"],), mxu_dims=(0,), lane_dim=0,
    )


def _decode_vmem_bytes(tile: TileShape, problem: Mapping[str, int],
                       dtype: str) -> float:
    bkv = tile[0]
    d = problem["d"]
    b = dtype_bytes(dtype)
    rep_p = _group_rows(problem)
    kv_tiles = 2 * bkv * d * b                  # the streamed K and V blocks
    resident = 2 * rep_p * d * b                # grouped q rows + out block
    scratch = rep_p * 128 * 4 * 2 + rep_p * d * 4
    logits = rep_p * bkv * 4
    return kv_tiles + resident + scratch + logits


def _decode_workload(tile: TileShape, problem: Mapping[str, int],
                     dtype: str) -> TileWorkload:
    bkv = tile[0]
    d = problem["d"]
    b = dtype_bytes(dtype)
    rep = max(problem["hq"] // max(problem["hkv"], 1), 1)
    window = problem.get("window", 0)
    # Decode visits every key up to ``pos`` (~ the whole cache in steady
    # state); a sliding window bounds the visited fraction like prefill.
    if window:
        visit = min(1.0, (window + bkv) / problem["skv"])
    else:
        visit = 1.0
    n_kv = cdiv(problem["skv"], bkv)
    flops = 2.0 * rep * bkv * d * 2 * visit          # qk^T and pv
    # K/V stream dominates; the resident q/out block amortizes over the KV
    # loop; each grid step re-issues the two stream DMAs (descriptor setup
    # ~ one DRAM page each) — the fixed per-split cost that makes tiny bkv
    # lose even though the streamed bytes are identical.
    rep_p = _group_rows(problem)
    hbm = (
        2 * bkv * d * b * visit
        + (2 * rep_p * d * b) / n_kv
        + 2 * DRAM_PAGE_BYTES
    )
    return TileWorkload(
        flops=flops,
        hbm_bytes=hbm,
        row_segments=bkv // 8,
        row_stride_bytes=float(d * b),
        pad_waste=max(1.0, 8 / rep) * max(1.0, 128 / d),
    )


def _decode_n_tiles(tile: TileShape, problem: Mapping[str, int]) -> int:
    return problem["b"] * problem["hkv"] * cdiv(problem["skv"], tile[0])


def _decode_default_tile(problem: Mapping[str, int], dtype: str) -> TileShape:
    return TileShape((min(512, problem["skv"]),))


registry.register(registry.KernelSpec(
    name="flash_decode",
    constraints=_decode_constraints,
    vmem_bytes=_decode_vmem_bytes,
    workload=_decode_workload,
    n_tiles=_decode_n_tiles,
    default_tile=_decode_default_tile,
))


# ---------------------------------------------------------------------------
# chunked_prefill: one prompt chunk attending over the live KV cache.
# ---------------------------------------------------------------------------

# Fixed per-chunk dispatch cost, in DRAM pages: every chunk is a separate
# engine step (scheduler bookkeeping, program re-entry, cache-pointer DMA
# descriptors), so halving the chunk doubles this term while the streamed
# KV bytes stay constant. It is what makes degenerate tiny chunks lose the
# sweep even on overhead-free TPU descriptors.
CHUNK_STEP_PAGES = 256


def _chunked_constraints(problem: Mapping[str, int]) -> TileConstraints:
    # dim 0 = chunk length (the resident query block; sublane-tiled rows of
    # the logits block, MXU M dim), dim 1 = bkv (lane dim / MXU N dim).
    return TileConstraints(
        rank=2, max_dims=(problem["sq"], problem["skv"]),
        mxu_dims=(0, 1), lane_dim=1, sublane_dim=0,
    )


def _chunked_vmem_bytes(tile: TileShape, problem: Mapping[str, int],
                        dtype: str) -> float:
    chunk, bkv = tile
    d = problem["d"]
    b = dtype_bytes(dtype)
    resident = chunk * d * b + chunk * d * 4      # q block + f32 accumulator
    kv_tiles = 2 * bkv * d * b                    # streamed K and V blocks
    scratch = chunk * 128 * 4 * 2                 # running max / denominator
    logits = chunk * bkv * 4
    return resident + kv_tiles + scratch + logits


def _chunked_workload(tile: TileShape, problem: Mapping[str, int],
                      dtype: str) -> TileWorkload:
    chunk, bkv = tile
    sq, d = problem["sq"], problem["d"]
    b = dtype_bytes(dtype)
    window = problem.get("window", 0)
    # One grid step = one whole chunk: its queries stay resident while the
    # visible KV prefix streams once (shared across all chunk rows). The
    # average visible prefix over the chunks of one prompt:
    if window:
        visit = float(min(window + chunk, sq))
    else:
        visit = (sq + chunk) / 2.0
    # Causal masking halves the MAC work per query irrespective of the
    # chunk decomposition (inner tiles skip fully-masked blocks), so FLOPs
    # are chunk-independent per token: 4*d per (query, visible key) pair.
    flops = 4.0 * chunk * (sq / 2.0 if not window else visit) * d
    hbm = (
        2 * visit * d * b                    # K/V stream, shared by the chunk
        + 2 * chunk * d * b                  # q in / out write
        + CHUNK_STEP_PAGES * DRAM_PAGE_BYTES  # per-chunk dispatch (see above)
    )
    return TileWorkload(
        flops=flops,
        hbm_bytes=hbm,
        row_segments=bkv // 8,
        row_stride_bytes=float(d * b),
        pad_waste=max(1.0, 128 / d),
    )


def _chunked_n_tiles(tile: TileShape, problem: Mapping[str, int]) -> int:
    return problem["hq"] * cdiv(problem["sq"], tile[0])


def _chunked_default_tile(problem: Mapping[str, int], dtype: str) -> TileShape:
    return TileShape((min(512, problem["sq"]), min(512, problem["skv"])))


registry.register(registry.KernelSpec(
    name="chunked_prefill",
    constraints=_chunked_constraints,
    vmem_bytes=_chunked_vmem_bytes,
    workload=_chunked_workload,
    n_tiles=_chunked_n_tiles,
    default_tile=_chunked_default_tile,
))


# ---------------------------------------------------------------------------
# packed_prefill: N requests' chunks segment-concatenated into one launch.
# ---------------------------------------------------------------------------

# The fixed workload one packed cell is scored against: a round of this many
# sq-length segments (short prompts of the bucket class), served in
# ceil(round/pack) packed steps. A fixed round makes scores comparable
# across pack widths — the tile changes how the round is decomposed, not
# how much work it is (mirroring chunked_prefill's whole-prompt scoring).
PACK_ROUND_SEGS = 8

# Fixed per-packed-step dispatch cost, in DRAM pages: one scheduler pick +
# program re-entry + per-segment cache-pointer descriptors per step,
# regardless of how many segments ride it. Packing exists to amortize this
# over more chunk tokens per step.
PACK_STEP_PAGES = 256


def _packed_constraints(problem: Mapping[str, int]) -> TileConstraints:
    # dim 0 = pack width (resident packed query tokens; sublane-tiled rows,
    # MXU M dim) — bounded by the whole round, NOT by sq: pack > sq is the
    # multi-segment case. dim 1 = bkv (lane dim / MXU N dim).
    return TileConstraints(
        rank=2,
        max_dims=(PACK_ROUND_SEGS * problem["sq"], problem["skv"]),
        mxu_dims=(0, 1), lane_dim=1, sublane_dim=0,
    )


def _packed_vmem_bytes(tile: TileShape, problem: Mapping[str, int],
                       dtype: str) -> float:
    pack, bkv = tile
    d = problem["d"]
    b = dtype_bytes(dtype)
    resident = pack * d * b + pack * d * 4        # q block + f32 accumulator
    kv_tiles = 2 * bkv * d * b                    # streamed K and V blocks
    scratch = pack * 128 * 4 * 2                  # running max / denominator
    logits = pack * bkv * 4
    return resident + kv_tiles + scratch + logits


def _packed_workload(tile: TileShape, problem: Mapping[str, int],
                     dtype: str) -> TileWorkload:
    pack, bkv = tile
    sq, d = problem["sq"], problem["d"]
    b = dtype_bytes(dtype)
    window = problem.get("window", 0)
    # Each packed token belongs to an sq-length segment and attends its own
    # causal prefix (avg sq/2; window-bounded when set) — segment masking
    # means packing never adds cross-segment MACs.
    visible = float(min(window, sq)) if window else sq / 2.0
    flops = 4.0 * pack * visible * d
    # Per step: every resident segment streams its own visible KV prefix
    # ((pack/sq) segments x avg prefix), the packed q/out block moves once,
    # each KV split re-issues its stream descriptors, and ONE fixed
    # dispatch cost covers the whole step — the term wider packs amortize.
    n_segs = max(1.0, pack / sq)
    hbm = (
        n_segs * 2.0 * visible * d * b            # per-segment K/V streams
        + 2 * pack * d * b                        # packed q in / out write
        + 2 * DRAM_PAGE_BYTES * cdiv(problem["skv"], bkv)
        + PACK_STEP_PAGES * DRAM_PAGE_BYTES       # per-step dispatch
    )
    return TileWorkload(
        flops=flops,
        hbm_bytes=hbm,
        row_segments=bkv // 8,
        row_stride_bytes=float(d * b),
        pad_waste=max(1.0, 128 / d),
    )


def _packed_n_tiles(tile: TileShape, problem: Mapping[str, int]) -> int:
    # Steps to serve the fixed round of segments, per query head.
    return problem["hq"] * cdiv(PACK_ROUND_SEGS * problem["sq"], tile[0])


def _packed_default_tile(problem: Mapping[str, int], dtype: str) -> TileShape:
    pack = min(1024, PACK_ROUND_SEGS * problem["sq"])
    return TileShape((pack, min(512, problem["skv"])))


registry.register(registry.KernelSpec(
    name="packed_prefill",
    constraints=_packed_constraints,
    vmem_bytes=_packed_vmem_bytes,
    workload=_packed_workload,
    n_tiles=_packed_n_tiles,
    default_tile=_packed_default_tile,
))


# ---------------------------------------------------------------------------
# kv_page: the KV-cache page size of the paged pool (serve/pool.py).
# ---------------------------------------------------------------------------
#
# Page geometry is a tile axis, not a constant: a decode/prefill step stages
# one K page + one V page (across all kv heads) in VMEM while streaming the
# cache, so VMEM capacity bounds the page per hardware model exactly the way
# it bounds ``bkv`` — and every page transfer pays a fixed descriptor cost
# that penalizes tiny pages, while the last page of a request wastes
# (page - len % page) slots of HBM, amortized over how often the page is
# re-read. Net: cost decreases with page size until the VMEM budget binds,
# so models with different VMEM (v5e 16 MiB vs v6e 32 MiB) resolve
# different page sizes for the same cache geometry (goldens in
# tests/test_plans.py).
#     problem dims {"skv", "d", "hkv"}: cache length, head dim, kv heads.
#     tile rank 1 = (page,), the pool's page length in tokens.


def _kv_page_constraints(problem: Mapping[str, int]) -> TileConstraints:
    # A page is DMA granularity (token rows of the K/V stream), not an MXU
    # operand: it wants lane (128) multiples, nothing else.
    return TileConstraints(
        rank=1, max_dims=(problem["skv"],), lane_dim=0,
    )


def _kv_page_vmem_bytes(tile: TileShape, problem: Mapping[str, int],
                        dtype: str) -> float:
    page = tile[0]
    d, hkv = problem["d"], problem["hkv"]
    b = dtype_bytes(dtype)
    # One K page + one V page staged across all kv heads, plus the page
    # table rows resolving this cache (int32 per page).
    return 2 * page * hkv * d * b + cdiv(problem["skv"], page) * 4


def _kv_page_workload(tile: TileShape, problem: Mapping[str, int],
                      dtype: str) -> TileWorkload:
    page = tile[0]
    d, hkv, skv = problem["d"], problem["hkv"], problem["skv"]
    b = dtype_bytes(dtype)
    # Copy/accumulate through the page, sub-dominant to the stream.
    flops = 2.0 * page * hkv * d
    hbm = (
        2 * page * hkv * d * b            # the K and V page bytes
        + 2 * DRAM_PAGE_BYTES             # per-page stream descriptors
        # Allocation waste: a request's tail page holds on average page/2
        # dead slots; their bytes re-cross HBM once per full cache read,
        # amortized over the skv tokens each read covers.
        + page * hkv * d * b / (2.0 * max(skv, 1))
    )
    return TileWorkload(
        flops=flops,
        hbm_bytes=hbm,
        row_segments=page // 8,
        row_stride_bytes=float(d * b),
        pad_waste=max(1.0, 128 / d),
    )


def _kv_page_n_tiles(tile: TileShape, problem: Mapping[str, int]) -> int:
    return cdiv(problem["skv"], tile[0])


def _kv_page_default_tile(problem: Mapping[str, int], dtype: str) -> TileShape:
    return TileShape((min(512, problem["skv"]),))


registry.register(registry.KernelSpec(
    name="kv_page",
    constraints=_kv_page_constraints,
    vmem_bytes=_kv_page_vmem_bytes,
    workload=_kv_page_workload,
    n_tiles=_kv_page_n_tiles,
    default_tile=_kv_page_default_tile,
))
