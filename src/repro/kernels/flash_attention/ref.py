"""Reference attention implementations.

``attention_dense_ref`` — O(S^2) materialized oracle, small shapes only;
    the ground truth for kernel and chunked-reference tests.
``flash_attention_ref`` — chunked online-softmax in pure lax.scan. Same
    math as the Pallas kernel, differentiable, memory O(S * chunk). This is
    also the path the distributed model lowers on non-TPU backends (Pallas
    TPU kernels cannot lower to host HLO), so the dry-run's HLO reflects a
    flash-style memory footprint rather than a naive S^2 one.

Shared semantics: q [B, Hq, Sq, D], k/v [B, Hkv, Skv, D] with Hq % Hkv == 0
(GQA broadcast), optional causal mask with ``q_offset`` (decode: queries
start at position ``q_offset``), optional sliding ``window`` (attend to
keys with q_pos - window < k_pos <= q_pos), optional logit ``softcap``
(gemma2: s = cap * tanh(s / cap)).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import flags

NEG_INF = -2.0e30


def fit_bkv(bkv: int, s: int) -> int:
    """Clamp then snap a KV chunk to the largest divisor of ``s`` <= it.

    The single source of truth for the chunk a reference lowering actually
    runs when a requested (plan) chunk does not divide the sequence — the
    tile-event ``effective`` fields in ``models.attention`` report exactly
    this value.
    """
    bkv = min(int(bkv), s)
    if s % bkv:
        bkv = next(c for c in range(bkv, 0, -1) if s % c == 0)
    return bkv


def _logits_mask(q_pos, k_pos, causal: bool, window: Optional[int]):
    """[Sq, Skv] boolean mask of *visible* positions."""
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    return mask


def _expand_gqa(k, n_rep: int):
    if n_rep == 1:
        return k
    b, h, s, d = k.shape
    return jnp.broadcast_to(k[:, :, None], (b, h, n_rep, s, d)).reshape(
        b, h * n_rep, s, d
    )


def attention_dense_ref(
    q, k, v, *, causal: bool = True, window: Optional[int] = None,
    softcap: Optional[float] = None, scale: Optional[float] = None,
    q_offset: int = 0,
):
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    k = _expand_gqa(k, hq // hkv)
    v = _expand_gqa(v, hq // hkv)
    scale = scale if scale is not None else d ** -0.5

    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32
    ) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(skv)
    mask = _logits_mask(q_pos, k_pos, causal, window)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum(
        "bhqk,bhkd->bhqd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    ).astype(q.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "q_offset", "chunk"),
)
def flash_attention_ref(
    q, k, v, *, causal: bool = True, window: Optional[int] = None,
    softcap: Optional[float] = None, scale: Optional[float] = None,
    q_offset: int = 0, chunk: int = 512,
):
    """Online-softmax attention, scanned over kv chunks."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    n_rep = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    # Largest divisor of skv <= requested chunk (e.g. whisper's 1500
    # encoder frames with a 512 request -> 375).
    chunk = fit_bkv(chunk, skv)
    n_chunks = skv // chunk

    # GQA: repeat kv up to the q-head count. jnp.repeat partitions cleanly
    # when heads are sharded (it is a gather along the head axis), unlike a
    # [b, hkv, rep, ...] grouping reshape which splits the sharded axis.
    if n_rep > 1:
        k = jnp.repeat(k, n_rep, axis=1)
        v = jnp.repeat(v, n_rep, axis=1)

    compute_dtype = (q.dtype if flags.ATTN_COMPUTE_BF16 else jnp.float32)
    qf = q.astype(compute_dtype) * jnp.asarray(scale, compute_dtype)
    q_pos = q_offset + jnp.arange(sq)

    # [n_chunks, ...] leading-axis chunking for scan.
    kc = k.reshape(b, hq, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hq, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        idx, k_blk, v_blk = xs
        k_pos = idx * chunk + jnp.arange(chunk)
        s = jnp.einsum(
            "bhqd,bhkd->bhqk", qf, k_blk.astype(qf.dtype),
            preferred_element_type=jnp.float32,
        )
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = _logits_mask(q_pos, k_pos, causal, window)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(compute_dtype),
            v_blk.astype(compute_dtype),
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hq, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    acc0 = jnp.zeros((b, hq, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (jnp.arange(n_chunks), kc, vc),
        unroll=flags.scan_unroll(),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)
