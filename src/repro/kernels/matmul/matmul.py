"""Tiled matmul Pallas TPU kernel with tunable (bm, bk, bn) block shapes.

The canonical MXU kernel: grid (m/bm, n/bn, k/bk) with the contraction
dimension innermost ("arbitrary" semantics), f32 accumulator in VMEM scratch,
cast on the final k step. The (bm, bk, bn) space is registered with the
tile autotuner — the LM stack asks the TilingPolicy for block shapes instead
of hard-coding them (the paper's methodology as infrastructure).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat


def _matmul_kernel(a_ref, b_ref, out_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    tile: tuple[int, int, int] = (256, 512, 256),
    out_dtype=None,
    interpret: bool = False,
) -> jnp.ndarray:
    """``a`` [M, K] @ ``b`` [K, N] -> [M, N] with block shapes (bm, bk, bn)."""
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad matmul shapes {a.shape} @ {b.shape}")
    m, k = a.shape
    _, n = b.shape
    out_dtype = out_dtype or a.dtype
    bm, bk, bn = (min(t, s) for t, s in zip(tile, (m, k, n)))
    if m % bm or k % bk or n % bn:
        raise ValueError(f"tile {(bm, bk, bn)} must divide problem {(m, k, n)}")

    n_k = k // bk
    kernel = functools.partial(_matmul_kernel, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)
