"""Jit'd wrapper + registry declaration for the tiled matmul kernel.

Problem dims: {"m", "k", "n"}. Tile rank 3 = (bm, bk, bn). The VMEM working
set per grid step is a(bm,bk) + b(bk,bn) + out(bm,bn) + acc f32(bm,bn) — the
TPU analogue of the paper's threads-per-block legality bound.
"""
from __future__ import annotations

import functools
from typing import Mapping

import jax

from repro.core import registry
from repro.core.cost_model import TileWorkload
from repro.core.tiling import TileConstraints, TileShape, cdiv, dtype_bytes, round_up
from repro.kernels.matmul.matmul import matmul
from repro.kernels.matmul.ref import matmul_ref


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def mm(a, b, tile=(256, 512, 256), interpret: bool = False):
    return matmul(a, b, tile=tile, interpret=interpret)


def _constraints(problem: Mapping[str, int]) -> TileConstraints:
    m, k, n = problem["m"], problem["k"], problem["n"]
    return TileConstraints(
        rank=3, max_dims=(m, k, n),
        mxu_dims=(0, 1, 2), lane_dim=2, sublane_dim=0,
    )


def _vmem_bytes(tile: TileShape, problem: Mapping[str, int], dtype: str) -> float:
    bm, bk, bn = tile
    b = dtype_bytes(dtype)
    return bm * bk * b + bk * bn * b + bm * bn * b + bm * bn * 4  # + f32 acc


def _workload(tile: TileShape, problem: Mapping[str, int], dtype: str) -> TileWorkload:
    bm, bk, bn = tile
    b = dtype_bytes(dtype)
    # MXU padding waste if block dims are not multiples of the MXU dim is
    # handled via pad_waste at sweep time using the lane count as a proxy.
    waste_m = round_up(bm, 8) / bm
    waste_n = round_up(bn, 128) / bn
    return TileWorkload(
        flops=2.0 * bm * bk * bn,
        hbm_bytes=float((bm * bk + bk * bn) * b)
        + float(bm * bn * b) / max(1, problem["k"] // bk),
        row_segments=bm,                      # A-tile rows (strided when bk < k)
        row_stride_bytes=float(problem["k"] * b),
        pad_waste=waste_m * waste_n,
    )


def _n_tiles(tile: TileShape, problem: Mapping[str, int]) -> int:
    bm, bk, bn = tile
    return (
        cdiv(problem["m"], bm) * cdiv(problem["k"], bk) * cdiv(problem["n"], bn)
    )


def _default_tile(problem: Mapping[str, int], dtype: str) -> TileShape:
    m, k, n = problem["m"], problem["k"], problem["n"]
    # Wide-minor-first heuristic (the 32x4 principle, MXU-scaled): large bn
    # for lane contiguity, bm sized to keep the f32 accumulator modest, bk
    # grown to amortize the accumulator over more MXU work.
    bn = min(512, n)
    bm = min(256, m)
    bk = min(512, k)
    return TileShape((bm, bk, bn))


registry.register(registry.KernelSpec(
    name="matmul",
    constraints=_constraints,
    vmem_bytes=_vmem_bytes,
    workload=_workload,
    n_tiles=_n_tiles,
    default_tile=_default_tile,
))
