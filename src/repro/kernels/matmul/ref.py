"""Pure-jnp oracle for the tiled matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray, out_dtype=None) -> jnp.ndarray:
    """a [M, K] @ b [K, N] with f32 accumulation, cast to ``out_dtype``."""
    out_dtype = out_dtype or a.dtype
    acc = jnp.dot(a, b, preferred_element_type=jnp.float32)
    return acc.astype(out_dtype)
