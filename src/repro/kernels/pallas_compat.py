"""Version shims for the Pallas TPU API surface the kernels use.

The kernels target the current API (``pltpu.CompilerParams``); older jax
releases (< 0.6) expose the same dataclass as ``pltpu.TPUCompilerParams``.
Resolving the name at import time keeps every kernel source identical across
environments instead of gating each call site.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
