"""Jit'd wrapper + registry declaration for the RG-LRU scan kernel.

Problem dims: {"s", "f"} (per batch element). Tile rank 2 = (bt, bf).
"""
from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp

from repro.core import registry
from repro.core.cost_model import TileWorkload
from repro.core.tiling import TileConstraints, TileShape, cdiv, dtype_bytes
from repro.kernels.rglru.ref import rglru_ref
from repro.kernels.rglru.rglru import rglru_scan


@functools.partial(jax.jit, static_argnames=("c", "tile", "interpret"))
def rglru(x, r, i, a_param, h0=None, c: float = 8.0,
          tile=(128, 512), interpret: bool = False):
    """Full RG-LRU: gate math in jnp (fused by XLA), scan in Pallas."""
    b, s, f = x.shape
    log_a = -c * jax.nn.softplus(a_param)[None, None, :] * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    inp = beta * (i * x)
    h0 = jnp.zeros((b, f), x.dtype) if h0 is None else h0
    return rglru_scan(a.astype(x.dtype), inp.astype(x.dtype), h0,
                      tile=tile, interpret=interpret)


def _constraints(problem: Mapping[str, int]) -> TileConstraints:
    return TileConstraints(
        rank=2, max_dims=(problem["s"], problem["f"]),
        lane_dim=1, sublane_dim=0,
    )


def _vmem_bytes(tile: TileShape, problem: Mapping[str, int], dtype: str) -> float:
    bt, bf = tile
    b = dtype_bytes(dtype)
    return 2 * bt * bf * b + bt * bf * b + 2 * bf * 4  # a,x in + y out + state


def _workload(tile: TileShape, problem: Mapping[str, int], dtype: str) -> TileWorkload:
    bt, bf = tile
    b = dtype_bytes(dtype)
    return TileWorkload(
        flops=2.0 * bt * bf,                  # fma per element
        hbm_bytes=3.0 * bt * bf * b,          # read a, x; write y
        row_segments=bt,                      # one DMA row per time step
        row_stride_bytes=float(problem["f"] * b),
        pad_waste=1.0,
    )


def _n_tiles(tile: TileShape, problem: Mapping[str, int]) -> int:
    bt, bf = tile
    return cdiv(problem["s"], bt) * cdiv(problem["f"], bf)


def _default_tile(problem: Mapping[str, int], dtype: str) -> TileShape:
    return TileShape((min(128, problem["s"]), min(1024, problem["f"])))


registry.register(registry.KernelSpec(
    name="rglru",
    constraints=_constraints,
    vmem_bytes=_vmem_bytes,
    workload=_workload,
    n_tiles=_n_tiles,
    default_tile=_default_tile,
))
