"""Pure-jnp oracle for the RG-LRU (Real-Gated Linear Recurrent Unit).

Griffin / RecurrentGemma recurrence (arXiv:2402.19427 eq. 3-4):

    r_t = sigmoid(x_t @ W_r + b_r)            (recurrence gate, computed outside)
    i_t = sigmoid(x_t @ W_i + b_i)            (input gate, computed outside)
    log_a_t = -c * softplus(Lambda) * r_t     (c = 8)
    a_t = exp(log_a_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The kernel consumes precomputed gates (the matmuls belong to the matmul
kernel); its job is the sequential scan, which is the memory-bound hot loop
the Griffin authors hand-wrote a Pallas kernel for.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import flags


def rglru_ref(x, r, i, a_param, h0=None, c: float = 8.0):
    """x, r, i: [B, S, F]; a_param (Lambda): [F]; h0: [B, F] or None.

    Returns (y [B, S, F], h_final [B, F]). In analysis mode the linear
    recurrence runs as an associative scan (no while loop, so XLA cost
    analysis counts its work; ~2x the flops of the sequential scan, which is
    the honest TPU lowering trade-off anyway).
    """
    b, s, f = x.shape
    log_a = -c * jax.nn.softplus(a_param)[None, None, :] * r  # [B, S, F]
    a = jnp.exp(log_a)
    # Multiply by sqrt(1 - a^2) for variance preservation (Griffin eq. 4).
    gated_x = i * x
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    inp = beta * gated_x

    h0 = jnp.zeros((b, f), x.dtype) if h0 is None else h0
    af = a.astype(jnp.float32)
    xf = inp.astype(jnp.float32)

    if flags.ANALYSIS_UNROLL:
        # Fold h0 into the first input: x_1' = x_1 + a_1 * h_0, then run an
        # associative scan: (a2, x2) o (a1, x1) = (a1*a2, a2*x1 + x2).
        x1 = xf[:, :1] + af[:, :1] * h0.astype(jnp.float32)[:, None]
        xh = jnp.concatenate([x1, xf[:, 1:]], axis=1)

        def combine(left, right):
            al, xl = left
            ar, xr = right
            return al * ar, ar * xl + xr

        _, y = jax.lax.associative_scan(combine, (af, xh), axis=1)
        h_last = y[:, -1]
        return y.astype(x.dtype), h_last.astype(x.dtype)

    def step(h, xs):
        a_t, in_t = xs
        h_new = a_t * h + in_t
        return h_new, h_new

    h_last, ys = jax.lax.scan(
        step,
        h0.astype(jnp.float32),
        (af.transpose(1, 0, 2), xf.transpose(1, 0, 2)),
    )
    return ys.transpose(1, 0, 2).astype(x.dtype), h_last.astype(x.dtype)
