"""RG-LRU linear-recurrence Pallas TPU kernel, tunable (bt, bf).

The recurrence is elementwise over features and sequential over time — a
pure VPU/bandwidth workload. The kernel streams (time-block x feature-block)
tiles through VMEM while the recurrent state h stays VMEM-resident per
feature block; time is scanned with an in-kernel fori_loop over the tile's
rows. Tiles:

    bt — time rows per DMA (amortizes HBM descriptor cost; the paper's
         "wide tile" axis: the feature dim is lane-contiguous),
    bf — features per block (bounds the VMEM-resident state slice).

Grid: (B, F/bf, S/bt) with time innermost (carries state in scratch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat


def _rglru_kernel(a_ref, x_ref, h0_ref, y_ref, hout_ref, h_ref, *, bt: int, n_t: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)   # [bt, bf] decay
    x = x_ref[0].astype(jnp.float32)   # [bt, bf] pre-gated input

    def step(t, h):
        h_new = a[t] * h + x[t]
        y_ref[0, t, :] = h_new.astype(y_ref.dtype)
        return h_new

    h = jax.lax.fori_loop(0, bt, step, h_ref[0])
    h_ref[...] = h[None]

    @pl.when(it == n_t - 1)
    def _():
        hout_ref[0] = h.astype(hout_ref.dtype)


def rglru_scan(
    a: jnp.ndarray,
    x: jnp.ndarray,
    h0: jnp.ndarray,
    tile: tuple[int, int] = (128, 512),
    interpret: bool = False,
):
    """Scan h_t = a_t * h_{t-1} + x_t.

    a, x: [B, S, F] (decay and pre-gated input); h0: [B, F].
    Returns (y [B, S, F], h_final [B, F]).
    """
    b, s, f = a.shape
    bt, bf = min(tile[0], s), min(tile[1], f)
    if s % bt or f % bf:
        raise ValueError(f"tile {(bt, bf)} must divide ({s}, {f})")
    n_t = s // bt

    kernel = functools.partial(_rglru_kernel, bt=bt, n_t=n_t)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(b, f // bf, n_t),
        in_specs=[
            pl.BlockSpec((1, bt, bf), lambda bb, jf, it: (bb, it, jf)),
            pl.BlockSpec((1, bt, bf), lambda bb, jf, it: (bb, it, jf)),
            pl.BlockSpec((1, bf), lambda bb, jf, it: (bb, jf)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, bf), lambda bb, jf, it: (bb, it, jf)),
            pl.BlockSpec((1, bf), lambda bb, jf, it: (bb, jf)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, f), x.dtype),
            jax.ShapeDtypeStruct((b, f), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, bf), jnp.float32)],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, x, h0)
    return y, h_last
