"""Jit'd wrapper + registry declaration for the SSD kernel.

Problem dims: {"s", "h", "p", "n"}. Tile rank 1 = (chunk,).
"""
from __future__ import annotations

import functools
from typing import Mapping, Optional

import jax
import jax.numpy as jnp

from repro.core import registry
from repro.core.cost_model import TileWorkload
from repro.core.tiling import TileConstraints, TileShape, cdiv, dtype_bytes
from repro.kernels.ssd.ref import ssd_chunked_ref, ssd_ref
from repro.kernels.ssd.ssd import ssd_scan


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, Bm, C, D=None, h0=None, chunk: int = 128,
        interpret: bool = False):
    """Full SSD op: discretization in jnp, chunk scan in Pallas."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    dtf = dt.astype(jnp.float32)
    log_a = (dtf * A[None, None, :]).transpose(0, 2, 1)   # [B, H, S]
    dtx = dtf[..., None] * x.astype(jnp.float32)
    h0 = jnp.zeros((b, h, n, p), x.dtype) if h0 is None else h0
    y, h_last = ssd_scan(
        log_a.astype(x.dtype), dtx.astype(x.dtype), Bm, C, h0,
        chunk=chunk, interpret=interpret,
    )
    if D is not None:
        y = y + (D[None, None, :, None] * x.astype(jnp.float32)).astype(y.dtype)
    return y, h_last


def _constraints(problem: Mapping[str, int]) -> TileConstraints:
    return TileConstraints(
        rank=1, max_dims=(problem["s"],), mxu_dims=(0,),
        lane_dim=0, sublane_dim=None,
    )


def _vmem_bytes(tile: TileShape, problem: Mapping[str, int], dtype: str) -> float:
    (q,) = tile
    p, n = problem["p"], problem["n"]
    b = dtype_bytes(dtype)
    io = q * b + q * p * b + 2 * q * n * b + q * p * b   # la, x, Bm, C, y
    state = 2 * n * p * 4
    logits = 2 * q * q * 4                                # cb + decay
    return io + state + logits


def _workload(tile: TileShape, problem: Mapping[str, int], dtype: str) -> TileWorkload:
    (q,) = tile
    p, n = problem["p"], problem["n"]
    b = dtype_bytes(dtype)
    flops = 2.0 * q * q * n + 2.0 * q * q * p + 2.0 * q * n * p * 2
    hbm = (q + q * p + 2 * q * n + q * p) * b
    return TileWorkload(
        flops=flops,
        hbm_bytes=float(hbm),
        row_segments=q // 8,
        row_stride_bytes=float(problem["h"] * p * b),
        pad_waste=max(1.0, 128 / p) if p < 128 else 1.0,
    )


def _n_tiles(tile: TileShape, problem: Mapping[str, int]) -> int:
    return problem["h"] * cdiv(problem["s"], tile[0])


def _default_tile(problem: Mapping[str, int], dtype: str) -> TileShape:
    return TileShape((min(256, problem["s"]),))


registry.register(registry.KernelSpec(
    name="ssd",
    constraints=_constraints,
    vmem_bytes=_vmem_bytes,
    workload=_workload,
    n_tiles=_n_tiles,
    default_tile=_default_tile,
))
