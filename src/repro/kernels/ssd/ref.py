"""Pure-jnp oracle for the Mamba-2 SSD (state-space dual) layer.

Selective state-space recurrence (arXiv:2405.21060), per head:

    a_t = exp(dt_t * A)                       A < 0 scalar per head
    h_t = a_t * h_{t-1} + B_t (x) (dt_t x_t)  outer product [N] x [P]
    y_t = C_t @ h_t  (+ D * x_t skip)

``ssd_ref`` runs the literal recurrence with lax.scan (the correctness
oracle). ``ssd_chunked_ref`` implements the chunked dual form (intra-chunk
attention-like matmuls + inter-chunk state carry) in pure jnp — the same
algorithm the Pallas kernel implements, and the path the distributed model
lowers on non-TPU backends.

Shapes: x [B, S, H, P]; dt [B, S, H]; A [H]; Bm, C [B, S, N] (single group,
broadcast over heads); D [H] optional. State: [B, H, N, P].
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import flags


def ssd_ref(x, dt, A, Bm, C, D=None, h0=None):
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    a = jnp.exp(dtf * A[None, None, :])                 # [B, S, H]
    dtx = dtf[..., None] * xf                            # [B, S, H, P]

    def step(hstate, xs):
        a_t, dtx_t, b_t, c_t = xs
        # hstate [B, H, N, P]
        outer = b_t[:, None, :, None] * dtx_t[:, :, None, :]   # [B, H, N, P]
        h_new = a_t[..., None, None] * hstate + outer
        y_t = jnp.einsum("bn,bhnp->bhp", c_t, h_new)
        return h_new, y_t

    h0 = jnp.zeros((b, h, n, p), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    h_last, ys = jax.lax.scan(
        step, h0,
        (a.transpose(1, 0, 2), dtx.transpose(1, 0, 2, 3),
         Bm.astype(jnp.float32).transpose(1, 0, 2),
         C.astype(jnp.float32).transpose(1, 0, 2)),
    )
    y = ys.transpose(1, 0, 2, 3)                         # [B, S, H, P]
    if D is not None:
        y = y + D[None, None, :, None] * xf
    return y.astype(x.dtype), h_last.astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_chunked_ref(x, dt, A, Bm, C, D=None, h0=None, chunk: int = 128):
    """Chunked dual form; identical math, O(S*Q) intra + state carry."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    if s % chunk:
        raise ValueError(f"seq {s} not divisible by chunk {chunk}")
    nc = s // chunk

    cdt = jnp.bfloat16 if flags.SSD_COMPUTE_BF16 else jnp.float32
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    log_a = dtf * A[None, None, :]                       # [B, S, H] (<= 0)
    dtx = (dtf[..., None] * xf).astype(cdt)              # [B, S, H, P]

    # Chunked views, scan over chunk index. Decay statistics (log_a, cumsum,
    # exp) stay f32; the heavy [Q,Q]/[Q,P]/[N,P] einsums run in cdt with f32
    # accumulation (preferred_element_type below).
    la = log_a.reshape(b, nc, chunk, h).transpose(1, 0, 3, 2)     # [nc,B,H,Q]
    xc = dtx.reshape(b, nc, chunk, h, p).transpose(1, 0, 3, 2, 4)  # [nc,B,H,Q,P]
    bc = Bm.astype(cdt).reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)
    cc = C.astype(cdt).reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)

    idx = jnp.arange(chunk)
    tri = idx[:, None] >= idx[None, :]                   # causal within chunk

    def body(hstate, xs):
        la_c, x_c, b_c, c_c = xs
        cum = jnp.cumsum(la_c, axis=-1)                  # [B,H,Q] inclusive
        # Intra-chunk: scores[i,j] = (C_i . B_j) exp(cum_i - cum_j), i >= j.
        cb = jnp.einsum("bin,bjn->bij", c_c, b_c,
                        preferred_element_type=jnp.float32)  # [B,Q,Q]
        L = jnp.where(
            tri[None], jnp.exp(cum[:, :, :, None] - cum[:, :, None, :]), 0.0
        )                                                # [B,H,Q,Q]
        y_intra = jnp.einsum("bhij,bhjp->bhip",
                             (cb[:, None] * L).astype(cdt), x_c,
                             preferred_element_type=jnp.float32)
        # Inter-chunk: y_i += exp(cum_i) * C_i @ h_prev.
        y_inter = jnp.einsum("bin,bhnp->bhip", c_c, hstate.astype(cdt),
                             preferred_element_type=jnp.float32) * jnp.exp(
            cum
        )[..., None]
        # State update: h = exp(cum_last) h_prev + sum_j exp(cum_last-cum_j) B_j (x) x_j.
        total = cum[:, :, -1]                            # [B,H]
        w = jnp.exp(total[:, :, None] - cum)             # [B,H,Q]
        h_new = (
            jnp.exp(total)[:, :, None, None] * hstate
            + jnp.einsum("bjn,bhjp->bhnp", b_c,
                         (w[..., None] * x_c.astype(jnp.float32)).astype(cdt),
                         preferred_element_type=jnp.float32)
        )
        return h_new, (y_intra + y_inter).transpose(0, 2, 1, 3)  # [B,Q,H,P]

    h0 = jnp.zeros((b, h, n, p), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    h_last, ys = jax.lax.scan(body, h0, (la, xc, bc, cc),
                              unroll=flags.scan_unroll())
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    if D is not None:
        y = y + D[None, None, :, None] * xf
    return y.astype(x.dtype), h_last.astype(x.dtype)
