"""Mamba-2 SSD Pallas TPU kernel, tunable chunk length Q.

Grid (B, H, S/Q) with the chunk dimension innermost ("arbitrary"): the
[N, P] state is carried across chunks in VMEM scratch, each chunk does three
MXU contractions (CB^T, intra-chunk combine, state update) plus VPU decay
math. Q is the tile knob: large Q amortizes state I/O and raises MXU
occupancy ([Q,Q] scores), small Q bounds the VMEM logits buffer — the same
working-set-vs-parallelism trade the paper sweeps.

Inputs are pre-arranged by ops.py: log_a [B, H, S]; dtx [B, S, H, P];
Bm, C [B, S, N]; h0 [B, H, N, P]. Outputs: y [B, S, H, P], h_last like h0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat


def _ssd_kernel(la_ref, x_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref, h_ref,
                *, q: int, n_c: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _():
        h_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    la = la_ref[0, 0].astype(jnp.float32)       # [Q]
    x = x_ref[0, :, 0, :].astype(jnp.float32)   # [Q, P]
    bm = b_ref[0].astype(jnp.float32)           # [Q, N]
    cm = c_ref[0].astype(jnp.float32)           # [Q, N]

    cum = jnp.cumsum(la)                        # [Q] inclusive
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.where(ii >= jj, jnp.exp(cum[:, None] - cum[None, :]), 0.0)

    cb = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    )                                           # [Q, Q]
    scores = cb * decay
    y_intra = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )                                           # [Q, P]

    h_prev = h_ref[...]                         # [N, P]
    y_inter = jax.lax.dot_general(
        cm, h_prev, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    ) * jnp.exp(cum)[:, None]                   # [Q, P]

    total = cum[q - 1]
    w = jnp.exp(total - cum)                    # [Q]
    h_new = jnp.exp(total) * h_prev + jax.lax.dot_general(
        bm * w[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                           # [N, P]
    h_ref[...] = h_new
    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    @pl.when(ic == n_c - 1)
    def _():
        hout_ref[0, 0] = h_new.astype(hout_ref.dtype)


def ssd_scan(
    log_a: jnp.ndarray,   # [B, H, S]
    dtx: jnp.ndarray,     # [B, S, H, P]
    Bm: jnp.ndarray,      # [B, S, N]
    C: jnp.ndarray,       # [B, S, N]
    h0: jnp.ndarray,      # [B, H, N, P]
    chunk: int = 128,
    interpret: bool = False,
):
    b, s, h, p = dtx.shape
    n = Bm.shape[-1]
    q = min(chunk, s)
    if s % q:
        raise ValueError(f"seq {s} not divisible by chunk {q}")
    n_c = s // q

    kernel = functools.partial(_ssd_kernel, q=q, n_c=n_c)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(b, h, n_c),
        in_specs=[
            pl.BlockSpec((1, 1, q), lambda bb, hh, ic: (bb, hh, ic)),
            pl.BlockSpec((1, q, 1, p), lambda bb, hh, ic: (bb, ic, hh, 0)),
            pl.BlockSpec((1, q, n), lambda bb, hh, ic: (bb, ic, 0)),
            pl.BlockSpec((1, q, n), lambda bb, hh, ic: (bb, ic, 0)),
            pl.BlockSpec((1, 1, n, p), lambda bb, hh, ic: (bb, hh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, 1, p), lambda bb, hh, ic: (bb, ic, hh, 0)),
            pl.BlockSpec((1, 1, n, p), lambda bb, hh, ic: (bb, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), dtx.dtype),
            jax.ShapeDtypeStruct((b, h, n, p), dtx.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(log_a, dtx, Bm, C, h0)
    return y, h_last
