"""Compile the ahead-of-time tile-plan artifact for a hardware fleet.

Sweeps every registered kernel across the requested hardware models and the
problem families derived from the assigned shape set
(``repro.configs.shapes.SHAPES``) for each architecture (``--all-archs``
covers the full roofline table), plus the paper's bilinear scale family,
and writes one schema-versioned JSON artifact:

    PYTHONPATH=src python -m repro.launch.compile_plans --out plans.json

``--serve-buckets 64,128,512`` additionally compiles the serving
scheduler's shape family — one (batch=1, seq=edge) prefill cell per bucket
edge plus the slot-batch decode cell — so a
``ShapeBucketScheduler``-admitted request always lands on an exact plan
cell (see ``repro.serve.scheduler``).

``--measure wallclock`` times the analytically-best tile candidates on the
running backend (``launch.measure``) when real TPU hardware is present;
measured scores outrank analytic ones. Without usable hardware every cell
silently keeps the analytic cost model.

Serving (``ServeEngine(plans=...)``), training
(``TrainerConfig.tile_plans=...``) and ``TilingPolicy(plans=...)`` then
resolve tiles from the artifact — exact hit, nearest shape, or
cross-hardware transfer — without ever sweeping on a hot path.
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Optional, Sequence, Tuple

from repro import configs, kernels
from repro.configs import shapes as shape_families
from repro.core import HARDWARE_REGISTRY, Autotuner
from repro.core.plans import PLAN_SCHEMA_VERSION, PlanJob, compile_plan
from repro.launch.specs import cell_problems, kernel_problems

# Kernels modelled only for one hardware family: everything defaults to the
# TPU estimator; the paper's CUDA gather kernel only makes sense on the
# paper's GPU descriptors.
KERNEL_FAMILIES: Dict[str, Tuple[str, ...]] = {
    "bilinear_cuda": ("gpu",),
}
DEFAULT_FAMILIES: Tuple[str, ...] = ("tpu",)

# Representative arch coverage: dense attention, hybrid attention+RG-LRU,
# and pure SSD — together they exercise every registered model kernel.
DEFAULT_ARCHS = ("qwen2-1.5b", "recurrentgemma-9b", "mamba2-2.7b")

# The paper's Fig. 3 sweep family (image kernels are shape-family-independent).
BILINEAR_PROBLEMS = [dict(src_h=800, src_w=800, scale=s) for s in (2, 4, 6, 8, 10)]


def kernel_dtypes(kernel: str, dtypes: Sequence[str]) -> Tuple[str, ...]:
    """The dtypes to compile one kernel's cells for.

    Image kernels run float32 only; model kernels sweep the requested list.
    dtype is part of the plan key — every artifact producer must use this
    policy or its entries are unreachable at lookup time.
    """
    return ("float32",) if kernel.startswith("bilinear") else tuple(dtypes)


def serve_bucket_cells(arch_names: Sequence[str], edges: Sequence[int],
                       slots: int, max_len: int, smoke: bool = False,
                       ) -> List[Tuple[str, Dict[str, int]]]:
    """The serving scheduler's shape family as deduped (kernel, problem)
    cells: a (batch=1, seq=edge) prefill cell, a chunked-prefill cell
    (chunk length swept as a first-class tile axis) AND a packed-prefill
    cell (pack width swept — how many chunk tokens ride one step) per
    bucket edge, plus the engine's (slots, max_len) decode cell, per
    architecture."""
    cells: Dict[Tuple[str, Tuple[Tuple[str, int], ...]], Dict[str, int]] = {}
    get_cfg = configs.get_smoke if smoke else configs.get_arch
    for arch in arch_names:
        cfg = get_cfg(arch)
        for edge in edges:
            for kind in ("prefill", "chunked_prefill", "packed_prefill"):
                for kernel, problem in kernel_problems(
                        cfg, 1, edge, kind).items():
                    cells[(kernel, tuple(sorted(problem.items())))] = problem
        for kernel, problem in kernel_problems(
                cfg, slots, max_len, "decode").items():
            cells[(kernel, tuple(sorted(problem.items())))] = problem
    return [(k, p) for (k, _), p in cells.items()]


def load_or_compile_cells(plans_path, cells, hw_names: Sequence[str],
                          dtype: str = "float32", meta=None, print_fn=print):
    """Reuse a compiled artifact when it covers ``cells`` on every listed
    hardware model; compile exactly those cells otherwise.

    The benches' artifact-reuse path: CI passes the compile-plans job's
    upload so bench jobs stop recompiling the serving shape family, and a
    missing/stale/non-covering artifact degrades to a local compile.
    """
    from repro import kernels as kernel_pkg
    from repro.core import HARDWARE_REGISTRY, Autotuner
    from repro.core.plans import TilePlan, compile_plan

    kernel_pkg.register_all()
    plan = TilePlan.load_or_none(plans_path)
    if plan is not None:
        covered = all(
            plan.lookup(kernel, problem, dtype, hw) is not None
            for kernel, problem in cells for hw in hw_names)
        if covered:
            print_fn(f"# reusing plan artifact {plans_path} "
                     f"({len(plan)} cells)")
            return plan
        print_fn(f"# plan artifact {plans_path} does not cover the "
                 f"requested cells; recompiling")
    jobs = [(kernel, problem, dtype, HARDWARE_REGISTRY[hw])
            for kernel, problem in cells for hw in hw_names]
    return compile_plan(jobs, autotuner=Autotuner(), meta=meta)


def build_jobs(arch_names: Sequence[str], hw_names: Sequence[str],
               dtypes: Sequence[str],
               serve_buckets: Sequence[int] = (),
               serve_slots: int = 4,
               serve_max_len: int = 0,
               serve_smoke: bool = False) -> List[PlanJob]:
    """Problem families (archs x shapes + paper bilinear + serve buckets)
    x hardware fleet."""
    kernels.register_all()
    hardware = [HARDWARE_REGISTRY[h] for h in hw_names]

    # Gather deduped (kernel, problem) cells from the shape families.
    cells: Dict[Tuple[str, Tuple[Tuple[str, int], ...]], Dict[str, int]] = {}
    for arch in arch_names:
        cfg = configs.get_arch(arch)
        for shape in shape_families.SHAPES:
            ok, _ = shape_families.applicable(cfg, shape)
            if not ok:
                continue
            for kernel, problem in cell_problems(cfg, shape).items():
                cells[(kernel, tuple(sorted(problem.items())))] = problem
    model_cells = [(k, p) for (k, _), p in cells.items()]
    if serve_buckets:
        model_cells += serve_bucket_cells(
            arch_names, serve_buckets, serve_slots,
            serve_max_len or max(serve_buckets), smoke=serve_smoke)
    image_cells = ([("bilinear", p) for p in BILINEAR_PROBLEMS]
                   + [("bilinear_cuda", p) for p in BILINEAR_PROBLEMS])

    jobs: List[PlanJob] = []
    seen = set()
    for kernel, problem in model_cells + image_cells:
        families = KERNEL_FAMILIES.get(kernel, DEFAULT_FAMILIES)
        for hw in hardware:
            if hw.family not in families:
                continue
            for dtype in kernel_dtypes(kernel, dtypes):
                job = (kernel, tuple(sorted(problem.items())), dtype, hw.name)
                if job in seen:
                    continue
                seen.add(job)
                jobs.append((kernel, problem, dtype, hw))
    return jobs


def main(argv: Optional[Sequence[str]] = None) -> str:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="plans.json",
                    help="artifact path (JSON)")
    ap.add_argument("--hardware", nargs="*",
                    default=sorted(HARDWARE_REGISTRY),
                    choices=sorted(HARDWARE_REGISTRY))
    ap.add_argument("--archs", nargs="*", default=list(DEFAULT_ARCHS),
                    choices=configs.list_archs())
    ap.add_argument("--all-archs", action="store_true",
                    help="cover every architecture (the full roofline "
                         "table's cells), not just the representative set")
    # Both serving dtypes by default: dtype is part of the plan key (it
    # changes sublane alignment and VMEM budgets), so a fleet artifact must
    # cover what engines actually run.
    ap.add_argument("--dtypes", nargs="*", default=["bfloat16", "float32"])
    ap.add_argument("--max-candidates", type=int, default=256,
                    help="sweep candidates per cell (bounds the curve size)")
    ap.add_argument("--curve-cap", type=int, default=0,
                    help="keep only the top-N curve points (0 = full curve)")
    ap.add_argument("--serve-buckets", default="",
                    help="comma list of scheduler bucket edges to compile "
                         "prefill/decode serving cells for (e.g. 64,128,512)")
    ap.add_argument("--serve-slots", type=int, default=4,
                    help="decode slot batch for --serve-buckets cells")
    ap.add_argument("--serve-max-len", type=int, default=0,
                    help="decode cache length for --serve-buckets cells "
                         "(default: largest bucket edge)")
    ap.add_argument("--serve-smoke", action="store_true",
                    help="compile serve cells for the reduced smoke configs "
                         "(what `python -m repro.launch.serve` runs) instead "
                         "of the full architectures")
    ap.add_argument("--measure", choices=("analytic", "wallclock"),
                    default="analytic",
                    help="wallclock: time top candidates on the running "
                         "backend when real hardware is present; falls back "
                         "to the analytic model per cell otherwise")
    args = ap.parse_args(argv)

    if args.all_archs:
        args.archs = configs.list_archs()
    buckets = sorted({int(x) for x in args.serve_buckets.split(",") if x})
    measure_factory = None
    if args.measure == "wallclock":
        from repro.launch.measure import make_measure_fn
        measure_factory = make_measure_fn

    jobs = build_jobs(args.archs, args.hardware, args.dtypes,
                      serve_buckets=buckets, serve_slots=args.serve_slots,
                      serve_max_len=args.serve_max_len,
                      serve_smoke=args.serve_smoke)
    plan = compile_plan(
        jobs,
        autotuner=Autotuner(),
        max_candidates=args.max_candidates,
        curve_cap=args.curve_cap or None,
        measure_fn_factory=measure_factory,
        meta={
            "generated_by": "repro.launch.compile_plans",
            "archs": list(args.archs),
            "dtypes": list(args.dtypes),
            "serve_buckets": buckets,
            "measure": args.measure,
        },
    )
    plan.save(args.out)
    print(f"schema v{PLAN_SCHEMA_VERSION}: {len(plan)} entries "
          f"({len(jobs)} jobs, {plan.meta['skipped_jobs']} infeasible) "
          f"-> {args.out}")
    print(f"kernels:  {', '.join(plan.kernels())}")
    print(f"hardware: {', '.join(plan.hardware_names())}")
    return args.out


if __name__ == "__main__":
    main()
