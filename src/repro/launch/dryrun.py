import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver builds abstract params/optimizer/batch specs
(ShapeDtypeStruct only — nothing is allocated), jits the train or serve
step with explicit in/out shardings on the production mesh, compiles, and
records memory_analysis + cost_analysis + parsed collective bytes to
``dryrun_results/<cell>.json``. Incremental: existing results are skipped
unless --force.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import EncoderConfig
from repro.configs.shapes import SHAPES, applicable, get_shape
from repro.core.hardware import PRODUCTION_TARGET
from repro.distributed import sharding_rules as rules
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models import api, flags
from repro.optim import adamw
from repro.roofline import analysis as RA
from repro.train.step import make_serve_steps, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "dryrun_results")

OPT_CFG = adamw.AdamWConfig(moment_dtype="bfloat16")  # 235B @256 chips needs it


def _batch_shardings(batch_abs, mesh):
    return jax.tree.map(
        lambda x: rules.batch_sharding(mesh, x.ndim)
        if x.shape[0] % mesh.shape[rules.batch_axes_for(mesh)[0]] == 0
        or x.shape[0] > 1 else rules.replicated(mesh),
        batch_abs,
    )


CARRY_BUDGET = 2 * 2**30  # target bytes for scan-carry activations/device


def choose_microbatches(cfg, shape, mesh) -> int:
    """Split the per-device batch so layer-boundary carries fit the budget."""
    if shape.kind != "train":
        return 1
    dp = 1
    for ax in rules.batch_axes_for(mesh):
        dp *= mesh.shape[ax]
    per_dev = max(1, shape.global_batch // dp)
    per_seq = shape.seq_len * cfg.d_model * 2 * max(cfg.n_layers, 1)
    if cfg.encoder is not None and cfg.encoder.kind == "audio":
        per_seq += cfg.encoder.seq_len * cfg.d_model * 2 * cfg.encoder.n_layers
    need = (per_dev * per_seq + CARRY_BUDGET - 1) // CARRY_BUDGET
    mb = 1
    while mb < need and mb < per_dev:
        mb *= 2
    return mb


def _compile_step(cfg, shape, mesh, microbatches: int = 1) -> Tuple[Any, Any]:
    """Build + lower + compile the cell's step. Returns (lowered, compiled)."""
    ctx = rules.make_context(mesh)
    params_abs = S.abstract_params(cfg, jnp.bfloat16)
    axes = api.param_logical_axes(cfg)
    p_shard = rules.param_shardings(axes, params_abs, mesh, fsdp=True)

    if shape.kind == "train":
        opt_abs = S.abstract_opt_state(params_abs, OPT_CFG)
        opt_shard = {"m": p_shard, "v": p_shard,
                     "step": rules.replicated(mesh)}
        batch_abs = S.input_specs(cfg, shape)
        b_shard = _batch_shardings(batch_abs, mesh)
        # Huge models (235B-class) accumulate microbatch grads in bf16 to
        # keep the f32 accumulation buffer off the HBM budget.
        import numpy as _np
        params_bytes = sum(_np.prod(l.shape) for l in jax.tree.leaves(params_abs)) * 2
        accum = jnp.bfloat16 if params_bytes / 256 > 2**30 else jnp.float32
        step = make_train_step(cfg, ctx, OPT_CFG, microbatches=microbatches,
                               accum_dtype=accum)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, opt_shard, b_shard),
            out_shardings=(p_shard, opt_shard, None),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(params_abs, opt_abs, batch_abs)
    elif shape.kind == "prefill":
        batch_abs = S.input_specs(cfg, shape)
        batch_abs.pop("targets", None)
        b_shard = _batch_shardings(batch_abs, mesh)
        prefill_step, _ = make_serve_steps(cfg, ctx, max_len=shape.seq_len,
                                           dtype=jnp.bfloat16)
        state_abs = jax.eval_shape(prefill_step, params_abs, batch_abs)[1]
        st_shard = rules.serve_state_shardings(state_abs, mesh)
        jitted = jax.jit(
            prefill_step,
            in_shardings=(p_shard, b_shard),
            out_shardings=(None, st_shard),
        )
        lowered = jitted.lower(params_abs, batch_abs)
    else:  # decode
        state_abs = S.abstract_serve_state(cfg, shape, jnp.bfloat16,
                                           params=params_abs)
        st_shard = rules.serve_state_shardings(state_abs, mesh)
        tok_abs = S.decode_token_spec(cfg, shape)
        tok_shard = _batch_shardings({"t": tok_abs}, mesh)["t"]
        _, decode_step = make_serve_steps(cfg, ctx, max_len=shape.seq_len,
                                          dtype=jnp.bfloat16)
        jitted = jax.jit(
            decode_step,
            in_shardings=(p_shard, tok_shard, st_shard),
            out_shardings=(None, st_shard),
            donate_argnums=(2,),
        )
        lowered = jitted.lower(params_abs, tok_abs, state_abs)
    return lowered, lowered.compile()


# ---------------------------------------------------------------------------
# Exact cost terms via per-layer differencing of unrolled probe configs.
# XLA cost analysis counts while bodies once, so the full scanned compile
# undercounts; probes with 1-2 layers per distinct LayerSpec and
# ANALYSIS_UNROLL give exact per-layer costs to extrapolate from.
# ---------------------------------------------------------------------------

def _distinct_specs(cfg) -> List[Tuple[Any, int]]:
    counts: Dict[Any, int] = {}
    order = []
    for spec in cfg.layers():
        if spec not in counts:
            order.append(spec)
        counts[spec] = counts.get(spec, 0) + 1
    return [(s, counts[s]) for s in order]


def _probe_cfg(cfg, pattern, enc_layers: Optional[int] = None):
    kw = dict(n_layers=len(pattern), layer_pattern=tuple(pattern))
    if enc_layers is not None and cfg.encoder is not None:
        kw["encoder"] = dataclasses.replace(cfg.encoder, n_layers=enc_layers)
    return dataclasses.replace(cfg, **kw)


def _terms_of(cfg, shape, mesh) -> Tuple[float, float, float]:
    flags.set_analysis_unroll(True)
    try:
        _, compiled = _compile_step(cfg, shape, mesh)
        t = RA.analyze(compiled, PRODUCTION_TARGET)
        return (t.flops, t.hbm_bytes, t.collective_bytes)
    finally:
        flags.set_analysis_unroll(False)


def exact_cost_terms(cfg, shape, mesh) -> Dict[str, float]:
    distinct = _distinct_specs(cfg)
    base_pattern = [s for s, _ in distinct]
    enc_probe = (cfg.encoder is not None and cfg.encoder.kind == "audio"
                 and shape.kind != "decode")
    base_enc = 1 if enc_probe else None

    base = _terms_of(_probe_cfg(cfg, base_pattern, base_enc), shape, mesh)
    total = list(base)
    for i, (spec, count) in enumerate(distinct):
        if count == 1:
            continue
        plus = _terms_of(
            _probe_cfg(cfg, base_pattern + [spec], base_enc), shape, mesh)
        for j in range(3):
            total[j] += (count - 1) * (plus[j] - base[j])
    if enc_probe and cfg.encoder.n_layers > 1:
        plus = _terms_of(_probe_cfg(cfg, base_pattern, 2), shape, mesh)
        for j in range(3):
            total[j] += (cfg.encoder.n_layers - 1) * (plus[j] - base[j])
    return {"flops": total[0], "hbm_bytes": total[1],
            "collective_bytes": total[2]}


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               fsdp: bool = True, remat: bool = True,
               extra_tag: str = "") -> Dict[str, Any]:
    cfg = configs.get_arch(arch)
    shape = get_shape(shape_name)
    ok, why = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    mb = choose_microbatches(cfg, shape, mesh)

    # Phase A: full-depth scanned compile — proves sharding coherence and
    # gives the real memory picture.
    t0 = time.time()
    lowered, compiled = _compile_step(cfg, shape, mesh, microbatches=mb)
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()

    # Phase B: exact cost terms from unrolled probe differencing. The
    # roofline table is single-pod only (per the task spec); the multi-pod
    # pass proves the pod axis shards and records memory/compile only.
    hw = PRODUCTION_TARGET
    if multi_pod:
        t_probe = 0.0
        quick = RA.analyze(compiled, hw)
        terms = quick  # scanned-HLO lower bound, recorded for reference
    else:
        t0 = time.time()
        exact = exact_cost_terms(cfg, shape, mesh)
        t_probe = time.time() - t0
        terms = RA.RooflineTerms(
            flops=exact["flops"],
            hbm_bytes=exact["hbm_bytes"],
            collective_bytes=exact["collective_bytes"],
            compute_s=exact["flops"] / hw.peak_flops_bf16,
            memory_s=exact["hbm_bytes"] / hw.hbm_bw,
            collective_s=exact["collective_bytes"]
            / (hw.ici_links * hw.ici_bw_per_link),
        )
    mf = RA.model_flops(cfg, shape)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "n_chips": int(n_chips),
        "microbatches": mb,
        "compile_s": round(t_compile, 1),
        "probe_s": round(t_probe, 1),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "peak_bytes": int(mem.argument_size_in_bytes
                              + mem.temp_size_in_bytes),
            "hbm_per_chip": PRODUCTION_TARGET.hbm_bytes,
            "fits": bool(mem.argument_size_in_bytes + mem.temp_size_in_bytes
                         < PRODUCTION_TARGET.hbm_bytes),
        },
        "roofline": {
            "flops_per_chip": terms.flops,
            "hbm_bytes_per_chip": terms.hbm_bytes,
            "collective_bytes_per_chip": terms.collective_bytes,
            "compute_s": terms.compute_s,
            "memory_s": terms.memory_s,
            "collective_s": terms.collective_s,
            "dominant": terms.dominant,
            "roofline_fraction": terms.roofline_fraction(),
            "model_flops_global": mf,
            "useful_flops_ratio": (
                mf / (terms.flops * n_chips) if terms.flops else 0.0
            ),
        },
    }
    if extra_tag:
        result["tag"] = extra_tag
    return result


def cell_path(arch, shape_name, multi_pod, tag="") -> str:
    mesh = "multi" if multi_pod else "single"
    suffix = f".{tag}" if tag else ""
    return os.path.join(
        os.path.abspath(RESULTS_DIR),
        f"{arch}__{shape_name}__{mesh}{suffix}.json",
    )


OPT_PRESETS = {
    "attn_bf16": dict(attn_bf16=True),
    "remat_dots": dict(remat="dots"),
    "decode_sharded": dict(decode_sharded=True),
    "ssd256": dict(ssd_chunk=256),
    "ssd512": dict(ssd_chunk=512),
    "ssd_bf16": dict(ssd_bf16=True),
    "all": dict(attn_bf16=True, remat="dots", decode_sharded=True),
}


def apply_opts(opts: str) -> None:
    from repro.models import flags as _f
    _f.set_perf(attn_bf16=False, remat="nothing", ssd_chunk=0,
                decode_sharded=False)
    for name in [o for o in opts.split(",") if o]:
        _f.set_perf(**OPT_PRESETS[name])


def run_cell(arch, shape_name, multi_pod, force=False, fsdp=True,
             remat=True, tag="", opts="") -> Dict[str, Any]:
    path = cell_path(arch, shape_name, multi_pod, tag)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    apply_opts(opts)
    try:
        res = lower_cell(arch, shape_name, multi_pod, fsdp=fsdp,
                         remat=remat, extra_tag=tag)
    except Exception as e:  # record failures — they are bugs to fix
        res = {"arch": arch, "shape": shape_name,
               "mesh": "multi" if multi_pod else "single",
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    return res


def plan_hit_report(plans, arch: str, shape_name: str,
                    dtype: str = "bfloat16") -> Dict[str, str]:
    """kernel -> resolution source for one roofline cell against a plan.

    Pure plan lookups (no lowering): the dry-run's (arch x shape) cell maps
    to kernel problems via ``specs.cell_problems`` — the same mapping
    ``compile_plans`` sweeps — so this reports how well the artifact covers
    the roofline table. Sources: exact | nearest_shape | cross_hardware |
    fallback (plan had nothing usable).
    """
    import warnings

    from repro import kernels as kernel_pkg
    from repro.core.plans import PlanTransferWarning

    kernel_pkg.register_all()
    cfg = configs.get_arch(arch)
    shape = get_shape(shape_name)
    ok, _ = applicable(cfg, shape)
    if not ok:
        return {}
    sources = {}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", PlanTransferWarning)
        for kernel, problem in S.cell_problems(cfg, shape).items():
            res = plans.resolve(kernel, problem, dtype, PRODUCTION_TARGET)
            sources[kernel] = res.source if res is not None else "fallback"
    return sources


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--opt", default="",
                    help="comma list of OPT_PRESETS (perf hillclimb runs)")
    ap.add_argument("--tile-plans", default=None,
                    help="compiled TilePlan artifact; reports per-cell plan "
                         "hit-rate alongside the roofline results")
    ap.add_argument("--plan-dtype", default="bfloat16",
                    help="dtype key for the --tile-plans hit-rate lookups "
                         "(the dry-run itself lowers bfloat16)")
    args = ap.parse_args()
    if args.opt and not args.tag:
        args.tag = args.opt.replace(",", "+")

    from repro.core.plans import TilePlan
    plans = TilePlan.load_or_none(args.tile_plans)

    meshes = []
    if args.single_pod or not args.multi_pod:
        meshes.append(False)
    if args.multi_pod or not args.single_pod:
        meshes.append(True)

    archs = configs.list_archs() if args.all or not args.arch else [args.arch]
    shapes = [s.name for s in SHAPES] if args.all or not args.shape \
        else [args.shape]

    plan_sources: List[Tuple[str, str]] = []   # (shape kind, source)
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                res = run_cell(arch, shape_name, mp, force=args.force,
                               fsdp=not args.no_fsdp, tag=args.tag,
                               opts=args.opt)
                status = res["status"]
                line = f"{arch:24s} {shape_name:12s} {res['mesh']:6s} {status}"
                if status == "ok":
                    r = res["roofline"]
                    line += (
                        f"  compile={res['compile_s']}s"
                        f"  peak={res['memory']['peak_bytes']/2**30:.2f}GiB"
                        f"  dom={r['dominant']}"
                        f"  frac={r['roofline_fraction']:.2f}"
                    )
                elif status == "error":
                    line += f"  {res['error'][:120]}"
                if plans is not None and not mp:
                    sources = plan_hit_report(plans, arch, shape_name,
                                              args.plan_dtype)
                    if sources:
                        kind = get_shape(shape_name).kind
                        plan_sources.extend(
                            (kind, s) for s in sources.values())
                        line += "  plan=" + ",".join(
                            f"{k}:{s}" for k, s in sorted(sources.items()))
                print(line, flush=True)
    if plans is not None and plan_sources:
        # Decode cells sweep their own kernel (flash_decode) with its own
        # sensitivity curve; report its coverage separately from the
        # full-sequence (train/prefill) cells.
        def _rate(label: str, pool: List[Tuple[str, str]]) -> None:
            if not pool:
                return
            srcs = [s for _, s in pool]
            hits = sum(s == "exact" for s in srcs)
            print(f"tile-plan hit-rate [{label}] ({args.plan_dtype}, "
                  f"{PRODUCTION_TARGET.name}): "
                  f"{hits}/{len(srcs)} exact ({hits / len(srcs):.2f}); "
                  f"sources: { {s: srcs.count(s) for s in sorted(set(srcs))} }",
                  flush=True)

        _rate("all", plan_sources)
        _rate("decode", [p for p in plan_sources if p[0] == "decode"])
        _rate("prefill+train", [p for p in plan_sources if p[0] != "decode"])


if __name__ == "__main__":
    main()
