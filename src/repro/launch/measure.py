"""Wall-clock tile measurement for plan compilation (real hardware only).

The paper timed every tile candidate on each GPU; the plan compiler defaults
to the analytic cost model because CI and laptops have no TPU. This module
supplies the paper-faithful path when real hardware *is* present:
``make_measure_fn`` returns a ``MeasureFn`` (tile -> seconds) that runs the
kernel's jitted Pallas op on synthetic operands with warmup, which the
autotuner then prefers over analytic scores (``SweepEntry.measured_s``
outranks ``cost.total_s``).

Gating: measurement requires the running jax backend to be a TPU *and* the
target hardware descriptor to be TPU-family (we cannot wall-clock a GTX260
descriptor on a TPU). Anything else returns None and the caller falls back
to the analytic model — the compile never fails for lack of hardware.

``make_cell_timer`` wraps the same machinery as the *always-available*
timing path shared by plan compilation and the serving engines' shadow
execution (``repro.serve.refine``): wall-clock when hardware is present,
the analytic cost-model score otherwise.
"""
from __future__ import annotations

import logging
import time
from typing import Callable, Mapping, Optional

import numpy as np

from repro.core.hardware import HardwareModel
from repro.core.tiling import TileShape

log = logging.getLogger("repro.measure")

MeasureFn = Callable[[TileShape], float]


def _np_dtype(dtype: str):
    import jax.numpy as jnp

    return jnp.dtype(dtype)


def _matmul_call(problem: Mapping[str, int], dtype: str):
    import jax.numpy as jnp

    from repro.kernels.matmul.ops import mm

    rng = np.random.default_rng(0)
    m, k, n = problem["m"], problem["k"], problem["n"]
    a = jnp.asarray(rng.standard_normal((m, k)), _np_dtype(dtype))
    b = jnp.asarray(rng.standard_normal((k, n)), _np_dtype(dtype))
    return lambda tile: mm(a, b, tile=tuple(tile))


def _flash_call(problem: Mapping[str, int], dtype: str):
    import jax.numpy as jnp

    from repro.kernels.flash_attention.ops import attend

    rng = np.random.default_rng(0)
    sq, skv, d = problem["sq"], problem["skv"], problem["d"]
    hq, hkv = problem["hq"], problem["hkv"]
    window = problem.get("window", 0) or None
    q = jnp.asarray(rng.standard_normal((1, hq, sq, d)), _np_dtype(dtype))
    k = jnp.asarray(rng.standard_normal((1, hkv, skv, d)), _np_dtype(dtype))
    v = jnp.asarray(rng.standard_normal((1, hkv, skv, d)), _np_dtype(dtype))
    return lambda tile: attend(q, k, v, window=window, tile=tuple(tile))


def _flash_decode_call(problem: Mapping[str, int], dtype: str):
    import jax.numpy as jnp

    from repro.kernels.flash_attention.ops import attend_decode

    rng = np.random.default_rng(0)
    b, skv, d = problem["b"], problem["skv"], problem["d"]
    hq, hkv = problem["hq"], problem["hkv"]
    window = problem.get("window", 0) or None
    dt = _np_dtype(dtype)
    q = jnp.asarray(rng.standard_normal((b, hq, d)), dt)
    k = jnp.asarray(rng.standard_normal((b, hkv, skv, d)), dt)
    v = jnp.asarray(rng.standard_normal((b, hkv, skv, d)), dt)
    pos = jnp.asarray(skv - 1, jnp.int32)       # steady state: full cache

    def call(tile):
        if skv % int(tile[0]):
            # The Pallas kernel cannot run a non-dividing split; score it
            # infeasible so the sweep never certifies a tile the serve
            # path would then reject.
            return None
        return attend_decode(q, k, v, pos=pos, window=window,
                             bkv=int(tile[0]))

    return call


def _ssd_call(problem: Mapping[str, int], dtype: str):
    import jax.numpy as jnp

    from repro.kernels.ssd.ops import ssd

    rng = np.random.default_rng(0)
    s, h, p, n = problem["s"], problem["h"], problem["p"], problem["n"]
    dt = _np_dtype(dtype)
    x = jnp.asarray(rng.standard_normal((1, s, h, p)), dt)
    dts = jnp.asarray(rng.uniform(0.01, 0.1, (1, s, h)), dt)
    A = jnp.asarray(-rng.uniform(0.5, 1.5, (h,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((1, s, n)), dt)
    C = jnp.asarray(rng.standard_normal((1, s, n)), dt)
    return lambda tile: ssd(x, dts, A, Bm, C, chunk=int(tile[0]))


def _rglru_call(problem: Mapping[str, int], dtype: str):
    import jax.numpy as jnp

    from repro.kernels.rglru.ops import rglru

    rng = np.random.default_rng(0)
    s, f = problem["s"], problem["f"]
    dt = _np_dtype(dtype)
    x = jnp.asarray(rng.standard_normal((1, s, f)), dt)
    r = jnp.asarray(rng.uniform(0.0, 1.0, (1, s, f)), dt)
    i = jnp.asarray(rng.uniform(0.0, 1.0, (1, s, f)), dt)
    a = jnp.asarray(rng.standard_normal((f,)), jnp.float32)
    return lambda tile: rglru(x, r, i, a, tile=tuple(tile))


def _bilinear_call(problem: Mapping[str, int], dtype: str):
    import jax.numpy as jnp

    from repro.kernels.bilinear.ops import upscale

    rng = np.random.default_rng(0)
    src = jnp.asarray(
        rng.standard_normal((problem["src_h"], problem["src_w"])),
        _np_dtype(dtype))
    return lambda tile: upscale(src, problem["scale"], tile=tuple(tile))


_BUILDERS = {
    "matmul": _matmul_call,
    "flash_attention": _flash_call,
    "flash_decode": _flash_decode_call,
    "ssd": _ssd_call,
    "rglru": _rglru_call,
    "bilinear": _bilinear_call,
}


def hardware_available(hw: HardwareModel) -> bool:
    """True when the running backend can execute kernels for ``hw``."""
    import jax

    try:
        backend = jax.default_backend()
    except RuntimeError:
        return False
    return backend == "tpu" and hw.family == "tpu"


def make_measure_fn(
    kernel: str,
    problem: Mapping[str, int],
    dtype: str,
    hw: HardwareModel,
    warmup: int = 2,
    iters: int = 5,
) -> Optional[MeasureFn]:
    """A tile -> wall-clock-seconds hook for one cell, or None.

    None (analytic fallback) when no real TPU backend is present, the target
    descriptor is not TPU-family, or the kernel has no operand builder. A
    builder call may itself return None for a candidate its kernel cannot
    legally run (e.g. a non-dividing decode split); that candidate measures
    +inf and never wins the sweep.
    """
    if not hardware_available(hw):
        return None
    builder = _BUILDERS.get(kernel)
    if builder is None:
        log.info("no wallclock builder for kernel %r; analytic only", kernel)
        return None
    import math

    import jax

    call = builder(problem, dtype)

    def measure(tile: TileShape) -> float:
        for _ in range(warmup):  # first iteration compiles
            out = call(tile)
            if out is None:
                return math.inf
            jax.block_until_ready(out)
        t0 = time.perf_counter()
        out = None
        for _ in range(iters):
            out = call(tile)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / iters

    return measure


def make_cell_timer(
    kernel: str,
    problem: Mapping[str, int],
    dtype: str,
    hw: HardwareModel,
    warmup: int = 1,
    iters: int = 3,
) -> MeasureFn:
    """The shared timing path for plan compilation AND shadow execution.

    Wall-clock via :func:`make_measure_fn` when the running backend can
    execute kernels for ``hw``; the analytic cost-model score otherwise.
    Unlike ``make_measure_fn`` (which returns None off-hardware so the
    compiler can distinguish measured from analytic artifacts), this always
    returns a callable — shadow steps must produce *a* comparable number on
    every backend, and on modelled-only targets that number is the same
    analytic score the plan was ranked by.
    """
    fn = make_measure_fn(kernel, problem, dtype, hw,
                         warmup=warmup, iters=iters)
    if fn is not None:
        return lambda tile: fn(TileShape(tuple(tile)))
    from repro.core.plans import score_tile

    return lambda tile: score_tile(kernel, TileShape(tuple(tile)),
                                   dict(problem), dtype, hw)
