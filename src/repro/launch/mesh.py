"""Production mesh construction. Importing this module never touches jax
device state — ``make_production_mesh`` is a function, called only by the
launchers after XLA flags are in place.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests, CPU demos)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
