"""Serving launcher: batched request demo on the reduced config.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --requests 6

``--tile-plans plans.json`` resolves decode-path kernel tiles from a
compiled AOT artifact (see ``repro.launch.compile_plans``) instead of
tuning lazily; a corrupt/missing artifact degrades to heuristics.

``--scheduler bucket`` switches admission to the shape-bucketed scheduler
(``--bucket-policy`` sets the shape family: "64,128,512", "pow2:16:512", or
"plan" to derive the edges from the loaded artifact). ``--fleet
tpu_v4,tpu_v5e`` serves through the hardware-aware router instead of a
single engine — one engine per hardware model, each request placed on the
cost-model-cheapest instance. Runtime telemetry (per-bucket TTFT/TPOT,
queue depth, plan hit/transfer/fallback counters) prints at exit.

``--chunk-prefill`` splits every admitted prompt into plan-sized chunks and
co-schedules one prefill chunk with the decode batch each step (mixed
steps), bounded by ``--step-token-budget`` tokens per step. The chunk
length comes from the artifact's ``chunked_prefill`` cell for the target
hardware, so different models prefill the same prompt in different chunk
sizes. Prompts longer than the largest bucket edge are then admitted too
(padded to a multiple of the top edge) instead of rejected.

``--pack-prefill`` goes one step further (true batch mixing): each step
packs SEVERAL in-flight prefills' chunks — segment-concatenated into one
kernel launch — plus the decode batch, under the step budget and the
artifact's ``packed_prefill`` pack width (VMEM-bounded per hardware model,
so different models pack different widths). Token outputs are identical to
one-chunk-per-step and unchunked service; only the schedule densifies.

``--paged`` swaps the per-request contiguous KV caches for the fleet-wide
paged pool (``repro.serve.pool``): page size comes from the artifact's
``kv_page`` cell for the target hardware, page-table indirection runs
through decode and (packed) chunked prefill, identical prompt prefixes are
served from shared refcounted pages (copy-on-write on divergence; disable
with ``--no-prefix-sharing``), and prefill admission is gated by pool
headroom instead of ``--prefill-slots``. Served tokens are identical to
the contiguous path; pool counters print under ``pool`` in the metrics.

``--autoscale`` (with ``--fleet``) starts from a minimal fleet and lets
the telemetry-driven :class:`~repro.serve.autoscale.AutoscalePolicy`
join/drain instances between ``--min-instances`` and ``--max-instances``:
every listed hardware model is a scale candidate, priced by the live
traffic mix, so compute-heavy and memory-heavy workloads grow DIFFERENT
hardware. Decisions land on the fleet trace lane and under ``autoscale``
in the exit metrics.

``--refine`` closes the loop from telemetry back to the plan: engines divert
``--shadow-fraction`` of their steps to shadow-measuring candidate tiles
from the artifact's sensitivity curves (served tokens are untouched), the
shared :class:`~repro.serve.refine.PlanRefiner` re-ranks confidently-better
cells at exit, the refined artifact is written to ``--refine-out``, and the
deployment rolls onto it (one instance at a time through the fleet router's
rollback guard).

``--trace-out trace.json`` records the full request lifecycle (submit ->
admit/reject -> prefill chunks -> first token -> decode -> finish), every
plan-resolution audit record, and shadow/rollout decisions through
``repro.obs``; the file loads in Perfetto (ui.perfetto.dev) and feeds
``python -m repro.launch.trace_report`` for waterfalls and regression diffs.
"""
from __future__ import annotations

import argparse
import json
import logging
import time

import jax
import numpy as np

from repro import configs
from repro.core import HARDWARE_REGISTRY, PRODUCTION_TARGET
from repro.core.plans import TilePlan
from repro.models import api
from repro.serve import (BucketPolicy, FleetExhausted, FleetRouter,
                         ServeEngine, make_scheduler)


def build_policy(spec: str, plans, hardware_name, max_queue: int,
                 allow_overflow: bool = False) -> BucketPolicy:
    """One policy for the whole deployment. ``hardware_name=None`` derives
    "plan" edges from every hardware's cells (the union) — a fleet must
    share a single edge set or the router's bucketing and each engine's
    would diverge."""
    if spec == "plan":
        if plans is None:
            raise SystemExit("--bucket-policy plan requires --tile-plans")
        return BucketPolicy.from_plan(plans, hardware=hardware_name,
                                      max_queue=max_queue,
                                      allow_overflow=allow_overflow)
    return BucketPolicy.parse(spec, max_queue=max_queue,
                              allow_overflow=allow_overflow)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b",
                    choices=configs.list_archs())
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--tile-plans", default=None,
                    help="compiled TilePlan artifact (JSON)")
    ap.add_argument("--hardware", default=PRODUCTION_TARGET.name,
                    choices=sorted(HARDWARE_REGISTRY))
    ap.add_argument("--scheduler", default="fifo", choices=("fifo", "bucket"),
                    help="admission policy: naive FIFO or shape-bucketed")
    ap.add_argument("--bucket-policy", default="pow2:16:128",
                    help='bucket edges: "64,128", "pow2:lo:hi", or "plan" '
                         "(derive from the --tile-plans artifact)")
    ap.add_argument("--max-queue", type=int, default=256,
                    help="admission bound for the bucketed scheduler")
    ap.add_argument("--chunk-prefill", action="store_true",
                    help="split prompts into plan-sized chunks and build "
                         "mixed prefill/decode steps (admits over-length "
                         "prompts via chunking)")
    ap.add_argument("--step-token-budget", type=int, default=0,
                    help="max tokens one mixed step may process (prefill "
                         "chunk + decode batch); 0 = plan chunk unclamped")
    ap.add_argument("--prefill-slots", type=int, default=2,
                    help="concurrent partially-prefilled requests (chunked "
                         "mode; lets short prompts overtake long ones)")
    ap.add_argument("--pack-prefill", action="store_true",
                    help="pack MULTIPLE prefill chunks (plus the decode "
                         "batch) into each step under --step-token-budget "
                         "and the plan's per-hardware pack width, instead "
                         "of one chunk per step (implies --chunk-prefill)")
    ap.add_argument("--paged", action="store_true",
                    help="serve from the paged KV pool (page size from the "
                         "plan's kv_page cell; shared-prefix copy-on-write "
                         "reuse; admission by pool headroom — implies "
                         "--chunk-prefill)")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable shared-prefix page reuse in --paged mode")
    ap.add_argument("--fleet", default="",
                    help="comma list of hardware models; serve through the "
                         "fleet router with one engine per model "
                         "(overrides --hardware)")
    ap.add_argument("--watchdog-threshold", type=int, default=8,
                    help="fleet: consecutive no-progress steps before an "
                         "instance is declared stalled and its work "
                         "recovered onto survivors")
    ap.add_argument("--retry-budget", type=int, default=2,
                    help="fleet: recovery attempts per request before it "
                         "is declared lost")
    ap.add_argument("--autoscale", action="store_true",
                    help="fleet: start with ONE instance (the first --fleet "
                         "model) and let the telemetry-driven policy join/"
                         "drain instances — every --fleet model is a scale "
                         "candidate, priced by the live traffic mix")
    ap.add_argument("--min-instances", type=int, default=1,
                    help="autoscale: never drain below this many instances")
    ap.add_argument("--max-instances", type=int, default=4,
                    help="autoscale: never join above this many instances")
    ap.add_argument("--refine", action="store_true",
                    help="shadow-measure candidate tiles during service and "
                         "emit a refined (re-ranked) plan artifact at exit; "
                         "requires --tile-plans")
    ap.add_argument("--shadow-fraction", type=float, default=1 / 32,
                    help="fraction of steps diverted to shadow measurement "
                         "when --refine is on (deterministic counter-based "
                         "sampling; default 1/32)")
    ap.add_argument("--refine-out", default=None,
                    help="write the refined plan artifact here (with "
                         "--refine; default: print the drift summary only)")
    ap.add_argument("--metrics-json", action="store_true",
                    help="dump full metrics as JSON instead of the summary")
    ap.add_argument("--trace-out", default=None,
                    help="write a request-lifecycle / plan-audit trace here "
                         "(.jsonl for JSONL, else Chrome/Perfetto JSON; "
                         "inspect with python -m repro.launch.trace_report)")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    # The fleet router's cost model (and autoscale candidate pricing)
    # scores default tiles straight from the kernel registry; engines only
    # register lazily on their first plan resolution, which is too late
    # for the first route() call.
    from repro import kernels

    kernels.register_all()
    cfg = configs.get_smoke(args.arch)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    plans = TilePlan.load_or_none(args.tile_plans)

    refiner = None
    if args.refine:
        if plans is None:
            raise SystemExit("--refine requires a loadable --tile-plans "
                             "artifact (shadow candidates come from its "
                             "sensitivity curves)")
        from repro.serve import PlanRefiner

        refiner = PlanRefiner()

    tracer = None
    if args.trace_out:
        from repro.obs import Tracer

        tracer = Tracer()  # wall clock, same as the launcher's timing

    fleet_names = [h for h in args.fleet.split(",") if h]
    policy = None
    if args.scheduler == "bucket":
        # Fleet: derive "plan" edges across all hardware (union) so router
        # and engines share one bucketing; single engine: its own cells.
        policy = build_policy(
            args.bucket_policy, plans,
            None if fleet_names else args.hardware, args.max_queue,
            allow_overflow=(args.chunk_prefill or args.pack_prefill
                            or args.paged))

    def make_engine(hw_name: str, instance: str = None) -> ServeEngine:
        return ServeEngine(
            cfg, params, max_len=args.max_len, slots=args.slots,
            plans=plans, hardware=HARDWARE_REGISTRY[hw_name],
            scheduler=make_scheduler(args.scheduler, policy),
            chunk_prefill=args.chunk_prefill,
            step_token_budget=args.step_token_budget,
            prefill_slots=args.prefill_slots,
            pack_prefill=args.pack_prefill,
            paged=args.paged,
            prefix_sharing=not args.no_prefix_sharing,
            shadow_fraction=args.shadow_fraction if args.refine else 0.0,
            refiner=refiner, tracer=tracer,
            instance=instance or hw_name)

    router = None
    if fleet_names:
        if args.scheduler != "bucket":
            raise SystemExit("--fleet requires --scheduler bucket "
                             "(routing is per shape bucket)")
        autoscaler = None
        seed_names = fleet_names
        if args.autoscale:
            from repro.serve import AutoscalePolicy, ScaleCandidate

            # Start minimal; every --fleet model is a candidate the policy
            # may join (under its own name, suffixed on re-join) when the
            # mix-priced cost says so.
            candidates = tuple(
                ScaleCandidate(name=h, hardware=h,
                               make_engine=lambda name, hw=h:
                                   make_engine(hw, instance=name))
                for h in fleet_names)
            autoscaler = AutoscalePolicy(
                candidates, min_instances=args.min_instances,
                max_instances=args.max_instances)
            seed_names = fleet_names[:max(1, args.min_instances)]
        router = FleetRouter({h: make_engine(h) for h in seed_names}, policy,
                             tracer=tracer,
                             watchdog_threshold=args.watchdog_threshold,
                             retry_budget=args.retry_budget,
                             autoscaler=autoscaler)
    elif args.autoscale:
        raise SystemExit("--autoscale requires --fleet (the candidates come "
                         "from its hardware list)")
    else:
        engine = make_engine(args.hardware)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    rejected = 0
    for i in range(args.requests):
        prompt = rng.integers(2, cfg.vocab_size, size=rng.integers(4, 12))
        if router is not None:
            ok = router.route(prompt, max_new_tokens=args.new_tokens)
        else:
            ok = engine.add_request(prompt, max_new_tokens=args.new_tokens)
        rejected += ok is None

    if router is not None:
        try:
            done_by = router.run_until_done()
        except FleetExhausted as exc:
            # Surface exhaustion loudly (a partial result set must never
            # read as a complete run) but still report what DID finish.
            print(f"WARNING: {exc}")
            done_by = {name: list(eng._finished)
                       for name, eng in router.engines.items()}
        done = [r for rs in done_by.values() for r in rs]
        for name, rs in sorted(done_by.items()):
            for r in rs:
                print(f"req {r.rid}@{name}: {r.out_tokens}")
        print("placements:", {str(b): p for b, p in
                              sorted(router.placements().items())})
        metrics = router.metrics()
        scale = metrics.get("autoscale")
        if scale is not None:
            print(f"autoscale: {scale['joins']} join(s), "
                  f"{scale['drains']} drain(s) over "
                  f"{scale['evaluations']} evaluation(s); final fleet: "
                  f"{router.live_instances()}")
            for entry in scale["log"]:
                print(f"  step {entry['step']}: {entry['action']} "
                      f"{entry['instance']} ({entry['reason']})")
    else:
        done = engine.run_until_done()
        for r in done:
            print(f"req {r.rid}: {r.out_tokens}")
        metrics = engine.metrics.as_dict()

    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"{len(done)} requests ({rejected} rejected), {toks} tokens in "
          f"{dt:.2f}s ({toks / dt:.1f} tok/s)")

    if refiner is not None:
        from repro.serve import drift_report

        refine_trace = (tracer.attach("refiner", kind="refiner")
                        if tracer is not None else None)
        refined = refiner.refine(plans, trace=refine_trace)
        report = drift_report(refined)
        print(f"refined {report['n_refined']} cell(s) from "
              f"{report['shadow_samples']} shadow sample(s)")
        for cell in report["cells"]:
            print(f"  {cell['cell']}: {cell['incumbent']} -> "
                  f"{cell['refined']} ({cell['speedup']:.2f}x, "
                  f"{cell['samples']} samples)")
        if args.refine_out:
            refined.save(args.refine_out)
            print(f"refined plan artifact -> {args.refine_out}")
        # Versioned rollout: the fleet rolls one instance at a time via the
        # p95-TTFT guard (unguarded here — the demo has no probe traffic);
        # a single engine just swaps.
        if router is not None:
            for decision in router.roll_plans(refined):
                print(f"rolled {decision.instance}: "
                      f"rolled_back={decision.rolled_back}")
        else:
            engine.set_plans(refined)
            print("engine rolled onto the refined artifact")

    if tracer is not None:
        from repro.obs import write_jsonl, write_trace

        if args.trace_out.endswith(".jsonl"):
            write_jsonl(tracer, args.trace_out)
        else:
            write_trace(tracer, args.trace_out)
        print(f"trace -> {args.trace_out} "
              f"({len(tracer.events)} events; open in ui.perfetto.dev or "
              f"run python -m repro.launch.trace_report {args.trace_out})")

    if args.metrics_json:
        print(json.dumps(metrics, indent=1, sort_keys=True, default=str))
    elif router is not None:
        for name, eng in sorted(router.engines.items()):
            print(f"--- {name}")
            print(eng.metrics.render())
    else:
        print(engine.metrics.render())


if __name__ == "__main__":
    main()
