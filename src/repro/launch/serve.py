"""Serving launcher: batched request demo on the reduced config.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --requests 6

``--tile-plans plans.json`` resolves decode-path kernel tiles from a
compiled AOT artifact (see ``repro.launch.compile_plans``) instead of
tuning lazily; a corrupt/missing artifact degrades to heuristics.
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import numpy as np

from repro import configs
from repro.core import HARDWARE_REGISTRY, PRODUCTION_TARGET
from repro.core.plans import TilePlan
from repro.models import api
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b",
                    choices=configs.list_archs())
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--tile-plans", default=None,
                    help="compiled TilePlan artifact (JSON)")
    ap.add_argument("--hardware", default=PRODUCTION_TARGET.name,
                    choices=sorted(HARDWARE_REGISTRY))
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    cfg = configs.get_smoke(args.arch)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_len=args.max_len, slots=args.slots,
                         plans=TilePlan.load_or_none(args.tile_plans),
                         hardware=HARDWARE_REGISTRY[args.hardware])

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        prompt = rng.integers(2, cfg.vocab_size, size=rng.integers(4, 12))
        engine.add_request(prompt, max_new_tokens=args.new_tokens)
    done = engine.run_until_done()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    for r in done:
        print(f"req {r.rid}: {r.out_tokens}")
    print(f"{len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
