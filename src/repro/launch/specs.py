"""ShapeDtypeStruct stand-ins for every model input — no device allocation.

``input_specs(cfg, shape)`` returns the abstract batch for a (arch x shape)
cell; ``state_specs`` builds the abstract params / optimizer / serve-state
trees via jax.eval_shape. The dry-run lowers against these.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeSpec
from repro.models import api
from repro.optim import adamw

SDS = jax.ShapeDtypeStruct


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract train/prefill batch for one cell."""
    b, s = shape.global_batch, shape.seq_len
    if api.is_encdec(cfg):
        return {
            "frames": SDS((b, cfg.encoder.seq_len, cfg.d_model), jnp.bfloat16),
            "tokens": SDS((b, s), jnp.int32),
            "targets": SDS((b, s), jnp.int32),
        }
    if api.is_vlm(cfg):
        p = cfg.encoder.seq_len
        # Total sequence = p patch positions + text tail; loss on text only.
        return {
            "patch_embeds": SDS((b, p, 1024), jnp.bfloat16),
            "tokens": SDS((b, s - p), jnp.int32),
            "targets": SDS((b, s - p), jnp.int32),
        }
    return {
        "tokens": SDS((b, s), jnp.int32),
        "targets": SDS((b, s), jnp.int32),
    }


def decode_token_spec(cfg: ArchConfig, shape: ShapeSpec) -> SDS:
    return SDS((shape.global_batch, 1), jnp.int32)


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: api.init_params(cfg, jax.random.PRNGKey(0), dtype=dtype)
    )


def abstract_opt_state(params, opt_cfg: adamw.AdamWConfig):
    return jax.eval_shape(lambda p: adamw.init_state(p, opt_cfg), params)


def abstract_serve_state(cfg: ArchConfig, shape: ShapeSpec,
                         dtype=jnp.bfloat16, params=None):
    """Abstract KV/recurrent state for a decode cell (cache len = seq_len)."""
    b, s = shape.global_batch, shape.seq_len
    if api.is_encdec(cfg):
        enc = SDS((b, cfg.encoder.seq_len, cfg.d_model), dtype)
        return jax.eval_shape(
            lambda p, e: api.make_serve_state(
                cfg, b, s, dtype, enc_out=e, params=p),
            params, enc,
        )
    from repro.models import transformer as T
    return jax.eval_shape(
        lambda: T.make_caches(cfg, b, s, dtype,
                              ring_local=bool(cfg.attn_window))
    )
