"""ShapeDtypeStruct stand-ins for every model input — no device allocation.

``input_specs(cfg, shape)`` returns the abstract batch for a (arch x shape)
cell; ``state_specs`` builds the abstract params / optimizer / serve-state
trees via jax.eval_shape. The dry-run lowers against these.

``kernel_problems(cfg, batch, seq_len, kind)`` is the tile-plan counterpart:
it maps the same cell onto the tunable-kernel problem dicts the AOT plan
compiler sweeps and the serve/train hot paths resolve against.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeSpec
from repro.models import api
from repro.optim import adamw

SDS = jax.ShapeDtypeStruct

# Cap the token dim fed to the matmul tuning problem; beyond this the
# optimum is insensitive to m (compute-bound steady state).
MAX_PLAN_TOKENS = 65536


def kernel_problems(cfg: ArchConfig, batch: int, seq_len: int,
                    kind: str) -> Dict[str, Dict[str, int]]:
    """Per-kernel tile-tuning problems for one (config, geometry) cell.

    ``kind``: "train" | "prefill" (full-sequence), "decode" (one token per
    sequence against a KV cache of ``seq_len``), "chunked_prefill" (the
    full ``seq_len`` prompt prefilled in scheduler-sized chunks — same
    geometry as "prefill" but the attention cell is the ``chunked_prefill``
    kernel, whose tile ``(chunk, bkv)`` makes the chunk length a
    first-class tuning axis), or "packed_prefill" (N requests of the
    ``seq_len`` bucket class segment-concatenated into one launch — the
    attention cell is ``packed_prefill``, whose tile ``(pack, bkv)`` makes
    the PACK WIDTH the tuning axis; see kernels/flash_attention/ops.py).
    Pure config arithmetic — no jax, no sweeps — so hot paths can call it
    at init time.
    """
    decode = kind == "decode"
    chunked = kind == "chunked_prefill"
    packed = kind == "packed_prefill"
    tokens = batch if decode else min(batch * seq_len, MAX_PLAN_TOKENS)
    problems: Dict[str, Dict[str, int]] = {
        # The FF projection GEMM dominates per-layer step time.
        "matmul": dict(m=tokens, k=cfg.d_model, n=cfg.d_ff or cfg.d_model),
    }
    mixers = {spec.mixer for spec in cfg.layers()}
    if mixers & {"attn", "local_attn"}:
        # Hybrids (attn + local_attn) tune for the global-attention workload:
        # it dominates cost, and a window-limited problem would mischaracterize
        # the full-attention layers (per-layer plans are a ROADMAP item).
        window = cfg.attn_window if "attn" not in mixers else 0
        if decode:
            # Decode is its own kernel (split-KV flash decode), not a
            # degenerate sq=1 prefill cell: the tunable axis is the KV
            # split size and the sensitivity curve is decode's own.
            problems["flash_decode"] = dict(
                b=batch,
                skv=seq_len,
                d=cfg.head_dim_,
                hq=max(cfg.n_heads, 1),
                hkv=max(cfg.n_kv_heads, 1),
                window=window,
            )
            # Page geometry of the paged KV pool rides the decode cell's
            # geometry: the cache length bounds the page and decode is the
            # steady-state reader the page is tuned for (serve/pool.py).
            problems["kv_page"] = dict(
                skv=seq_len,
                d=cfg.head_dim_,
                hkv=max(cfg.n_kv_heads, 1),
            )
        else:
            attn_kernel = ("packed_prefill" if packed
                           else "chunked_prefill" if chunked
                           else "flash_attention")
            problems[attn_kernel] = dict(
                sq=seq_len,
                skv=seq_len,
                d=cfg.head_dim_,
                hq=max(cfg.n_heads, 1),
                hkv=max(cfg.n_kv_heads, 1),
                window=window,
            )
    if "rglru" in mixers and cfg.recurrent is not None:
        problems["rglru"] = dict(
            s=1 if decode else seq_len,
            f=cfg.recurrent.lru_width or cfg.d_model,
        )
    if "ssd" in mixers and cfg.ssm is not None:
        problems["ssd"] = dict(
            s=1 if decode else seq_len,
            h=cfg.ssm.n_heads(cfg.d_model),
            p=cfg.ssm.head_dim,
            n=cfg.ssm.d_state,
        )
    return problems


def cell_problems(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Dict[str, int]]:
    """``kernel_problems`` for one of the assigned (arch x shape) cells."""
    return kernel_problems(cfg, shape.global_batch, shape.seq_len, shape.kind)


def resolve_model_tiles(plans, cfg: ArchConfig, batch: int, seq_len: int,
                        kind: str, dtype: str, hardware):
    """Resolve every kernel tile for one model geometry from an AOT plan.

    Shared by ServeEngine and Trainer construction. Never sweeps: cells the
    plan cannot resolve fall back to the kernel's zero-cost heuristic
    default. Returns ``(tiles, resolutions)`` — kernel name -> TileShape,
    and kernel name -> PlanResolution for the cells the plan satisfied.
    """
    import logging

    from repro import kernels as kernel_pkg
    from repro.core import registry

    log = logging.getLogger("repro.plans")
    kernel_pkg.register_all()
    tiles, resolutions = {}, {}
    for kernel, problem in kernel_problems(cfg, batch, seq_len, kind).items():
        res = plans.resolve(kernel, problem, dtype, hardware)
        if res is None:
            tiles[kernel] = registry.get(kernel).default_tile(problem, dtype)
            log.warning("no tile plan for %s on %s; using heuristic "
                        "default %s", kernel, hardware.name, tiles[kernel])
        else:
            tiles[kernel] = res.tile
            resolutions[kernel] = res
            log.info("tile plan %s on %s: %s (%s)", kernel, hardware.name,
                     res.tile, res.source)
    return tiles, resolutions


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract train/prefill batch for one cell."""
    b, s = shape.global_batch, shape.seq_len
    if api.is_encdec(cfg):
        return {
            "frames": SDS((b, cfg.encoder.seq_len, cfg.d_model), jnp.bfloat16),
            "tokens": SDS((b, s), jnp.int32),
            "targets": SDS((b, s), jnp.int32),
        }
    if api.is_vlm(cfg):
        p = cfg.encoder.seq_len
        # Total sequence = p patch positions + text tail; loss on text only.
        return {
            "patch_embeds": SDS((b, p, 1024), jnp.bfloat16),
            "tokens": SDS((b, s - p), jnp.int32),
            "targets": SDS((b, s - p), jnp.int32),
        }
    return {
        "tokens": SDS((b, s), jnp.int32),
        "targets": SDS((b, s), jnp.int32),
    }


def decode_token_spec(cfg: ArchConfig, shape: ShapeSpec) -> SDS:
    return SDS((shape.global_batch, 1), jnp.int32)


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: api.init_params(cfg, jax.random.PRNGKey(0), dtype=dtype)
    )


def abstract_opt_state(params, opt_cfg: adamw.AdamWConfig):
    return jax.eval_shape(lambda p: adamw.init_state(p, opt_cfg), params)


def abstract_serve_state(cfg: ArchConfig, shape: ShapeSpec,
                         dtype=jnp.bfloat16, params=None):
    """Abstract KV/recurrent state for a decode cell (cache len = seq_len)."""
    b, s = shape.global_batch, shape.seq_len
    if api.is_encdec(cfg):
        enc = SDS((b, cfg.encoder.seq_len, cfg.d_model), dtype)
        return jax.eval_shape(
            lambda p, e: api.make_serve_state(
                cfg, b, s, dtype, enc_out=e, params=p),
            params, enc,
        )
    from repro.models import transformer as T
    return jax.eval_shape(
        lambda: T.make_caches(cfg, b, s, dtype,
                              ring_local=bool(cfg.attn_window))
    )
