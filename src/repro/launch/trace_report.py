"""Trace analysis / regression-diff CLI for serving traces.

Usage::

    # Summarize one trace: per-request waterfall, plan-source attribution,
    # pack-occupancy summary, autoscale decision log.
    python -m repro.launch.trace_report trace.json

    # Regression diff: BASE then CANDIDATE. Exits nonzero when the
    # candidate's pooled p95 TTFT regresses past --ttft-tol x the base's,
    # or its packed-step occupancy drops below base / --occupancy-tol.
    python -m repro.launch.trace_report base.json candidate.json --diff

Traces come from any ``--trace-out`` surface (``repro.launch.serve``, the
three serving benches) in Chrome-trace JSON or JSONL form — see
:mod:`repro.obs.trace` for the event vocabulary this report reads and
:mod:`repro.obs.export` for the formats. The TTFT statistics here use the
same nearest-rank percentile over the trace's ``ttft`` span durations that
:class:`~repro.serve.metrics.ServeMetrics` uses over its samples, so a
trace reproduces the engine's reported percentiles exactly.

Exit codes: 0 ok / no regression; 1 threshold breach in ``--diff``;
2 usage or unreadable trace.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import Counter
from typing import Any, Dict, List, Optional

from repro.obs.export import load_trace
from repro.serve.metrics import nearest_rank


def _proc_names(trace: Dict[str, Any]) -> Dict[int, str]:
    return {p["pid"]: p["name"] for p in trace.get("procs", [])}


def _args(ev: Dict[str, Any]) -> Dict[str, Any]:
    return ev.get("args") or {}


def waterfall(trace: Dict[str, Any]) -> List[Dict[str, Any]]:
    """Per-request lifecycle rows, ordered by (process, submit time, rid)."""
    rows: Dict[tuple, Dict[str, Any]] = {}

    def row(pid: int, rid: Any) -> Dict[str, Any]:
        return rows.setdefault((pid, rid), {
            "pid": pid, "rid": rid, "bucket": None, "submit": None,
            "wait_s": None, "chunks": 0, "packed_chunks": 0,
            "ttft_s": None, "finish": None, "tokens": None,
        })

    for ev in trace["events"]:
        name, a = ev.get("name"), _args(ev)
        if name == "submit":
            r = row(ev["pid"], a.get("rid"))
            r["submit"] = ev["ts"]
            r["bucket"] = a.get("bucket")
        elif name == "admit":
            row(ev["pid"], a.get("rid"))["wait_s"] = a.get("wait_s")
        elif name == "chunk":
            r = row(ev["pid"], a.get("rid"))
            r["chunks"] += 1
            r["packed_chunks"] += 1 if a.get("pack_n", 1) > 1 else 0
        elif name == "ttft":
            r = row(ev["pid"], a.get("rid"))
            r["ttft_s"] = ev.get("dur", 0.0)
            if r["bucket"] is None:
                r["bucket"] = a.get("bucket")
        elif name == "finish":
            r = row(ev["pid"], a.get("rid"))
            r["finish"] = ev["ts"]
            r["tokens"] = a.get("tokens")
    ordered = sorted(rows.values(), key=lambda r: (
        r["pid"], r["submit"] if r["submit"] is not None else float("inf"),
        str(r["rid"])))
    return ordered


def plan_attribution(trace: Dict[str, Any]) -> List[Dict[str, Any]]:
    """(process, phase, kernel, source) -> resolution count."""
    counts: Counter = Counter()
    for ev in trace["events"]:
        if ev.get("name") != "plan_resolve":
            continue
        a = _args(ev)
        counts[(ev["pid"], a.get("phase"), a.get("kernel"),
                a.get("source"))] += 1
    return [
        {"pid": pid, "phase": phase, "kernel": kernel, "source": source,
         "count": n}
        for (pid, phase, kernel, source), n in sorted(
            counts.items(), key=lambda kv: (kv[0][0], str(kv[0][1:])))
    ]


def pack_occupancy(trace: Dict[str, Any]) -> Dict[str, Any]:
    """Packed-chunks-per-step distribution over the trace's step spans."""
    hist: Counter = Counter()
    steps = prefill_steps = 0
    total_packed = 0
    for ev in trace["events"]:
        if ev.get("name") != "step":
            continue
        a = _args(ev)
        steps += 1
        packed = int(a.get("packed_chunks", 0) or 0)
        if packed:
            prefill_steps += 1
            total_packed += packed
            hist[packed] += 1
    return {
        "steps": steps,
        "prefill_steps": prefill_steps,
        "mean_packed_chunks": (total_packed / prefill_steps
                               if prefill_steps else 0.0),
        "histogram": {str(k): hist[k] for k in sorted(hist)},
    }


def autoscale_log(trace: Dict[str, Any]) -> List[Dict[str, Any]]:
    """Autoscale decisions (the fleet lane's ``autoscale`` instants) in
    time order, each with its full signal snapshot."""
    out = []
    for ev in trace["events"]:
        if ev.get("name") != "autoscale":
            continue
        a = _args(ev)
        out.append({"ts": ev.get("ts"), "pid": ev["pid"],
                    "action": a.get("action"), "instance": a.get("instance"),
                    "hardware": a.get("hardware"), "reason": a.get("reason"),
                    "signals": a.get("signals") or {}})
    out.sort(key=lambda d: (d["ts"] if d["ts"] is not None else 0.0,
                            str(d["instance"])))
    return out


def ttft_values(trace: Dict[str, Any]) -> List[float]:
    """Every request's TTFT (the ``ttft`` span durations), pooled."""
    return [ev.get("dur", 0.0) for ev in trace["events"]
            if ev.get("name") == "ttft"]


def rejects(trace: Dict[str, Any]) -> Dict[str, int]:
    counts: Counter = Counter()
    for ev in trace["events"]:
        if ev.get("name") in ("reject", "route_reject"):
            counts[_args(ev).get("reason", "unknown")] += 1
    return {k: counts[k] for k in sorted(counts)}


def summarize(trace: Dict[str, Any]) -> Dict[str, Any]:
    ttfts = ttft_values(trace)
    return {
        "processes": _proc_names(trace),
        "requests": len({(r["pid"], r["rid"]) for r in waterfall(trace)}),
        "ttft": {
            "n": len(ttfts),
            "p50_s": nearest_rank(ttfts, 0.50),
            "p95_s": nearest_rank(ttfts, 0.95),
            "p99_s": nearest_rank(ttfts, 0.99),
        },
        "occupancy": pack_occupancy(trace),
        "rejects": rejects(trace),
        "autoscale": autoscale_log(trace),
    }


def render(trace: Dict[str, Any], max_rows: int = 20) -> str:
    names = _proc_names(trace)
    s = summarize(trace)
    lines = [
        f"trace: {len(trace['events'])} events, "
        f"{len(names)} processes, {s['requests']} requests",
        f"ttft: n={s['ttft']['n']} p50={s['ttft']['p50_s'] * 1e3:.2f}ms "
        f"p95={s['ttft']['p95_s'] * 1e3:.2f}ms "
        f"p99={s['ttft']['p99_s'] * 1e3:.2f}ms",
        f"pack occupancy: {s['occupancy']['prefill_steps']}/"
        f"{s['occupancy']['steps']} steps carried prefill, "
        f"mean {s['occupancy']['mean_packed_chunks']:.2f} chunks/step, "
        f"histogram {s['occupancy']['histogram']}",
    ]
    if s["rejects"]:
        lines.append(f"rejects: {s['rejects']}")

    lines.append("")
    lines.append("request waterfall (per process, by submit time):")
    lines.append(f"  {'proc':<14} {'rid':>5} {'bucket':>6} {'wait_ms':>8} "
                 f"{'chunks':>6} {'packed':>6} {'ttft_ms':>8} {'tokens':>6}")
    rows = waterfall(trace)
    for r in rows[:max_rows]:

        def ms(x: Optional[float]) -> str:
            return f"{x * 1e3:.2f}" if x is not None else "-"

        lines.append(
            f"  {names.get(r['pid'], r['pid']):<14} {str(r['rid']):>5} "
            f"{str(r['bucket']):>6} {ms(r['wait_s']):>8} "
            f"{r['chunks']:>6} {r['packed_chunks']:>6} "
            f"{ms(r['ttft_s']):>8} {str(r['tokens']):>6}")
    if len(rows) > max_rows:
        lines.append(f"  ... {len(rows) - max_rows} more "
                     f"(--max-rows to widen)")

    lines.append("")
    lines.append("plan-source attribution:")
    lines.append(f"  {'proc':<14} {'phase':<8} {'kernel':<22} "
                 f"{'source':<14} {'n':>4}")
    for row in plan_attribution(trace):
        lines.append(
            f"  {names.get(row['pid'], row['pid']):<14} "
            f"{str(row['phase']):<8} {str(row['kernel']):<22} "
            f"{str(row['source']):<14} {row['count']:>4}")

    scale = s["autoscale"]
    if scale:
        lines.append("")
        lines.append("autoscale decisions:")
        lines.append(f"  {'t_s':>10} {'action':<6} {'instance':<14} "
                     f"{'reason':<16} signals")
        for d in scale:
            sig = d["signals"]
            ttft = sig.get("p95_ttft")
            brief = (f"q/inst={sig.get('queue_per_instance')} "
                     f"p95={ttft * 1e3:.1f}ms " if ttft is not None else
                     f"q/inst={sig.get('queue_per_instance')} p95=- ")
            brief += (f"orphans={sig.get('orphans')} "
                      f"fleet={sig.get('instances')}")
            ts = f"{d['ts']:.3f}" if d["ts"] is not None else "-"
            lines.append(f"  {ts:>10} {str(d['action']):<6} "
                         f"{str(d['instance']):<14} {str(d['reason']):<16} "
                         f"{brief}")
    return "\n".join(lines)


def diff(base: Dict[str, Any], cand: Dict[str, Any],
         ttft_tol: float = 1.10, occupancy_tol: float = 1.10
         ) -> List[str]:
    """Regression breaches of ``cand`` against ``base`` (empty = clean).

    TTFT: candidate pooled p95 must not exceed ``ttft_tol`` x base p95.
    Occupancy: candidate mean packed-chunks-per-prefill-step must not drop
    below base / ``occupancy_tol`` (only checked when the base actually
    packed — an unpacked pair trivially passes).
    """
    breaches: List[str] = []
    b, c = summarize(base), summarize(cand)
    b95, c95 = b["ttft"]["p95_s"], c["ttft"]["p95_s"]
    if b["ttft"]["n"] and c["ttft"]["n"] and b95 > 0.0 \
            and c95 > ttft_tol * b95:
        breaches.append(
            f"ttft p95 regressed: {c95 * 1e3:.3f}ms vs base "
            f"{b95 * 1e3:.3f}ms (x{c95 / b95:.3f} > tol {ttft_tol})")
    b_occ = b["occupancy"]["mean_packed_chunks"]
    c_occ = c["occupancy"]["mean_packed_chunks"]
    if b_occ > 0.0 and c_occ < b_occ / occupancy_tol:
        breaches.append(
            f"pack occupancy regressed: {c_occ:.3f} chunks/step vs base "
            f"{b_occ:.3f} (x{c_occ / b_occ:.3f} < 1/tol {occupancy_tol})")
    return breaches


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.trace_report",
        description="Summarize a serving trace, or diff two for "
                    "TTFT/occupancy regressions.")
    ap.add_argument("trace", help="trace file (Chrome JSON or JSONL)")
    ap.add_argument("candidate", nargs="?", default=None,
                    help="candidate trace to diff against the first (base)")
    ap.add_argument("--diff", action="store_true",
                    help="diff mode: exit 1 when the candidate regresses")
    ap.add_argument("--ttft-tol", type=float, default=1.10,
                    help="allowed candidate/base p95-TTFT ratio "
                         "(default 1.10)")
    ap.add_argument("--occupancy-tol", type=float, default=1.10,
                    help="allowed base/candidate occupancy ratio "
                         "(default 1.10)")
    ap.add_argument("--json", action="store_true",
                    help="emit machine-readable JSON instead of text")
    ap.add_argument("--max-rows", type=int, default=20,
                    help="waterfall rows to print (default 20)")
    args = ap.parse_args(argv)

    if args.diff and args.candidate is None:
        print("--diff needs two traces: BASE CANDIDATE", file=sys.stderr)
        return 2
    try:
        base = load_trace(args.trace)
        cand = load_trace(args.candidate) if args.candidate else None
    except (OSError, ValueError, KeyError, json.JSONDecodeError) as e:
        print(f"cannot load trace: {e}", file=sys.stderr)
        return 2

    if args.diff:
        assert cand is not None
        breaches = diff(base, cand, ttft_tol=args.ttft_tol,
                        occupancy_tol=args.occupancy_tol)
        if args.json:
            print(json.dumps({"base": summarize(base),
                              "candidate": summarize(cand),
                              "breaches": breaches},
                             indent=1, sort_keys=True))
        else:
            print(f"base:      {args.trace}")
            print(f"candidate: {args.candidate}")
            for line in breaches:
                print(f"REGRESSION: {line}")
            if not breaches:
                print("no regression: candidate within thresholds")
        return 1 if breaches else 0

    if args.json:
        out: Dict[str, Any] = summarize(base)
        out["waterfall"] = waterfall(base)
        out["plan_attribution"] = plan_attribution(base)
        print(json.dumps(out, indent=1, sort_keys=True))
    else:
        print(render(base, max_rows=args.max_rows))
    return 0


if __name__ == "__main__":
    sys.exit(main())
