"""Training launcher.

CPU demo (default): reduced config, real training loop with checkpoints:
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --steps 50

Production flags mirror the dry-run: ``--mesh single|multi`` builds the
16x16 / 2x16x16 mesh (on a real TPU slice the same code path runs the full
config; on this CPU container use --smoke).
"""
from __future__ import annotations

import argparse
import logging

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.pipeline import DataConfig
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b",
                    choices=configs.list_archs())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="use the reduced config (CPU demo)")
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--peak-lr", type=float, default=1e-3)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (FT demo)")
    ap.add_argument("--tile-plans", default=None,
                    help="compiled TilePlan artifact (JSON); corrupt/missing "
                         "degrades to heuristic tiles")
    ap.add_argument("--hardware", default="",
                    help="hardware model to resolve tiles for "
                         "(default: production target)")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get_arch(args.arch))
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                          global_batch=args.global_batch)
    tcfg = TrainerConfig(
        steps=args.steps, checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir, peak_lr=args.peak_lr,
        microbatches=args.microbatches, log_every=10,
        tile_plans=args.tile_plans, hardware=args.hardware,
    )
    trainer = Trainer(cfg, data_cfg, tcfg,
                      opt_cfg=adamw.AdamWConfig(weight_decay=0.01))
    out = trainer.run(fail_at=args.fail_at)
    print(f"final loss: {out['losses'][-1]:.4f}  "
          f"restarts: {out['restarts']}  "
          f"stragglers: {out['straggler_events']}")


if __name__ == "__main__":
    main()
