"""Unified model API across families (decoder-only, vlm, enc-dec).

Batch conventions:
    LM:    {"tokens": [B,S] int32, "targets": [B,S] int32}
    VLM:   + {"patch_embeds": [B, P, 1024]} (frontend stub); tokens are the
             text tail, total sequence = P + S_text
    audio: {"frames": [B, S_enc, D]} + tokens/targets for the decoder

Serve state is an opaque pytree from ``make_serve_state`` consumed by
``prefill`` / ``decode_step``.
"""
from __future__ import annotations

from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.tiling import TileShape
from repro.models import encdec as E
from repro.models import transformer as T
from repro.models.context import DistContext

# Resolved kernel tiles (kernel name -> TileShape), as produced by
# ``launch.specs.resolve_model_tiles`` from an AOT TilePlan. Threaded from
# ServeEngine/Trainer through forward() into the attention/FF/SSD call
# sites, so a resolved plan actually changes the compiled kernels.
Tiles = Optional[Mapping[str, TileShape]]


def is_encdec(cfg: ArchConfig) -> bool:
    return cfg.encoder is not None and cfg.encoder.kind == "audio"


def is_vlm(cfg: ArchConfig) -> bool:
    return cfg.encoder is not None and cfg.encoder.kind == "vision"


def init_params(cfg: ArchConfig, key: jax.Array, dtype=jnp.float32):
    if is_encdec(cfg):
        return E.init_params(cfg, key, dtype)
    return T.init_params(cfg, key, dtype)


def param_logical_axes(cfg: ArchConfig):
    if is_encdec(cfg):
        return E.param_logical_axes(cfg)
    return T.param_logical_axes(cfg)


def train_loss(
    params, cfg: ArchConfig, batch: Dict[str, Any],
    ctx: Optional[DistContext] = None, remat: bool = True,
    tiles: Tiles = None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Scalar loss + metrics. Differentiable."""
    targets = batch["targets"]
    if is_encdec(cfg):
        enc = E.encode(params, cfg, batch["frames"], ctx)
        hidden = E.decode_train(params, cfg, batch["tokens"], enc, ctx,
                                return_hidden=True)
        head = params["embed"].T
        aux = jnp.zeros((), jnp.float32)
    else:
        out = T.forward(
            params, cfg, batch["tokens"], ctx=ctx,
            patch_embeds=batch.get("patch_embeds"), remat=remat,
            logits_mode="hidden", tiles=tiles,
        )
        hidden, aux = out.hidden, out.aux_loss
        if is_vlm(cfg):
            # Loss only on text positions (after the patch prefix).
            p = batch["patch_embeds"].shape[1]
            hidden = hidden[:, p:]
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
    ce = T.fused_lm_loss(head, hidden, targets, cfg)
    loss = ce + aux
    return loss, {"loss": loss, "ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def make_serve_state(
    cfg: ArchConfig, batch: int, max_len: int, dtype,
    enc_out: Optional[jnp.ndarray] = None,
    params=None, ring_local: bool = False,
):
    if is_encdec(cfg):
        assert enc_out is not None and params is not None
        return E.make_decode_caches(params, cfg, enc_out, batch, max_len, dtype)
    return T.make_caches(cfg, batch, max_len, dtype, ring_local=ring_local)


def make_paged_pool(cfg: ArchConfig, n_pages: int, page: int, dtype):
    """Engine-wide paged KV pool arrays (see ``serve.pool.PagedKVPool``,
    which owns the matching host-side page bookkeeping)."""
    if is_encdec(cfg):
        raise NotImplementedError(
            "paged KV pool is not supported for encoder-decoder models")
    return T.make_paged_pool(cfg, n_pages, page, dtype)


def make_paged_state(cfg: ArchConfig, dtype):
    """Per-request serve state for a pool-backed request: attention layers
    carry only their scalar write position (K/V live in the shared pool);
    recurrent/SSD layers keep their usual batch-1 carried state."""
    if is_encdec(cfg):
        raise NotImplementedError(
            "paged KV pool is not supported for encoder-decoder models")
    return T.make_caches(cfg, 1, 1, dtype, paged=True)


def prefill(
    params, cfg: ArchConfig, batch: Dict[str, Any], max_len: int,
    dtype=jnp.float32, ctx: Optional[DistContext] = None,
    ring_local: bool = False, tiles: Tiles = None,
):
    """Returns (last-token logits [B, Vpad], serve_state)."""
    if is_encdec(cfg):
        enc = E.encode(params, cfg, batch["frames"], ctx)
        logits, caches = E.prefill(
            params, cfg, batch["tokens"], enc, max_len, dtype, ctx)
        return logits[:, -1], caches
    caches = T.make_caches(
        cfg, batch["tokens"].shape[0], max_len, dtype, ring_local=ring_local)
    out = T.forward(
        params, cfg, batch["tokens"], ctx=ctx, caches=caches,
        patch_embeds=batch.get("patch_embeds"), remat=False, tiles=tiles,
    )
    return out.logits[:, -1], out.caches


def prefill_chunk(
    params, cfg: ArchConfig, tokens: jnp.ndarray, state, start: int,
    ctx: Optional[DistContext] = None, tiles: Tiles = None,
):
    """One chunk of a multi-step (chunked) prefill.

    ``tokens`` [B, c] sit at absolute positions ``start .. start+c-1``
    (``start`` must be a static int — the engine compiles one program per
    (chunk length, start) pair). ``state`` is the serve state from
    :func:`make_serve_state` (chunk 0) or the previous chunk. Attention
    layers attend over the KV the earlier chunks wrote plus the chunk
    itself; recurrent/SSD layers continue from their carried state. Running
    every chunk through this entry on a fresh state reproduces
    :func:`prefill` position by position.

    Returns (last-position logits [B, Vpad], new state) — the logits are
    the request's first sampled token only when this was the final chunk.
    """
    if is_encdec(cfg):
        raise NotImplementedError(
            "chunked prefill is not supported for encoder-decoder models")
    out = T.forward(
        params, cfg, tokens, ctx=ctx, caches=state, start_pos=start,
        chunked=True, decode=False, remat=False, logits_mode="last",
        tiles=tiles,
    )
    return out.logits[:, -1], out.caches


def prefill_packed(
    params, cfg: ArchConfig, tokens: jnp.ndarray, states, layout,
    ctx: Optional[DistContext] = None, tiles: Tiles = None,
):
    """One packed step of N independent requests' chunked prefills.

    ``tokens`` [1, S_packed] segment-concatenates one chunk per request;
    ``layout`` is the static tuple of per-segment ``(start, len)`` pairs
    (each request's continuation offset and chunk length) and ``states``
    the matching tuple of per-request serve states. Embedding/norm/FF work
    runs once over the pack, attention runs one segment-masked launch per
    layer, and each request's state advances exactly as if its chunk had
    gone through :func:`prefill_chunk` alone — step packing changes the
    schedule, not the math (tests/test_serve_packing.py pins parity).

    Returns ``(per-segment last-position logits [N, Vpad], new states)``.
    """
    if is_encdec(cfg):
        raise NotImplementedError(
            "packed prefill is not supported for encoder-decoder models")
    return T.forward_packed(params, cfg, tokens, states, layout, ctx=ctx,
                            tiles=tiles)


def decode_step(
    params, cfg: ArchConfig, token: jnp.ndarray, state,
    ctx: Optional[DistContext] = None, tiles: Tiles = None,
):
    """token [B,1] -> (logits [B, Vpad], new state)."""
    if is_encdec(cfg):
        logits, new = E.decode_step(params, cfg, token, state, ctx)
        return logits[:, 0], new
    out = T.forward(params, cfg, token, ctx=ctx, caches=state, decode=True,
                    remat=False, tiles=tiles)
    return out.logits[:, 0], out.caches


# -- pool-backed (paged KV) entry points ------------------------------------
# Each mirrors its per-request-cache counterpart with two extra inputs (the
# shared pool arrays + the request's page table) and one extra output (the
# updated pool). Separate entry points keep the existing signatures — and
# every compiled program built on them — untouched.

def decode_step_paged(
    params, cfg: ArchConfig, token: jnp.ndarray, state, pool, page_table,
    ctx: Optional[DistContext] = None, tiles: Tiles = None,
):
    """token [1,1] -> (logits [1, Vpad], new state, new pool)."""
    if is_encdec(cfg):
        raise NotImplementedError(
            "paged decode is not supported for encoder-decoder models")
    out = T.forward(params, cfg, token, ctx=ctx, caches=state, decode=True,
                    remat=False, tiles=tiles, pool=pool,
                    page_table=page_table)
    return out.logits[:, 0], out.caches, out.pool


def prefill_chunk_paged(
    params, cfg: ArchConfig, tokens: jnp.ndarray, state, start: int,
    pool, page_table,
    ctx: Optional[DistContext] = None, tiles: Tiles = None,
):
    """:func:`prefill_chunk` over the paged pool. A pool-backed request
    with a shared-prefix hit starts its first chunk at ``start = hit`` —
    the mapped pages stand in for the chunks it never ran."""
    if is_encdec(cfg):
        raise NotImplementedError(
            "chunked prefill is not supported for encoder-decoder models")
    out = T.forward(
        params, cfg, tokens, ctx=ctx, caches=state, start_pos=start,
        chunked=True, decode=False, remat=False, logits_mode="last",
        tiles=tiles, pool=pool, page_table=page_table,
    )
    return out.logits[:, -1], out.caches, out.pool


def prefill_packed_paged(
    params, cfg: ArchConfig, tokens: jnp.ndarray, states, layout,
    pool, page_tables,
    ctx: Optional[DistContext] = None, tiles: Tiles = None,
):
    """:func:`prefill_packed` over the paged pool (one page table per
    segment). Returns ``(logits [N, Vpad], new states, new pool)``."""
    if is_encdec(cfg):
        raise NotImplementedError(
            "packed prefill is not supported for encoder-decoder models")
    return T.forward_packed(params, cfg, tokens, states, layout, ctx=ctx,
                            tiles=tiles, pool=pool, page_tables=page_tables)
