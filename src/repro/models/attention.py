"""Attention block: GQA with RoPE, optional SWA window / softcap / QKV bias /
q-k norms, head padding for TP, and KV caches (full and ring-buffer).

The full-sequence path lowers through the chunked flash reference (same math
as the Pallas kernel; see kernels/flash_attention). Decode dispatches on the
plan-resolved decode tile: with a tile it lowers through the split-KV
flash-decode kernel (Pallas on TPU, the chunked online-softmax reference
elsewhere — the tile's ``bkv`` is the KV split on both); without one it
attends densely over the cache (the pre-plan behavior). On real TPU
deployments the prefill path swaps in the Pallas kernel via
``impl="pallas"``.

Tile-dispatch observability: every call that received a plan tile emits a
trace-time event through :func:`capture_tile_events` saying whether the tile
legally applied or the lowering silently degraded (clamped to a
non-dividing block -> reference fallback / adjusted chunk). The serve
engine records these as ``tile_fallback`` plan-counter entries so
``plan_hit_rate`` reflects decode/prefill tile misses, not just plan-store
lookups.
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.tiling import cdiv
from repro.models import flags
from repro.kernels.flash_attention.chunked import (
    flash_prefill_chunk_paged_ref, flash_prefill_chunk_ref,
    flash_prefill_packed_ref, paged_prefix,
)
from repro.kernels.flash_attention.decode import (
    fit_bkv, flash_decode, flash_decode_ref, paged_gather, paged_write,
)
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.models.layers import ParamDef, apply_rope, rms_norm

NEG_INF = -2.0e30

# ---------------------------------------------------------------------------
# Tile-dispatch events. Emitted at TRACE time (tile legality is a static
# shape decision), so a sink sees one event per compiled program per
# attention call site — cheap, and exactly when a plan tile goes unused.
# ---------------------------------------------------------------------------

_tile_event_sink: Optional[Callable[[Dict[str, Any]], None]] = None


@contextlib.contextmanager
def capture_tile_events(sink: Callable[[Dict[str, Any]], None]):
    """Route tile-dispatch events emitted under this context to ``sink``.

    Events are dicts: ``kernel`` (flash_attention | flash_decode), ``phase``
    (prefill | decode), ``impl`` (the lowering actually used), ``tile`` (the
    requested dims), ``effective`` (the parameter the lowering really used)
    and ``fallback`` (True when the plan's tile did not legally apply).
    """
    global _tile_event_sink
    prev = _tile_event_sink
    _tile_event_sink = sink
    try:
        yield
    finally:
        _tile_event_sink = prev


def _emit_tile_event(**event) -> None:
    if _tile_event_sink is not None:
        _tile_event_sink(dict(event))


def attn_defs(cfg: ArchConfig) -> Dict[str, ParamDef]:
    d, hd = cfg.d_model, cfg.head_dim_
    h, hkv = cfg.padded_heads, cfg.padded_kv_heads
    defs = {
        "wq": ParamDef((d, h, hd), ("d_model", "heads", None)),
        "wk": ParamDef((d, hkv, hd), ("d_model", "kv_heads", None)),
        "wv": ParamDef((d, hkv, hd), ("d_model", "kv_heads", None)),
        "wo": ParamDef((h, hd, d), ("heads", None, "d_model"), scale=1.0),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((h, hd), ("heads", None), init="zeros")
        defs["bk"] = ParamDef((hkv, hd), ("kv_heads", None), init="zeros")
        defs["bv"] = ParamDef((hkv, hd), ("kv_heads", None), init="zeros")
    if cfg.use_qk_norm:
        defs["q_norm"] = ParamDef((hd,), (None,), init="zeros")
        defs["k_norm"] = ParamDef((hd,), (None,), init="zeros")
    return defs


def make_kv_cache(cfg: ArchConfig, batch: int, max_len: int, dtype,
                  ring: bool = False) -> Dict[str, Any]:
    hkv, hd = cfg.padded_kv_heads, cfg.head_dim_
    cache = {
        "k": jnp.zeros((batch, hkv, max_len, hd), dtype),
        "v": jnp.zeros((batch, hkv, max_len, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
    if ring:
        cache["slot_pos"] = jnp.full((max_len,), -1, jnp.int32)
    return cache


def make_paged_kv_pages(cfg: ArchConfig, n_pages: int, page: int,
                        dtype) -> Dict[str, Any]:
    """One attention layer's slice of the shared paged KV pool: physical
    page arrays ``[n_pages, Hkv, page, hd]``. Requests index into them
    through their page tables (serve/pool.py); the per-request serve state
    keeps only the scalar write position (see ``transformer.make_caches``
    with ``paged=True``)."""
    hkv, hd = cfg.padded_kv_heads, cfg.head_dim_
    return {
        "k_pages": jnp.zeros((n_pages, hkv, page, hd), dtype),
        "v_pages": jnp.zeros((n_pages, hkv, page, hd), dtype),
    }


def _ring_write(cache, k, v, positions_1d, end_pos):
    """Write a chunk's K/V tail into a ring cache: the last
    ``min(chunk, W)`` positions land at ``pos % W`` with their absolute
    positions recorded in ``slot_pos``. ONE implementation shared by the
    full-sequence, chunked, and packed prefill paths — ring wraparound
    drift between them would break the chunk/pack parity suites."""
    max_len = cache["k"].shape[2]
    keep = min(k.shape[2], max_len)
    kk = k[:, :, -keep:]
    vv = v[:, :, -keep:]
    pos_tail = positions_1d[-keep:]
    slots = pos_tail % max_len
    ck = cache["k"].at[:, :, slots].set(kk.astype(cache["k"].dtype))
    cv = cache["v"].at[:, :, slots].set(vv.astype(cache["v"].dtype))
    sp = cache["slot_pos"].at[slots].set(pos_tail)
    return {"k": ck, "v": cv, "pos": jnp.asarray(end_pos, jnp.int32),
            "slot_pos": sp}


def _linear_write(cache, k, v, start, end_pos):
    """Write a chunk's K/V into a linear cache at its static offset
    (shared by the same three prefill paths as :func:`_ring_write`)."""
    ck = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, 0, start, 0))
    cv = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, 0, start, 0))
    return {"k": ck, "v": cv, "pos": jnp.asarray(end_pos, jnp.int32)}


def _project_qkv(p, cfg: ArchConfig, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.use_qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # [B, H, S, hd]
    return (t.transpose(0, 2, 1, 3) for t in (q, k, v))


def _out_proj(p, cfg: ArchConfig, attn_out, x_dtype):
    # Mask padded heads so they are numerically inert (grads included).
    h = cfg.padded_heads
    if h != cfg.n_heads:
        mask = (jnp.arange(h) < cfg.n_heads).astype(attn_out.dtype)
        attn_out = attn_out * mask[None, :, None, None]
    return jnp.einsum(
        "bhsk,hkd->bsd", attn_out, p["wo"].astype(x_dtype)
    )


def attn_forward(
    p, cfg: ArchConfig, x, positions, *,
    window: Optional[int] = None,
    cache: Optional[Dict[str, Any]] = None,
    impl: str = "auto",
    tile=None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, Any]]]:
    """Full-sequence attention (train/prefill). Fills ``cache`` if given.

    ``tile`` is the plan-resolved (bq, bkv) flash-attention block shape
    (``TileShape`` or 2-tuple). On the Pallas path it is the kernel's block
    spec; on the reference path ``bkv`` sets the online-softmax KV chunk, so
    a resolved plan changes the lowered computation on every backend.
    ``impl``: "auto" picks the Pallas kernel on TPU backends when a resolved
    tile legally divides the sequence, and the chunked reference otherwise
    (Pallas TPU kernels cannot lower to host HLO; without a plan the
    lowering is unchanged).
    """
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    scale = cfg.query_scale or cfg.head_dim_ ** -0.5
    kwargs = dict(
        causal=True, window=window,
        softcap=cfg.attn_softcap or None, scale=scale,
    )
    t = (min(tile[0], s), min(tile[1], s)) if tile is not None else None
    divides = t is not None and s % t[0] == 0 and s % t[1] == 0
    if impl == "auto":
        impl = "pallas" if (flags.pallas_enabled() and divides) \
            else "reference"
    if impl == "pallas":
        out = flash_attention(q, k, v, tile=t or (512, 512),
                              interpret=flags.pallas_interpret(), **kwargs)
        if tile is not None:
            _emit_tile_event(kernel="flash_attention", phase="prefill",
                             impl="pallas", tile=tuple(tile),
                             effective=t, fallback=False)
    else:
        if tile is not None:
            chunk = min(int(tile[1]), s)
            # The clamp can land on a non-dividing chunk; the reference
            # then snaps to the largest divisor, silently abandoning the
            # plan's bkv. Count it (and the Pallas-eligible-but-illegal
            # case) instead of hiding it.
            effective = fit_bkv(chunk, s)
            fallback = (effective != chunk
                        or (flags.pallas_enabled() and not divides))
            _emit_tile_event(kernel="flash_attention", phase="prefill",
                             impl="reference", tile=tuple(tile),
                             effective=effective, fallback=fallback)
        else:
            chunk = 2048 if flags.ANALYSIS_UNROLL else 512
        out = flash_attention_ref(q, k, v, chunk=min(chunk, s), **kwargs)
    y = _out_proj(p, cfg, out, x.dtype)
    new_cache = None
    if cache is not None:
        if "slot_pos" in cache:
            # Ring prefill: keep the last ``max_len`` positions.
            new_cache = _ring_write(cache, k, v, positions[0], s)
        else:
            new_cache = _linear_write(cache, k, v, 0, s)
    return y, new_cache


def attn_prefill_chunk(
    p, cfg: ArchConfig, x, positions, *,
    cache: Dict[str, Any],
    start: int,
    window: Optional[int] = None,
    impl: str = "auto",
    tile=None,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Continuation prefill of one prompt chunk over the live KV cache.

    ``x`` [B, c, D] holds the chunk's tokens at absolute positions
    ``start .. start+c-1`` (``positions`` carries them; ``start`` must be a
    static int — each (chunk length, start) pair is its own compiled
    program, which keeps the causal ``q_offset`` arithmetic and the cache
    prefix slice static). The chunk attends causally over the KV written by
    chunks ``0..N-1`` plus itself — the whole-prompt ``attn_forward``
    computation restricted to these query rows — and writes its K/V into
    the cache at the continuation offset.

    ``tile`` is the plan-resolved ``chunked_prefill`` tile ``(chunk, bkv)``.
    On TPU backends with a linear cache the Pallas ``flash_attention``
    kernel runs with the existing ``q_offset`` continuation math when the
    clamped tile legally divides ``(c, start+c)``; otherwise the chunked
    online-softmax reference runs with ``bkv`` as its KV split. Ring-buffer
    caches (sliding-window layers) always lower through
    :func:`~repro.kernels.flash_attention.chunked.flash_prefill_chunk_ref`,
    whose traced ``kv_pos`` map expresses slot wraparound that a static
    ``q_offset`` cannot.
    """
    b, c, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    scale = cfg.query_scale or cfg.head_dim_ ** -0.5
    softcap = cfg.attn_softcap or None

    if "k_pages" in cache:
        # Pool-backed cache: the chunk attends over the ``cdiv(start,
        # page)`` prefix pages its table maps (gathered to a positioned
        # linear view; positions >= start masked — unwritten page tails and
        # a shared-prefix donor's divergent tokens alike) plus itself, then
        # writes its K/V through the table at the static start offset. The
        # engine resolves copy-on-write BEFORE this runs (pool.prepare_span)
        # so the written span's pages are exclusively owned.
        page = cache["k_pages"].shape[2]
        n_pp = cdiv(start, page)
        skv = n_pp * page + c
        if tile is not None:
            requested = min(int(tile[-1]), skv)
            effective = fit_bkv(requested, skv)
            _emit_tile_event(kernel="chunked_prefill", phase="prefill",
                             impl="reference", tile=tuple(tile),
                             effective=effective,
                             fallback=effective != requested)
            bkv = requested
        else:
            bkv = 512
        out = flash_prefill_chunk_paged_ref(
            q, k, v, cache["k_pages"], cache["v_pages"], cache["table"],
            q_pos=positions[0], start=start, n_prefix_pages=n_pp,
            window=window, softcap=softcap, scale=scale, bkv=bkv)
        kp = paged_write(cache["k_pages"], cache["table"], k, start)
        vp = paged_write(cache["v_pages"], cache["table"], v, start)
        y = _out_proj(p, cfg, out, x.dtype)
        return y, {"k_pages": kp, "v_pages": vp, "table": cache["table"],
                   "pos": jnp.asarray(start + c, jnp.int32)}

    if "slot_pos" in cache:
        # Ring cache: visible keys = the ring's survivors (window-bounded
        # history) ++ the chunk itself, each with its absolute position.
        max_len = cache["k"].shape[2]
        k_all = jnp.concatenate([cache["k"].astype(k.dtype), k], axis=2)
        v_all = jnp.concatenate([cache["v"].astype(v.dtype), v], axis=2)
        kv_pos = jnp.concatenate(
            [cache["slot_pos"], positions[0].astype(jnp.int32)])
        skv = max_len + c
        if tile is not None:
            requested = min(int(tile[-1]), skv)
            effective = fit_bkv(requested, skv)
            _emit_tile_event(kernel="chunked_prefill", phase="prefill",
                             impl="reference", tile=tuple(tile),
                             effective=effective,
                             fallback=effective != requested)
            bkv = requested
        else:
            bkv = 512
        out = flash_prefill_chunk_ref(
            q, k_all, v_all, q_pos=positions[0], kv_pos=kv_pos,
            window=window, softcap=softcap, scale=scale, bkv=bkv)
        # Write the chunk's tail into the ring (mirrors attn_forward).
        new_cache = _ring_write(cache, k, v, positions[0], start + c)
    else:
        # Linear cache: the written prefix is exactly positions 0..start-1,
        # so the existing q_offset continuation math applies directly.
        skv = start + c
        if start:
            k_all = jnp.concatenate(
                [cache["k"][:, :, :start].astype(k.dtype), k], axis=2)
            v_all = jnp.concatenate(
                [cache["v"][:, :, :start].astype(v.dtype), v], axis=2)
        else:
            k_all, v_all = k, v
        t = (min(int(tile[0]), c), min(int(tile[1]), skv)) \
            if tile is not None else None
        divides = t is not None and c % t[0] == 0 and skv % t[1] == 0
        if impl == "auto":
            impl = "pallas" if (flags.pallas_enabled() and divides) \
                else "reference"
        kwargs = dict(causal=True, window=window, softcap=softcap,
                      scale=scale, q_offset=start)
        if impl == "pallas":
            out = flash_attention(q, k_all, v_all, tile=t or (512, 512),
                                  interpret=flags.pallas_interpret(),
                                  **kwargs)
            if tile is not None:
                _emit_tile_event(kernel="chunked_prefill", phase="prefill",
                                 impl="pallas", tile=tuple(tile),
                                 effective=t, fallback=False)
        else:
            if tile is not None:
                requested = min(int(tile[1]), skv)
                effective = fit_bkv(requested, skv)
                _emit_tile_event(
                    kernel="chunked_prefill", phase="prefill",
                    impl="reference", tile=tuple(tile), effective=effective,
                    fallback=(effective != requested
                              or (flags.pallas_enabled() and not divides)))
                chunk_kv = requested
            else:
                chunk_kv = 512
            out = flash_attention_ref(q, k_all, v_all,
                                      chunk=min(chunk_kv, skv), **kwargs)
        new_cache = _linear_write(cache, k, v, start, start + c)
    y = _out_proj(p, cfg, out, x.dtype)
    return y, new_cache


def attn_prefill_packed(
    p, cfg: ArchConfig, x, positions, *,
    caches,
    layout,
    window: Optional[int] = None,
    tile=None,
):
    """Packed continuation prefill: N requests' chunks, one attention call.

    ``x`` [1, S_packed, D] segment-concatenates the chunks of N independent
    requests; ``layout`` is the static tuple of per-segment ``(start, len)``
    pairs (sum of lens = S_packed) and ``positions`` [1, S_packed] carries
    each token's absolute position within its own request. ``caches`` is
    the matching tuple of per-request layer caches (each batch=1). Every
    segment attends causally over ITS OWN cache prefix plus its own chunk —
    never another segment's keys: the packed lowering concatenates each
    segment's visible KV with per-key segment tags and masks on segment
    equality (:func:`flash_prefill_packed_ref`), so the math per request is
    exactly :func:`attn_prefill_chunk` while the projections, the softmax
    scan, and the surrounding FF GEMMs run once over the whole pack — the
    occupancy win step packing exists for.

    ``tile`` is the plan-resolved ``packed_prefill`` tile ``(pack, bkv)``;
    ``bkv`` sets the packed KV stream split (the pack width itself is the
    scheduler's knob — by the time this runs, the pack is already built).
    Linear caches write each segment at its static start offset; ring
    caches take the chunked ring-write path per segment. Returns
    ``(y [1, S_packed, D], tuple of per-request new caches)``.
    """
    b, s_packed, _ = x.shape
    assert b == 1, "packed prefill packs segments, not batch rows"
    assert len(caches) == len(layout) and layout, (len(caches), len(layout))
    q, k, v = _project_qkv(p, cfg, x, positions)
    scale = cfg.query_scale or cfg.head_dim_ ** -0.5
    softcap = cfg.attn_softcap or None
    paged = "k_pages" in caches[0]
    ring = not paged and "slot_pos" in caches[0]
    if paged:
        # Pool-backed pack: by convention segment 0's cache carries the
        # SHARED page arrays (transformer.forward_packed merges them there);
        # every segment carries its own page table. Prefix reads all see
        # the pre-step pages (requests only share read-only prefix pages —
        # the engine's copy-on-write pass guarantees written spans are
        # exclusive), then the per-segment writes accumulate functionally.
        k_pool, v_pool = caches[0]["k_pages"], caches[0]["v_pages"]
        page = k_pool.shape[2]

    offs = [0]
    for _, ln in layout:
        offs.append(offs[-1] + ln)
    assert offs[-1] == s_packed, (offs, s_packed)

    k_parts, v_parts, kvp_parts, kvs_parts = [], [], [], []
    for i, ((start, ln), cache) in enumerate(zip(layout, caches)):
        k_seg = k[:, :, offs[i]:offs[i] + ln]
        v_seg = v[:, :, offs[i]:offs[i] + ln]
        seg_pos = positions[0, offs[i]:offs[i] + ln].astype(jnp.int32)
        if paged:
            # Paged prefix: the segment's mapped pages up to its start
            # (static count), position-masked like the ring's slot_pos map.
            n_pp = cdiv(start, page)
            if n_pp:
                kp_, vp_, pp_ = paged_prefix(
                    k_pool, v_pool, cache["table"], n_pp, start)
                k_parts += [kp_.astype(k.dtype), k_seg]
                v_parts += [vp_.astype(v.dtype), v_seg]
                kvp_parts += [pp_, seg_pos]
            else:
                k_parts += [k_seg]
                v_parts += [v_seg]
                kvp_parts += [seg_pos]
            prefix_len = n_pp * page
        elif ring:
            # Ring prefix: the whole window buffer, slot_pos mapping each
            # slot to its absolute position (-1 = never written).
            k_parts += [cache["k"].astype(k.dtype), k_seg]
            v_parts += [cache["v"].astype(v.dtype), v_seg]
            kvp_parts += [cache["slot_pos"], seg_pos]
            prefix_len = cache["k"].shape[2]
        else:
            # Linear prefix: exactly the positions 0..start-1 written by the
            # segment's earlier chunks (static slice — layout is static).
            k_parts += [cache["k"][:, :, :start].astype(k.dtype), k_seg]
            v_parts += [cache["v"][:, :, :start].astype(v.dtype), v_seg]
            kvp_parts += [jnp.arange(start, dtype=jnp.int32), seg_pos]
            prefix_len = start
        kvs_parts.append(jnp.full((prefix_len + ln,), i, jnp.int32))
    k_all = jnp.concatenate(k_parts, axis=2)
    v_all = jnp.concatenate(v_parts, axis=2)
    kv_pos = jnp.concatenate(kvp_parts)
    kv_seg = jnp.concatenate(kvs_parts)
    q_seg = jnp.concatenate([
        jnp.full((ln,), i, jnp.int32) for i, (_, ln) in enumerate(layout)
    ])

    skv = k_all.shape[2]
    if tile is not None:
        requested = min(int(tile[-1]), skv)
        effective = fit_bkv(requested, skv)
        _emit_tile_event(kernel="packed_prefill", phase="prefill",
                         impl="reference", tile=tuple(tile),
                         effective=effective,
                         fallback=effective != requested)
        bkv = requested
    else:
        bkv = 512
    out = flash_prefill_packed_ref(
        q, k_all, v_all, q_pos=positions[0], q_seg=q_seg,
        kv_pos=kv_pos, kv_seg=kv_seg, window=window, softcap=softcap,
        scale=scale, bkv=bkv)

    new_caches = []
    for i, ((start, ln), cache) in enumerate(zip(layout, caches)):
        k_seg = k[:, :, offs[i]:offs[i] + ln]
        v_seg = v[:, :, offs[i]:offs[i] + ln]
        seg_pos = positions[0, offs[i]:offs[i] + ln]
        if paged:
            k_pool = paged_write(k_pool, cache["table"], k_seg, start)
            v_pool = paged_write(v_pool, cache["table"], v_seg, start)
            new_caches.append({"table": cache["table"],
                               "pos": jnp.asarray(start + ln, jnp.int32)})
        elif ring:
            new_caches.append(
                _ring_write(cache, k_seg, v_seg, seg_pos, start + ln))
        else:
            new_caches.append(
                _linear_write(cache, k_seg, v_seg, start, start + ln))
    if paged:
        # Segment 0 returns the (single) updated pool alongside its state.
        new_caches[0] = {**new_caches[0], "k_pages": k_pool,
                        "v_pages": v_pool}
    y = _out_proj(p, cfg, out, x.dtype)
    return y, tuple(new_caches)


def _decode_attn_sharded(cfg: ArchConfig, ctx, qd, k_new, v_new, cache,
                         window: Optional[int], scale: float):
    """Flash-decoding: LSE-combined attention over the seq-sharded KV cache.

    Each model shard attends over its local sequence chunk with the GQA
    grouped contraction (no kv repeat!), then partial softmax statistics
    combine with pmax/psum of [B, H]-sized tensors — collective bytes drop
    from cache-sized copies to KBs. The single-position cache update runs
    inside the shard_map on the owner shard only.
    """
    from jax import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = ctx.mesh
    ax = ctx.model_axis
    n_shards = mesh.shape[ax]
    b, hq, hd = qd.shape
    hkv, s_total = cache["k"].shape[1], cache["k"].shape[2]
    s_loc = s_total // n_shards
    n_rep = hq // hkv
    pos = cache["pos"]

    def batch_entry(n):
        use, rem = [], n
        for a in ctx.batch_axes:
            if rem % mesh.shape[a] == 0 and rem >= mesh.shape[a]:
                use.append(a)
                rem //= mesh.shape[a]
        return tuple(use) if len(use) > 1 else (use[0] if use else None)

    bent = batch_entry(b)
    q_spec = P(bent, None, None)
    new_spec = P(bent, None, None, None)
    cache_spec = P(bent, None, ax, None)

    def body(q_loc, kn, vn, k_loc, v_loc, pos_):
        i = jax.lax.axis_index(ax)
        # Owner shard writes the new K/V at the local offset.
        owner = pos_ // s_loc
        local = pos_ % s_loc
        k_upd = jax.lax.dynamic_update_slice(
            k_loc, kn.astype(k_loc.dtype), (0, 0, local, 0))
        v_upd = jax.lax.dynamic_update_slice(
            v_loc, vn.astype(v_loc.dtype), (0, 0, local, 0))
        k_loc = jnp.where(i == owner, k_upd, k_loc)
        v_loc = jnp.where(i == owner, v_upd, v_loc)

        k_pos = i * s_loc + jnp.arange(s_loc)
        valid = k_pos <= pos_
        if window is not None:
            valid &= k_pos > pos_ - window
        bl = q_loc.shape[0]
        qg = q_loc.reshape(bl, hkv, n_rep, hd).astype(k_loc.dtype)
        s = jnp.einsum(
            "bgrk,bgsk->bgrs", qg, k_loc,
            preferred_element_type=jnp.float32,
        ) * scale
        if cfg.attn_softcap:
            s = cfg.attn_softcap * jnp.tanh(s / cfg.attn_softcap)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m = jnp.max(s, axis=-1)
        m_g = jax.lax.pmax(m, ax)                        # [B,Hkv,rep]
        prob = jnp.exp(s - m_g[..., None])
        l_g = jax.lax.psum(jnp.sum(prob, axis=-1), ax)
        pv = jnp.einsum(
            "bgrs,bgsk->bgrk", prob.astype(v_loc.dtype), v_loc,
            preferred_element_type=jnp.float32,
        )
        pv_g = jax.lax.psum(pv, ax)
        out = pv_g / jnp.maximum(l_g, 1e-30)[..., None]
        return out.reshape(bl, hq, hd), k_loc, v_loc

    out, ck, cv = shard_map(
        body, mesh=mesh,
        in_specs=(q_spec, new_spec, new_spec, cache_spec, cache_spec, P()),
        out_specs=(q_spec, cache_spec, cache_spec),
        check_vma=False,
    )(qd, k_new, v_new, cache["k"], cache["v"], pos)
    out = out.astype(qd.dtype)
    return out[:, :, None], {"k": ck, "v": cv, "pos": pos + 1}


def attn_decode(
    p, cfg: ArchConfig, x, *, cache: Dict[str, Any],
    window: Optional[int] = None, ctx=None,
    tile=None, impl: str = "auto",
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Single-token decode: x [B, 1, D]; attend over the cache.

    ``tile`` is the plan-resolved decode tile (``TileShape`` or tuple whose
    last dim is ``bkv``, the split-KV chunk). ``impl``: "auto" picks the
    Pallas flash-decode kernel on TPU backends when the tile legally divides
    the cache length, the chunked flash-decode reference when a tile is
    present elsewhere (``bkv`` sets the online-softmax KV split — a resolved
    plan changes the lowered computation on every backend), and the dense
    masked attend when no tile resolved (the pre-plan lowering). "dense" /
    "flash_ref" / "pallas" force a path. The sequence-sharded flash-decoding
    path (``flags.DECODE_ATTN_SHARDED``) keeps its own tiling — the split is
    the mesh axis — and ignores ``tile``.
    """
    b = x.shape[0]
    pos = cache["pos"]                                   # scalar int32
    positions = jnp.broadcast_to(pos[None, None], (b, 1))
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)  # [B, H(kv), 1, hd]
    scale = cfg.query_scale or cfg.head_dim_ ** -0.5

    paged = "k_pages" in cache
    max_len = (cache["table"].shape[0] * cache["k_pages"].shape[2]
               if paged else cache["k"].shape[2])
    if (flags.DECODE_ATTN_SHARDED and ctx is not None and ctx.mesh is not None
            and not paged and "slot_pos" not in cache
            and cfg.padded_kv_heads < ctx.mesh.shape[ctx.model_axis]
            and max_len % ctx.mesh.shape[ctx.model_axis] == 0):
        out, new_cache = _decode_attn_sharded(
            cfg, ctx, q[:, :, 0], k_new, v_new, cache, window, scale)
        y = _out_proj(p, cfg, out, x.dtype)
        return y, new_cache
    if paged:
        # Pool-backed cache (batch 1): scatter the new K/V into the page
        # the table maps position ``pos`` to, then attend over the table's
        # gathered linear view — the dispatch below (dense / flash_ref /
        # pallas) is the same as for a resident linear cache, so the paged
        # lowering changes where bytes live, not the math. Unwritten tail
        # slots of the view hold stale pages' data; ``k_pos <= pos`` masks
        # them exactly as it masks a linear cache's unwritten tail.
        kp = paged_write(cache["k_pages"], cache["table"], k_new, pos)
        vp = paged_write(cache["v_pages"], cache["table"], v_new, pos)
        ck = paged_gather(kp, cache["table"])
        cv = paged_gather(vp, cache["table"])
        slot_pos = None
        k_pos = jnp.arange(max_len)
        valid = k_pos <= pos
    elif "slot_pos" in cache:
        slot = pos % max_len
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, 0, slot, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, 0, slot, 0))
        slot_pos = cache["slot_pos"].at[slot].set(pos)
        k_pos = slot_pos                                  # [W] absolute
        valid = k_pos >= 0
    else:
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, 0, pos, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, 0, pos, 0))
        slot_pos = None
        k_pos = jnp.arange(max_len)
        valid = k_pos <= pos

    bkv = int(tile[-1]) if tile is not None else None
    clamped = min(bkv, max_len) if bkv is not None else None
    divides = clamped is not None and max_len % clamped == 0
    auto = impl == "auto"
    if auto:
        if bkv is None:
            impl = "dense"
        elif flags.pallas_enabled() and divides:
            impl = "pallas"
        else:
            impl = "flash_ref"
    if tile is not None:
        effective = fit_bkv(clamped, max_len)
        if impl == "pallas":
            fallback = False
        elif impl == "dense":
            fallback = True                 # forced dense ignores the tile
        else:                               # flash_ref: ran, but at the
            fallback = effective != clamped  # snapped (not the plan's) split
        _emit_tile_event(
            kernel="flash_decode", phase="decode", impl=impl,
            tile=tuple(tile), effective=effective, fallback=fallback,
        )

    softcap = cfg.attn_softcap or None
    if impl in ("pallas", "flash_ref"):
        fn = flash_decode if impl == "pallas" else flash_decode_ref
        extra = ({"interpret": flags.pallas_interpret()}
                 if impl == "pallas" else {})
        out = fn(
            q[:, :, 0], ck, cv, pos=pos, kv_pos=slot_pos, window=window,
            softcap=softcap, scale=scale, bkv=clamped or 512, **extra,
        )[:, :, None]                                      # [B, Hq, 1, hd]
        out = out.astype(x.dtype)
    else:
        mask = valid & (k_pos <= pos)
        if window is not None:
            mask &= k_pos > pos - window

        hq, hkv = cfg.padded_heads, cfg.padded_kv_heads
        n_rep = hq // hkv
        # GQA via kv repeat (gather) — partitions cleanly under head
        # sharding. Keep K/V in cache dtype: upcasting a 32k-seq cache to
        # f32 would materialize gigabytes per layer; the MXU accumulates in
        # f32 anyway (preferred_element_type).
        ke = jnp.repeat(ck, n_rep, axis=1) if n_rep > 1 else ck
        ve = jnp.repeat(cv, n_rep, axis=1) if n_rep > 1 else cv
        qd = q[:, :, 0].astype(ke.dtype)                  # [B, Hq, hd]
        s = jnp.einsum(
            "bhk,bhsk->bhs", qd, ke, preferred_element_type=jnp.float32,
        ) * scale                                         # [B, Hq, S] f32
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(mask[None, None], s, NEG_INF)
        pattn = jax.nn.softmax(s, axis=-1).astype(ve.dtype)
        out = jnp.einsum(
            "bhs,bhsk->bhk", pattn, ve, preferred_element_type=jnp.float32,
        )[:, :, None].astype(x.dtype)                      # [B, Hq, 1, hd]
    y = _out_proj(p, cfg, out, x.dtype)
    if paged:
        new_cache = {"k_pages": kp, "v_pages": vp, "table": cache["table"],
                     "pos": pos + 1}
    else:
        new_cache = {"k": ck, "v": cv, "pos": pos + 1}
        if slot_pos is not None:
            new_cache["slot_pos"] = slot_pos
    return y, new_cache
