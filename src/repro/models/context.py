"""DistContext: how model code sees the mesh without naming mesh axes.

``None`` context = single-device (tests, smoke). With a context, model code
applies logical sharding constraints and MoE uses shard_map EP. The logical
-> mesh axis mapping lives in distributed/sharding_rules.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec


@dataclasses.dataclass(frozen=True)
class DistContext:
    mesh: Optional[jax.sharding.Mesh] = None
    batch_axes: Tuple[str, ...] = ("data",)   # axes sharding the batch dim
    model_axis: str = "model"                 # TP / EP axis
    # Logical axis name -> mesh axis (None = replicated).
    rules: Tuple[Tuple[str, Optional[object]], ...] = (
        ("batch", None),        # filled by with_batch_axes below
        ("seq", None),
        ("d_model", None),
        ("heads", "model"),
        ("kv_heads", "model"),
        ("ff", "model"),
        ("vocab", "model"),
        ("experts", "model"),
        ("lru", "model"),
        ("ssm_heads", "model"),
    )

    def spec_for(self, logical_axes: Tuple[Optional[str], ...]) -> PartitionSpec:
        table = dict(self.rules)
        out = []
        for ax in logical_axes:
            if ax == "batch":
                out.append(self.batch_axes if len(self.batch_axes) > 1
                           else self.batch_axes[0])
            elif ax is None:
                out.append(None)
            else:
                out.append(table.get(ax))
        return PartitionSpec(*out)

    def constrain(self, x, *logical_axes):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, self.spec_for(logical_axes))
        )


def null_context() -> DistContext:
    return DistContext(mesh=None)
