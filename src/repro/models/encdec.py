"""Encoder-decoder stack (whisper-large-v3 backbone).

The audio conv frontend is a STUB per the task spec: the encoder consumes
precomputed frame embeddings [B, S_enc, D] (what the two conv layers would
produce). Architecture follows whisper: pre-LN transformer, sinusoidal
positions, plain GELU MLP, MHA (no GQA), decoder with causal self-attention
+ cross-attention, tied decoder embedding head.

Param layout mirrors models/transformer.py (stacked layers, scanned), so
sharding rules apply uniformly.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.models import flags
from repro.models.context import DistContext
from repro.models.layers import ParamDef, axes_tree, init_tree, layer_norm

NEG_INF = -2.0e30


def _sinusoid(seq: int, d: int):
    pos = jnp.arange(seq)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / (10000.0 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _mha_defs(cfg: ArchConfig) -> Dict[str, ParamDef]:
    d, hd, h = cfg.d_model, cfg.head_dim_, cfg.padded_heads
    return {
        "wq": ParamDef((d, h, hd), ("d_model", "heads", None)),
        "wk": ParamDef((d, h, hd), ("d_model", "heads", None)),
        "wv": ParamDef((d, h, hd), ("d_model", "heads", None)),
        "wo": ParamDef((h, hd, d), ("heads", None, "d_model")),
    }


def _ln_defs(cfg: ArchConfig, name: str) -> Dict[str, ParamDef]:
    return {
        f"{name}_w": ParamDef((cfg.d_model,), (None,), init="ones"),
        f"{name}_b": ParamDef((cfg.d_model,), (None,), init="zeros"),
    }


def _ff_defs(cfg: ArchConfig) -> Dict[str, ParamDef]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w1": ParamDef((d, f), ("d_model", "ff")),
        "b1": ParamDef((f,), ("ff",), init="zeros"),
        "w2": ParamDef((f, d), ("ff", "d_model")),
        "b2": ParamDef((d,), (None,), init="zeros"),
    }


def _enc_layer_defs(cfg):
    return {**_ln_defs(cfg, "ln1"), "attn": _mha_defs(cfg),
            **_ln_defs(cfg, "ln2"), "ff": _ff_defs(cfg)}


def _dec_layer_defs(cfg):
    return {**_ln_defs(cfg, "ln1"), "self_attn": _mha_defs(cfg),
            **_ln_defs(cfg, "lnx"), "cross_attn": _mha_defs(cfg),
            **_ln_defs(cfg, "ln2"), "ff": _ff_defs(cfg)}


def _stack(defs, count):
    return jax.tree.map(
        lambda pd: ParamDef((count,) + pd.shape, (None,) + pd.axes,
                            init=pd.init, scale=pd.scale),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def model_defs(cfg: ArchConfig) -> Dict[str, Any]:
    enc_l = cfg.encoder.n_layers
    return {
        "embed": ParamDef((cfg.padded_vocab, cfg.d_model),
                          ("vocab", "d_model"), init="normal", scale=0.02),
        "enc_layers": _stack(_enc_layer_defs(cfg), enc_l),
        "dec_layers": _stack(_dec_layer_defs(cfg), cfg.n_layers),
        **_ln_defs(cfg, "enc_final"),
        **_ln_defs(cfg, "dec_final"),
    }


def init_params(cfg: ArchConfig, key, dtype=jnp.float32):
    return init_tree(model_defs(cfg), key, dtype)


def param_logical_axes(cfg: ArchConfig):
    return axes_tree(model_defs(cfg))


def _ln(p, name, x, eps):
    return layer_norm(x, p[f"{name}_w"], p[f"{name}_b"], eps)


def _heads(cfg, p, x, w):  # [B,S,D] x [D,H,hd] -> [B,H,S,hd]
    return jnp.einsum("bsd,dhk->bhsk", x, p[w].astype(x.dtype))


def _mha(p, cfg: ArchConfig, xq, xkv, causal: bool,
         cached_kv=None, q_offset: int = 0):
    """Returns (out [B,Sq,D], (k, v)). cached_kv short-circuits projection."""
    q = _heads(cfg, p, xq, "wq")
    if cached_kv is None:
        k = _heads(cfg, p, xkv, "wk")
        v = _heads(cfg, p, xkv, "wv")
    else:
        k, v = cached_kv
    out = flash_attention_ref(
        q, k, v, causal=causal, q_offset=q_offset,
        chunk=min(2048 if flags.ANALYSIS_UNROLL else 512, k.shape[2]),
    )
    h = cfg.padded_heads
    if h != cfg.n_heads:
        mask = (jnp.arange(h) < cfg.n_heads).astype(out.dtype)
        out = out * mask[None, :, None, None]
    return jnp.einsum("bhsk,hkd->bsd", out, p["wo"].astype(xq.dtype)), (k, v)


def _ff(p, x):
    h = jax.nn.gelu(x @ p["w1"].astype(x.dtype) + p["b1"].astype(x.dtype))
    return h @ p["w2"].astype(x.dtype) + p["b2"].astype(x.dtype)


def encode(params, cfg: ArchConfig, frames: jnp.ndarray,
           ctx: Optional[DistContext] = None) -> jnp.ndarray:
    """frames [B, S_enc, D] (precomputed conv-frontend embeddings)."""
    x = frames + _sinusoid(frames.shape[1], cfg.d_model)[None].astype(frames.dtype)

    def body(xc, lp):
        h, _ = _mha(lp["attn"], cfg, _ln(lp, "ln1", xc, cfg.norm_eps),
                    _ln(lp, "ln1", xc, cfg.norm_eps), causal=False)
        xc = xc + h
        xc = xc + _ff(lp["ff"], _ln(lp, "ln2", xc, cfg.norm_eps))
        if ctx is not None:
            xc = ctx.constrain(xc, "batch", None, None)
        return xc, None

    x, _ = jax.lax.scan(
        jax.checkpoint(body, policy=flags.remat_policy()),
        x, params["enc_layers"], unroll=flags.scan_unroll())
    return _ln(params, "enc_final", x, cfg.norm_eps)


def decode_train(params, cfg: ArchConfig, tokens: jnp.ndarray,
                 enc_out: jnp.ndarray,
                 ctx: Optional[DistContext] = None,
                 return_hidden: bool = False) -> jnp.ndarray:
    """Teacher-forced decoder pass -> logits [B, S, Vpad] (or hidden)."""
    b, s = tokens.shape
    x = params["embed"][tokens]
    x = x + _sinusoid(s, cfg.d_model)[None].astype(x.dtype)

    def body(xc, lp):
        h, _ = _mha(lp["self_attn"], cfg, _ln(lp, "ln1", xc, cfg.norm_eps),
                    _ln(lp, "ln1", xc, cfg.norm_eps), causal=True)
        xc = xc + h
        h, _ = _mha(lp["cross_attn"], cfg, _ln(lp, "lnx", xc, cfg.norm_eps),
                    enc_out, causal=False)
        xc = xc + h
        xc = xc + _ff(lp["ff"], _ln(lp, "ln2", xc, cfg.norm_eps))
        if ctx is not None:
            xc = ctx.constrain(xc, "batch", None, None)
        return xc, None

    x, _ = jax.lax.scan(
        jax.checkpoint(body, policy=flags.remat_policy()),
        x, params["dec_layers"], unroll=flags.scan_unroll(),
    )
    x = _ln(params, "dec_final", x, cfg.norm_eps)
    if return_hidden:
        return x
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    return logits


def prefill(params, cfg: ArchConfig, tokens: jnp.ndarray, enc_out,
            max_len: int, dtype,
            ctx: Optional[DistContext] = None):
    """Teacher-forced pass that also fills the self-attn KV caches.

    Returns (logits [B, S, Vpad], caches ready for decode_step at pos=S).
    """
    b, s = tokens.shape
    caches = make_decode_caches(params, cfg, enc_out, b, max_len, dtype)
    x = params["embed"][tokens]
    x = x + _sinusoid(s, cfg.d_model)[None].astype(x.dtype)

    def body(xc, xs):
        lp, sk, sv, (ck, cv) = xs
        h = _ln(lp, "ln1", xc, cfg.norm_eps)
        q = _heads(cfg, lp["self_attn"], h, "wq")
        k1 = _heads(cfg, lp["self_attn"], h, "wk")
        v1 = _heads(cfg, lp["self_attn"], h, "wv")
        sk = jax.lax.dynamic_update_slice(sk, k1.astype(sk.dtype), (0, 0, 0, 0))
        sv = jax.lax.dynamic_update_slice(sv, v1.astype(sv.dtype), (0, 0, 0, 0))
        o = flash_attention_ref(q, k1, v1, causal=True,
                                chunk=min(2048 if flags.ANALYSIS_UNROLL else 512, s))
        hm = (jnp.arange(cfg.padded_heads) < cfg.n_heads).astype(o.dtype)
        o = o * hm[None, :, None, None]
        xc = xc + jnp.einsum("bhsk,hkd->bsd", o,
                             lp["self_attn"]["wo"].astype(xc.dtype))
        hx, _ = _mha(lp["cross_attn"], cfg, _ln(lp, "lnx", xc, cfg.norm_eps),
                     None, causal=False, cached_kv=(ck, cv))
        xc = xc + hx
        xc = xc + _ff(lp["ff"], _ln(lp, "ln2", xc, cfg.norm_eps))
        if ctx is not None:
            xc = ctx.constrain(xc, "batch", None, None)
        return xc, (sk, sv)

    x, (nsk, nsv) = jax.lax.scan(
        body, x,
        (params["dec_layers"], caches["self_k"], caches["self_v"],
         caches["cross"]),
        unroll=flags.scan_unroll(),
    )
    x = _ln(params, "dec_final", x[:, -1:], cfg.norm_eps)  # head on last pos
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    caches = dict(caches, self_k=nsk, self_v=nsv,
                  pos=jnp.asarray(s, jnp.int32))
    return logits, caches


def make_decode_caches(params, cfg: ArchConfig, enc_out, batch: int,
                       max_len: int, dtype) -> Dict[str, Any]:
    """Self-attn KV cache + precomputed cross-attn K/V per decoder layer."""
    h, hd = cfg.padded_heads, cfg.head_dim_

    def cross_kv(lp):
        k = _heads(cfg, lp["cross_attn"], enc_out, "wk")
        v = _heads(cfg, lp["cross_attn"], enc_out, "wv")
        return k.astype(dtype), v.astype(dtype)

    cross = jax.lax.map(cross_kv, params["dec_layers"])
    return {
        "self_k": jnp.zeros((cfg.n_layers, batch, h, max_len, hd), dtype),
        "self_v": jnp.zeros((cfg.n_layers, batch, h, max_len, hd), dtype),
        "cross": cross,
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params, cfg: ArchConfig, token: jnp.ndarray, caches,
                ctx: Optional[DistContext] = None):
    """token [B, 1] -> (logits [B, 1, Vpad], new caches)."""
    b = token.shape[0]
    pos = caches["pos"]
    x = params["embed"][token]
    d = cfg.d_model
    posemb = _sinusoid(caches["self_k"].shape[3], d)
    x = x + jax.lax.dynamic_slice(posemb, (pos, 0), (1, d))[None].astype(x.dtype)

    def body(xc, xs):
        lp, sk, sv, (ck, cv) = xs
        h = _ln(lp, "ln1", xc, cfg.norm_eps)
        q = _heads(cfg, lp["self_attn"], h, "wq")
        k1 = _heads(cfg, lp["self_attn"], h, "wk")
        v1 = _heads(cfg, lp["self_attn"], h, "wv")
        sk = jax.lax.dynamic_update_slice(sk, k1.astype(sk.dtype), (0, 0, pos, 0))
        sv = jax.lax.dynamic_update_slice(sv, v1.astype(sv.dtype), (0, 0, pos, 0))
        mask = jnp.arange(sk.shape[2]) <= pos
        s = jnp.einsum("bhqk,bhsk->bhqs", q.astype(jnp.float32),
                       sk.astype(jnp.float32)) * cfg.head_dim_ ** -0.5
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqs,bhsk->bhqk", a, sv.astype(jnp.float32)).astype(xc.dtype)
        hmask = (jnp.arange(cfg.padded_heads) < cfg.n_heads).astype(o.dtype)
        o = o * hmask[None, :, None, None]
        xc = xc + jnp.einsum("bhqk,hkd->bqd", o, lp["self_attn"]["wo"].astype(xc.dtype))
        h, _ = _mha(lp["cross_attn"], cfg, _ln(lp, "lnx", xc, cfg.norm_eps),
                    None, causal=False, cached_kv=(ck, cv))
        xc = xc + h
        xc = xc + _ff(lp["ff"], _ln(lp, "ln2", xc, cfg.norm_eps))
        return xc, (sk, sv)

    x, (nsk, nsv) = jax.lax.scan(
        body, x,
        (params["dec_layers"], caches["self_k"], caches["self_v"],
         caches["cross"]),
        unroll=flags.scan_unroll(),
    )
    x = _ln(params, "dec_final", x, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    new = dict(caches, self_k=nsk, self_v=nsv, pos=pos + 1)
    return logits, new
