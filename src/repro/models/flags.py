"""Analysis-mode switch.

XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
count, so cost_analysis on scanned models undercounts flops/bytes/
collectives. For roofline analysis the dry-run lowers small probe configs
with ANALYSIS_UNROLL set: every lax.scan in the model unrolls (and the
RG-LRU time recurrence switches to an associative scan, which has no while
loop), making the compiled HLO's cost analysis exact. Normal training and
the full-depth compile-proof keep scans (fast compiles, small HLO).
"""
ANALYSIS_UNROLL = False

# ---------------------------------------------------------------------------
# Performance flags (§Perf hillclimb). Baseline = all off (paper-faithful
# reference lowering); the optimized dry-runs toggle these and record
# tagged results so both variants stay visible in EXPERIMENTS.md.
# ---------------------------------------------------------------------------

# Attention internals in bf16 (f32 only for softmax stats + MXU accumulate).
ATTN_COMPUTE_BF16 = False
# Remat policy for scanned layer bodies: "nothing" (full recompute) or
# "dots" (save matmul outputs — less recompute, more resident memory).
REMAT_POLICY = "nothing"
# SSD chunk-length override (0 = kernel default); autotuner-driven.
SSD_CHUNK = 0
# SSD intra-chunk einsums in bf16 (decay stats stay f32).
SSD_COMPUTE_BF16 = False
# Flash-decoding: shard_map LSE-combined decode attention over the
# sequence-sharded KV cache (kills the GQA-repeat replication collectives).
DECODE_ATTN_SHARDED = False


def set_analysis_unroll(value: bool) -> None:
    global ANALYSIS_UNROLL
    ANALYSIS_UNROLL = bool(value)


def set_perf(attn_bf16=None, remat=None, ssd_chunk=None,
             decode_sharded=None, ssd_bf16=None) -> None:
    global ATTN_COMPUTE_BF16, REMAT_POLICY, SSD_CHUNK, DECODE_ATTN_SHARDED
    global SSD_COMPUTE_BF16
    if ssd_bf16 is not None:
        SSD_COMPUTE_BF16 = bool(ssd_bf16)
    if attn_bf16 is not None:
        ATTN_COMPUTE_BF16 = bool(attn_bf16)
    if remat is not None:
        assert remat in ("nothing", "dots")
        REMAT_POLICY = remat
    if ssd_chunk is not None:
        SSD_CHUNK = int(ssd_chunk)
    if decode_sharded is not None:
        DECODE_ATTN_SHARDED = bool(decode_sharded)


def pallas_interpret() -> bool:
    """Interpret-mode Pallas: REPRO_PALLAS_INTERPRET=1 runs the Pallas TPU
    kernels through the Pallas interpreter on host backends. Orders of
    magnitude slower than the reference lowerings — for conformance CI
    only, where it exercises the exact kernel bodies (grid/BlockSpec/
    masking logic) a TPU deployment would run, without TPU hardware."""
    import os
    return os.environ.get("REPRO_PALLAS_INTERPRET", "") not in ("", "0")


def pallas_enabled() -> bool:
    """Whether plan-resolved tiles may select Pallas TPU kernels in the
    model stack. True only on a real TPU backend — or under interpret-mode
    Pallas (see :func:`pallas_interpret`): the kernels cannot lower to host
    HLO, so CPU/GPU backends keep the reference lowerings (tiles still
    parameterize those — e.g. the flash reference's KV chunk)."""
    import jax
    if pallas_interpret():
        return True
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:
        return False


def remat_policy():
    import jax
    if REMAT_POLICY == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def scan_unroll():
    """Pass as lax.scan(..., unroll=scan_unroll())."""
    return True if ANALYSIS_UNROLL else 1
