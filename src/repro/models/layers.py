"""Model primitives: param definitions, norms, RoPE, activations, linear.

Parameters are plain pytrees (nested dicts of arrays). Every parameter is
declared as a :class:`ParamDef` carrying its *logical* sharding axes; the
distributed layer maps logical axes -> mesh axes, so model code never names
mesh axes directly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axes, len == len(shape)
    init: str = "fan_in"              # fan_in | normal | zeros | ones
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def init_tree(defs: Dict[str, Any], key: jax.Array, dtype) -> Dict[str, Any]:
    """Materialize a nested dict of ParamDefs into arrays (deterministic)."""
    flat, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )
    out = []
    for i, d in enumerate(flat):
        k = jax.random.fold_in(key, i)
        if d.init == "zeros":
            arr = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            arr = jnp.ones(d.shape, dtype)
        elif d.init == "normal":
            arr = (jax.random.normal(k, d.shape) * d.scale).astype(dtype)
        elif d.init == "fan_in":
            fan_in = d.shape[0] if len(d.shape) > 1 else d.shape[0]
            std = d.scale / math.sqrt(max(fan_in, 1))
            arr = (jax.random.normal(k, d.shape) * std).astype(dtype)
        else:
            raise ValueError(f"unknown init {d.init}")
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def axes_tree(defs: Dict[str, Any]) -> Dict[str, Any]:
    """The parallel pytree of logical-axes tuples."""
    return jax.tree.map(
        lambda d: d.axes, defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-6, offset: float = 1.0):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * (offset + w.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, w, b, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * w + b).astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True)}[name]


def softcap(x, cap: float):
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x, positions, theta: float = 10000.0):
    """x [..., S, H, D] (or [..., S, D]); positions [..., S] int32."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                              # [D/2]
    ang = positions[..., None].astype(jnp.float32) * inv    # [..., S, D/2]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    if x.ndim == positions.ndim + 2:                        # head axis present
        sin, cos = sin[..., None, :], cos[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
