"""Mixture-of-Experts block with capacity-based gather dispatch and EP.

Distribution design ("masked local EP", DESIGN.md §6): experts are sharded
over the ``model`` mesh axis. Activations arrive replicated across that axis
(the natural state between TP blocks), every model shard computes only the
tokens routed to ITS experts via per-expert gathered batches (static
capacity), and partial outputs combine with the same psum a dense TP
feed-forward would need anyway — no all-to-all in the baseline path.

Dispatch is differentiable end-to-end: argsort builds contiguous expert
groups, per-expert token indices are gathered (static [E_local, C] shape),
expert FFs run as one batched einsum (no ragged shapes), and results
scatter-add back weighted by gates. Over-capacity tokens drop (token-drop
MoE, capacity_factor configurable).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import ParamDef, act_fn


def moe_defs(cfg: ArchConfig) -> Dict[str, ParamDef]:
    m = cfg.moe
    d = cfg.d_model
    defs = {
        "router": ParamDef((d, m.n_experts), ("d_model", None), scale=0.1),
        "w1": ParamDef((m.n_experts, d, m.d_expert), ("experts", "d_model", None)),
        "w3": ParamDef((m.n_experts, d, m.d_expert), ("experts", "d_model", None)),
        "w2": ParamDef((m.n_experts, m.d_expert, d), ("experts", None, "d_model")),
    }
    if m.n_shared_experts:
        ds = m.d_shared or m.n_shared_experts * m.d_expert
        defs["shared_w1"] = ParamDef((d, ds), ("d_model", "ff"))
        defs["shared_w3"] = ParamDef((d, ds), ("d_model", "ff"))
        defs["shared_w2"] = ParamDef((ds, d), ("ff", "d_model"))
    return defs


def _capacity(n_tokens: int, cfg: ArchConfig) -> int:
    m = cfg.moe
    c = int(n_tokens * m.top_k * m.capacity_factor / m.n_experts) + 1
    return max(8, c)


def moe_apply_local(
    p: Dict[str, Any], cfg: ArchConfig, x2d: jnp.ndarray,
    n_local: int, local_offset,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Compute local experts' contribution for replicated tokens x2d [T, D].

    Returns (partial_out [T, D], aux_loss scalar). ``local_offset`` may be a
    traced scalar (derived from the mesh axis index under shard_map).
    """
    m = cfg.moe
    t, d = x2d.shape
    k = m.top_k
    cap = _capacity(t, cfg)

    logits = jnp.einsum(
        "td,de->te", x2d.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)                     # [T, E]
    gates, eidx = jax.lax.top_k(probs, k)                       # [T, k]
    if m.renorm_gates:
        gates = gates / jnp.maximum(
            jnp.sum(gates, axis=-1, keepdims=True), 1e-9
        )

    # Load-balance aux (Switch): E * sum_e f_e * P_e over the full expert set.
    ids_1h = jax.nn.one_hot(eidx[:, 0], m.n_experts, dtype=jnp.float32)
    f = jnp.mean(ids_1h, axis=0)
    pbar = jnp.mean(probs, axis=0)
    aux = m.n_experts * jnp.sum(f * pbar) * m.router_aux_weight

    flat_e = eidx.reshape(-1)                                   # [T*k]
    local_id = flat_e - local_offset
    is_local = (local_id >= 0) & (local_id < n_local)
    key = jnp.where(is_local, local_id, n_local)
    order = jnp.argsort(key)                                    # stable
    sizes = jnp.bincount(
        jnp.where(is_local, local_id, n_local), length=n_local + 1
    )[:n_local]
    starts = jnp.concatenate([jnp.zeros((1,), sizes.dtype), jnp.cumsum(sizes)[:-1]])
    slot = starts[:, None] + jnp.arange(cap)[None, :]           # [E_loc, C]
    valid = jnp.arange(cap)[None, :] < sizes[:, None]
    pair = order[jnp.clip(slot, 0, t * k - 1)]                  # [E_loc, C]
    tok = pair // k

    xg = x2d[tok] * valid[..., None].astype(x2d.dtype)          # [E_loc, C, D]
    act = act_fn(cfg.act)
    h = act(jnp.einsum("ecd,edf->ecf", xg, p["w1"].astype(x2d.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xg, p["w3"].astype(x2d.dtype))
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(x2d.dtype))

    g = gates.reshape(-1)[pair] * valid                         # [E_loc, C]
    contrib = out_e * g[..., None].astype(out_e.dtype)
    y = jnp.zeros_like(x2d).at[tok.reshape(-1)].add(
        contrib.reshape(-1, d), mode="drop",
    )
    return y, aux.astype(jnp.float32)


def _shared_ff(p, cfg: ArchConfig, x2d):
    act = act_fn(cfg.act)
    h = act(x2d @ p["shared_w1"].astype(x2d.dtype))
    h = h * (x2d @ p["shared_w3"].astype(x2d.dtype))
    return h @ p["shared_w2"].astype(x2d.dtype)


def moe_forward(
    p: Dict[str, Any], cfg: ArchConfig, x: jnp.ndarray,
    ctx: Optional["DistContext"] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B, S, D] -> (y [B, S, D], aux scalar)."""
    b, s, d = x.shape
    m = cfg.moe

    if ctx is None or ctx.mesh is None:
        x2d = x.reshape(-1, d)
        y, aux = moe_apply_local(p, cfg, x2d, m.n_experts, 0)
        if m.n_shared_experts:
            y = y + _shared_ff(p, cfg, x2d)
        return y.reshape(b, s, d), aux

    return _moe_forward_sharded(p, cfg, x, ctx)


def _moe_forward_sharded(p, cfg: ArchConfig, x, ctx):
    """shard_map EP: experts over the model axis, tokens over batch axes.

    FSDP-aware boundary: expert weights enter the shard_map STILL sharded
    over the data axis and are all-gathered INSIDE the body. That keeps the
    gather (and its transposed reduce-scatter in the backward) within the
    remat'd layer body, so weight cotangents cross the boundary sharded —
    without this, SPMD materializes data-replicated per-layer cotangents
    across the whole backward scan (~0.14 GiB/layer on qwen3-235B).
    """
    from jax import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding_rules import param_spec

    m = cfg.moe
    b, s, d = x.shape
    mesh = ctx.mesh
    model_axis = ctx.model_axis
    batch_axes = ctx.batch_axes
    n_shards = mesh.shape[model_axis]
    if m.n_experts % n_shards:
        raise ValueError(
            f"{cfg.name}: {m.n_experts} experts not divisible by "
            f"model axis {n_shards}"
        )
    n_local = m.n_experts // n_shards
    has_data = "data" in mesh.axis_names

    from repro.models.layers import axes_tree as _axes  # noqa: F401
    expert_axes = {
        "w1": ("experts", "d_model", None),
        "w3": ("experts", "d_model", None),
        "w2": ("experts", None, "d_model"),
    }
    wspec = {"router": P()}
    gather_dims = {}
    for name, axes in expert_axes.items():
        spec = param_spec(axes, p[name].shape, mesh, fsdp=has_data)
        wspec[name] = spec
        gather_dims[name] = next(
            (i for i, ax in enumerate(spec) if ax == "data"), None)
    if m.n_shared_experts:
        wspec.update({
            "shared_w1": P(None, model_axis),
            "shared_w3": P(None, model_axis),
            "shared_w2": P(model_axis, None),
        })
        for k in ("shared_w1", "shared_w3", "shared_w2"):
            gather_dims[k] = None

    x_spec = P(batch_axes, None, None)

    def body(p_loc, x_loc):
        # Un-FSDP the expert weights locally (bwd: reduce-scatter, inside
        # the remat boundary).
        p_full = dict(p_loc)
        for name, dim in gather_dims.items():
            if dim is not None:
                p_full[name] = jax.lax.all_gather(
                    p_loc[name], "data", axis=dim, tiled=True)
        t_loc = x_loc.shape[0] * x_loc.shape[1]
        x2d = x_loc.reshape(t_loc, d)
        my_shard = jax.lax.axis_index(model_axis)
        y, aux = moe_apply_local(p_full, cfg, x2d, n_local,
                                 my_shard * n_local)
        if m.n_shared_experts:
            y = y + _shared_ff(p_full, cfg, x2d)
        y = jax.lax.psum(y, model_axis)
        aux = jax.lax.pmean(aux, model_axis)
        return y.reshape(x_loc.shape), aux

    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(wspec, x_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(p, x)
    return y, aux
