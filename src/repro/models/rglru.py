"""Griffin/RecurrentGemma recurrent block (RG-LRU + temporal conv branch).

Block structure (arXiv:2402.19427 Fig. 2): two parallel branches from the
input — (a) linear -> causal depthwise conv(width 4) -> RG-LRU, (b) linear
-> GeLU — merged multiplicatively, then a linear output projection.

Decode state: conv tail [B, conv_width-1, F] + recurrent h [B, F].
The sequential scan is the RG-LRU Pallas kernel's job on TPU; the lax.scan
reference path lowers everywhere (same math, see kernels/rglru).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels.rglru.ref import rglru_ref
from repro.models.layers import ParamDef


def rglru_defs(cfg: ArchConfig) -> Dict[str, ParamDef]:
    d = cfg.d_model
    f = cfg.recurrent.lru_width or d
    w = cfg.recurrent.conv_width
    return {
        "wx": ParamDef((d, f), ("d_model", "lru")),
        "wy": ParamDef((d, f), ("d_model", "lru")),
        "conv_w": ParamDef((w, f), (None, "lru"), scale=0.5),
        "conv_b": ParamDef((f,), ("lru",), init="zeros"),
        "wr": ParamDef((f, f), ("lru", None), scale=0.5),
        "br": ParamDef((f,), ("lru",), init="zeros"),
        "wi": ParamDef((f, f), ("lru", None), scale=0.5),
        "bi": ParamDef((f,), ("lru",), init="zeros"),
        "a_param": ParamDef((f,), ("lru",), init="normal", scale=0.5),
        "wo": ParamDef((f, d), ("lru", "d_model")),
    }


def _causal_conv(x, w, b, tail=None):
    """Depthwise causal conv along time. x [B,S,F], w [W,F]; tail [B,W-1,F]."""
    width = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)                  # [B, S+W-1, F]
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :]
        for i in range(width)
    )
    new_tail = xp[:, -(width - 1):, :]
    return out + b[None, None, :], new_tail


def make_rglru_state(cfg: ArchConfig, batch: int, dtype) -> Dict[str, Any]:
    f = cfg.recurrent.lru_width or cfg.d_model
    w = cfg.recurrent.conv_width
    return {
        "conv": jnp.zeros((batch, w - 1, f), dtype),
        "h": jnp.zeros((batch, f), dtype),
    }


def rglru_forward(
    p: Dict[str, Any], cfg: ArchConfig, x: jnp.ndarray,
    state: Optional[Dict[str, Any]] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, Any]]]:
    """x [B, S, D] -> (y [B, S, D], new_state). Works for S==1 (decode)."""
    c = cfg.recurrent.c
    xa = jnp.einsum("bsd,df->bsf", x, p["wx"].astype(x.dtype))
    xb = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["wy"].astype(x.dtype)))

    tail = state["conv"] if state is not None else None
    xa, new_tail = _causal_conv(xa, p["conv_w"].astype(x.dtype),
                                p["conv_b"].astype(x.dtype), tail)

    r = jax.nn.sigmoid(
        jnp.einsum("bsf,fg->bsg", xa, p["wr"].astype(x.dtype))
        + p["br"].astype(x.dtype)
    )
    i = jax.nn.sigmoid(
        jnp.einsum("bsf,fg->bsg", xa, p["wi"].astype(x.dtype))
        + p["bi"].astype(x.dtype)
    )
    h0 = state["h"] if state is not None else None
    y, h_last = rglru_ref(xa, r, i, p["a_param"].astype(jnp.float32), h0=h0, c=c)

    y = y * xb                                               # gated merge
    out = jnp.einsum("bsf,fd->bsd", y, p["wo"].astype(x.dtype))
    new_state = None
    if state is not None:
        new_state = {"conv": new_tail, "h": h_last}
    return out, new_state
