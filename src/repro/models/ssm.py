"""Mamba-2 block (SSD mixer) — attention-free sequence mixing.

Block: per-component projections -> causal depthwise conv over (x,B,C) ->
SSD chunk scan -> gated RMSNorm(z) -> out_proj. Single group (G=1) for B/C,
broadcast over heads; A parameterized as -exp(A_log).

Sharding note: the projections are SEPARATE einsums (z, x, B, C, dt), not
one fused in_proj. A fused [d, 2*di+2n+h] projection splits at offsets that
do not align with model-axis shard boundaries, so XLA replicates the whole
activation — measured ~10x HBM traffic on the mamba2 train cell. Component
projections keep z/x/dt cleanly head-sharded and B/C (d_state wide)
replicated-small.

Decode state: conv tails per conv'd component + SSD state [B, H, N, P].
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import flags
from repro.kernels.ssd.ref import ssd_chunked_ref, ssd_ref
from repro.models.layers import ParamDef, rms_norm
from repro.models.rglru import _causal_conv


def ssm_defs(cfg: ArchConfig) -> Dict[str, ParamDef]:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    h = s.n_heads(d)
    n = s.d_state
    w = s.conv_width
    return {
        "in_z": ParamDef((d, di), ("d_model", "ssm_heads")),
        "in_x": ParamDef((d, di), ("d_model", "ssm_heads")),
        "in_B": ParamDef((d, n), ("d_model", None)),
        "in_C": ParamDef((d, n), ("d_model", None)),
        "in_dt": ParamDef((d, h), ("d_model", "ssm_heads")),
        "conv_x_w": ParamDef((w, di), (None, "ssm_heads"), scale=0.5),
        "conv_x_b": ParamDef((di,), ("ssm_heads",), init="zeros"),
        "conv_B_w": ParamDef((w, n), (None, None), scale=0.5),
        "conv_B_b": ParamDef((n,), (None,), init="zeros"),
        "conv_C_w": ParamDef((w, n), (None, None), scale=0.5),
        "conv_C_b": ParamDef((n,), (None,), init="zeros"),
        "A_log": ParamDef((h,), ("ssm_heads",), init="normal", scale=0.1),
        "D": ParamDef((h,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamDef((h,), ("ssm_heads",), init="zeros"),
        "norm_w": ParamDef((di,), ("ssm_heads",), init="zeros"),
        "out_proj": ParamDef((di, d), ("ssm_heads", "d_model")),
    }


def make_ssm_state(cfg: ArchConfig, batch: int, dtype) -> Dict[str, Any]:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    h = s.n_heads(d)
    w = s.conv_width
    return {
        "conv_x": jnp.zeros((batch, w - 1, di), dtype),
        "conv_B": jnp.zeros((batch, w - 1, s.d_state), dtype),
        "conv_C": jnp.zeros((batch, w - 1, s.d_state), dtype),
        "h": jnp.zeros((batch, h, s.d_state, s.head_dim), dtype),
    }


def ssm_forward(
    p: Dict[str, Any], cfg: ArchConfig, x: jnp.ndarray,
    state: Optional[Dict[str, Any]] = None,
    chunk: int = 0,
) -> Tuple[jnp.ndarray, Optional[Dict[str, Any]]]:
    s = cfg.ssm
    b, slen, d = x.shape
    di = s.d_inner(d)
    h = s.n_heads(d)
    n, pd = s.d_state, s.head_dim

    z = jnp.einsum("bsd,de->bse", x, p["in_z"].astype(x.dtype))
    xs = jnp.einsum("bsd,de->bse", x, p["in_x"].astype(x.dtype))
    Bm = jnp.einsum("bsd,dn->bsn", x, p["in_B"].astype(x.dtype))
    C = jnp.einsum("bsd,dn->bsn", x, p["in_C"].astype(x.dtype))
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["in_dt"].astype(x.dtype))

    t_x = state["conv_x"] if state is not None else None
    t_b = state["conv_B"] if state is not None else None
    t_c = state["conv_C"] if state is not None else None
    xs, nt_x = _causal_conv(xs, p["conv_x_w"].astype(x.dtype),
                            p["conv_x_b"].astype(x.dtype), t_x)
    Bm, nt_b = _causal_conv(Bm, p["conv_B_w"].astype(x.dtype),
                            p["conv_B_b"].astype(x.dtype), t_b)
    C, nt_c = _causal_conv(C, p["conv_C_w"].astype(x.dtype),
                           p["conv_C_b"].astype(x.dtype), t_c)
    xs = jax.nn.silu(xs)
    Bm = jax.nn.silu(Bm)
    C = jax.nn.silu(C)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )                                                        # [B, S, H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))             # [H]
    xh = xs.reshape(b, slen, h, pd)

    h0 = state["h"] if state is not None else None
    if slen == 1:
        y, h_last = ssd_ref(xh, dt, A, Bm, C, p["D"].astype(jnp.float32), h0=h0)
    else:
        if not chunk:
            chunk = flags.SSD_CHUNK or (512 if flags.ANALYSIS_UNROLL else 128)
        y, h_last = ssd_chunked_ref(
            xh, dt, A, Bm, C, p["D"].astype(jnp.float32), h0=h0,
            chunk=min(chunk, slen),
        )
    y = y.reshape(b, slen, di)
    y = rms_norm(y * jax.nn.silu(z.astype(y.dtype)), p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    new_state = None
    if state is not None:
        new_state = {"conv_x": nt_x, "conv_B": nt_b, "conv_C": nt_c,
                     "h": h_last}
    return out, new_state
