"""Generic decoder stack builder — one builder for all ten architectures.

An ArchConfig's layer pattern is grouped into runs of identical LayerSpecs;
each run's parameters are stacked on a leading layer axis and the run is
executed with ``jax.lax.scan`` (+ remat), so a 94-layer model compiles as one
scanned superblock. Hybrid patterns (recurrentgemma's rglru/rglru/attn,
gemma2's local/global alternation) scan their repeat unit.

Decode/prefill use the same grouped structure with per-layer caches stacked
along the scan axis.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models import flags
from repro.models.context import DistContext
from repro.models.layers import (
    ParamDef, act_fn, axes_tree, init_tree, layer_norm, rms_norm, softcap,
)


# ---------------------------------------------------------------------------
# Param definitions
# ---------------------------------------------------------------------------

def dense_ff_defs(cfg: ArchConfig) -> Dict[str, ParamDef]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w1": ParamDef((d, f), ("d_model", "ff")),
        "w3": ParamDef((d, f), ("d_model", "ff")),
        "w2": ParamDef((f, d), ("ff", "d_model")),
    }


def _norm_defs(cfg: ArchConfig, name: str) -> Dict[str, ParamDef]:
    if cfg.norm_kind == "layernorm":
        return {
            f"{name}_w": ParamDef((cfg.d_model,), (None,), init="ones"),
            f"{name}_b": ParamDef((cfg.d_model,), (None,), init="zeros"),
        }
    return {f"{name}_w": ParamDef((cfg.d_model,), (None,), init="zeros")}


def _apply_norm(p, cfg: ArchConfig, x, name: str):
    if cfg.norm_kind == "layernorm":
        return layer_norm(x, p[f"{name}_w"], p[f"{name}_b"], cfg.norm_eps)
    return rms_norm(x, p[f"{name}_w"], cfg.norm_eps)


def layer_defs(cfg: ArchConfig, spec: LayerSpec) -> Dict[str, Any]:
    defs: Dict[str, Any] = {}
    defs.update(_norm_defs(cfg, "norm1"))
    if spec.mixer in ("attn", "local_attn"):
        defs["attn"] = attn_mod.attn_defs(cfg)
    elif spec.mixer == "rglru":
        defs["rglru"] = rglru_mod.rglru_defs(cfg)
    elif spec.mixer == "ssd":
        defs["ssm"] = ssm_mod.ssm_defs(cfg)
    else:
        raise ValueError(f"unknown mixer {spec.mixer}")
    if cfg.post_norms:
        defs.update(_norm_defs(cfg, "post1"))
    if spec.ff is not None:
        if not cfg.parallel_block:
            defs.update(_norm_defs(cfg, "norm2"))
        if spec.ff == "dense":
            defs["ff"] = dense_ff_defs(cfg)
        elif spec.ff == "moe":
            defs["moe"] = moe_mod.moe_defs(cfg)
        else:
            raise ValueError(f"unknown ff {spec.ff}")
        if cfg.post_norms:
            defs.update(_norm_defs(cfg, "post2"))
    return defs


def decompose(cfg: ArchConfig) -> List[Tuple]:
    """Split the layer pattern into scan-able segments.

    Returns a list of ("seq", (specs...)) and ("scan", unit_specs, reps)
    segments. A periodic pattern (gemma2's local/global alternation,
    recurrentgemma's rglru/rglru/attn unit) scans its repeat UNIT — one
    heterogeneous body over ``reps`` iterations — so alternating-layer
    models compile as one scanned superblock instead of unrolling (which
    costs compile time AND saved-residual memory: ~1.6 GiB/layer measured
    on gemma2 before this decomposition existed).
    """
    pattern = cfg.layers()
    n = len(pattern)
    best = None  # (scanned_layers, -unit_len, start, p, reps)
    for start in range(0, min(4, n)):
        for p in range(1, 9):
            if start + 2 * p > n:
                break
            reps = (n - start) // p
            if reps < 2:
                continue
            if all(pattern[start + i] == pattern[start + (i % p)]
                   for i in range(reps * p)):
                cand = (reps * p, -p, start, p, reps)
                if best is None or cand > best:
                    best = cand
    if best is None:
        return [("seq", tuple(pattern))] if pattern else []
    _, _, start, p, reps = best
    segments: List[Tuple] = []
    if start:
        segments.append(("seq", tuple(pattern[:start])))
    segments.append(("scan", tuple(pattern[start:start + p]), reps))
    rest = pattern[start + reps * p:]
    if rest:
        segments.append(("seq", tuple(rest)))
    return segments


def group_layers(cfg: ArchConfig) -> List[Tuple[LayerSpec, int]]:
    """Consecutive-run view (kept for tests/back-compat)."""
    groups: List[Tuple[LayerSpec, int]] = []
    for spec in cfg.layers():
        if groups and groups[-1][0] == spec:
            groups[-1] = (spec, groups[-1][1] + 1)
        else:
            groups.append((spec, 1))
    return groups


def model_defs(cfg: ArchConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.padded_vocab
    defs: Dict[str, Any] = {
        "embed": ParamDef((v, d), ("vocab", "d_model"), init="normal", scale=0.02),
    }
    defs.update(_norm_defs(cfg, "final_norm"))
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, v), ("d_model", "vocab"), init="normal",
                                   scale=0.02)
    segs = []
    for seg in decompose(cfg):
        if seg[0] == "seq":
            segs.append([layer_defs(cfg, spec) for spec in seg[1]])
        else:
            _, unit, reps = seg
            segs.append([_stack_defs(layer_defs(cfg, spec), reps)
                         for spec in unit])
    defs["segments"] = segs
    if cfg.encoder is not None and cfg.encoder.kind == "vision":
        defs["vit_proj"] = {
            "w": ParamDef((1024, d), (None, "d_model")),
            "b": ParamDef((d,), (None,), init="zeros"),
        }
    return defs


def _stack_defs(defs: Dict[str, Any], count: int) -> Dict[str, Any]:
    return jax.tree.map(
        lambda pd: ParamDef((count,) + pd.shape, (None,) + pd.axes,
                            init=pd.init, scale=pd.scale),
        defs, is_leaf=lambda x: isinstance(x, ParamDef),
    )


def init_params(cfg: ArchConfig, key: jax.Array, dtype=jnp.float32):
    return init_tree(model_defs(cfg), key, dtype)


def param_logical_axes(cfg: ArchConfig):
    return axes_tree(model_defs(cfg))


# ---------------------------------------------------------------------------
# Layer forward
# ---------------------------------------------------------------------------

def _mixer(p, cfg: ArchConfig, spec: LayerSpec, x, positions, cache,
           decode: bool, ctx=None, tiles=None, chunk_start=None,
           pack_layout=None):
    tiles = tiles or {}
    if pack_layout is not None:
        return _mixer_packed(p, cfg, spec, x, positions, cache, tiles,
                             pack_layout)
    if spec.mixer in ("attn", "local_attn"):
        window = cfg.attn_window if spec.mixer == "local_attn" else None
        if decode:
            return attn_mod.attn_decode(p["attn"], cfg, x, cache=cache,
                                        window=window, ctx=ctx,
                                        tile=tiles.get("flash_decode"))
        if chunk_start is not None:
            return attn_mod.attn_prefill_chunk(
                p["attn"], cfg, x, positions, cache=cache,
                start=chunk_start, window=window,
                tile=tiles.get("chunked_prefill"))
        return attn_mod.attn_forward(p["attn"], cfg, x, positions,
                                     window=window, cache=cache,
                                     tile=tiles.get("flash_attention"))
    if spec.mixer == "rglru":
        return rglru_mod.rglru_forward(p["rglru"], cfg, x, state=cache)
    if spec.mixer == "ssd":
        ssd_tile = tiles.get("ssd")
        return ssm_mod.ssm_forward(p["ssm"], cfg, x, state=cache,
                                   chunk=ssd_tile[0] if ssd_tile else 0)
    raise ValueError(spec.mixer)


def _mixer_packed(p, cfg: ArchConfig, spec: LayerSpec, x, positions, caches,
                  tiles, layout):
    """One mixer over a packed (segment-concatenated) multi-request step.

    ``caches`` is a TUPLE of per-request layer caches/states (one per
    segment of the static ``layout``). Attention layers run the whole pack
    as ONE segment-masked launch (``attn_prefill_packed``); recurrent/SSD
    layers are sequence recurrences — a packed sequence would leak state
    across segment boundaries — so they run per segment on static slices,
    each continuing its own carried state (the surrounding norms/FF still
    run packed, which is where their win lives anyway).
    """
    if spec.mixer in ("attn", "local_attn"):
        window = cfg.attn_window if spec.mixer == "local_attn" else None
        return attn_mod.attn_prefill_packed(
            p["attn"], cfg, x, positions, caches=caches, layout=layout,
            window=window, tile=tiles.get("packed_prefill"))
    outs, news = [], []
    off = 0
    for (_, ln), cache in zip(layout, caches):
        seg = x[:, off:off + ln]
        if spec.mixer == "rglru":
            y, nc = rglru_mod.rglru_forward(p["rglru"], cfg, seg, state=cache)
        elif spec.mixer == "ssd":
            ssd_tile = tiles.get("ssd")
            y, nc = ssm_mod.ssm_forward(p["ssm"], cfg, seg, state=cache,
                                        chunk=ssd_tile[0] if ssd_tile else 0)
        else:
            raise ValueError(spec.mixer)
        outs.append(y)
        news.append(nc)
        off += ln
    return jnp.concatenate(outs, axis=1), tuple(news)


def _tile_fits(tile, m: int, k: int, n: int) -> bool:
    """True when the (clamped) tile divides the GEMM — pallas_call legality."""
    return all(dim % min(t, dim) == 0
               for t, dim in zip(tile, (m, k, n)))


def _dense_ff(p, cfg: ArchConfig, x, tile=None):
    """SwiGLU FF. ``tile`` is the plan-resolved matmul tile (bm, bk, bn);
    on TPU backends the projection GEMMs run through the tiled Pallas matmul
    kernel with it (inference paths), elsewhere the tile is advisory and the
    einsum lowering is kept (Pallas TPU kernels cannot lower to host HLO)."""
    act = act_fn(cfg.act)
    b, s, d = x.shape
    f = p["w1"].shape[1]
    if (tile is not None and flags.pallas_enabled()
            and _tile_fits(tile, b * s, d, f)
            and _tile_fits(tile, b * s, f, d)):
        from repro.kernels.matmul.ops import mm

        xf = x.reshape(b * s, d)
        t = tuple(tile)
        interp = flags.pallas_interpret()
        h = act(mm(xf, p["w1"].astype(x.dtype), tile=t, interpret=interp))
        h = h * mm(xf, p["w3"].astype(x.dtype), tile=t, interpret=interp)
        return mm(h, p["w2"].astype(x.dtype), tile=t,
                  interpret=interp).reshape(b, s, -1)
    h = act(jnp.einsum("bsd,df->bsf", x, p["w1"].astype(x.dtype)))
    h = h * jnp.einsum("bsd,df->bsf", x, p["w3"].astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", h, p["w2"].astype(x.dtype))


def layer_forward(
    p, cfg: ArchConfig, spec: LayerSpec, x, positions, cache,
    ctx: Optional[DistContext], decode: bool = False, tiles=None,
    chunk_start=None, pack_layout=None,
):
    """Returns (x_out, new_cache, aux). With ``pack_layout`` (a packed
    multi-request step) ``cache`` is a tuple of per-request caches and the
    returned new_cache matches."""
    aux = jnp.zeros((), jnp.float32)
    ff_tile = (tiles or {}).get("matmul")
    h = _apply_norm(p, cfg, x, "norm1")
    mix, new_cache = _mixer(p, cfg, spec, h, positions, cache, decode, ctx,
                            tiles, chunk_start=chunk_start,
                            pack_layout=pack_layout)
    if cfg.post_norms:
        mix = _apply_norm(p, cfg, mix, "post1")

    if cfg.parallel_block and spec.ff is not None:
        ff = _dense_ff(p["ff"], cfg, h, tile=ff_tile)
        x = x + mix + ff
    else:
        x = x + mix
        if spec.ff is not None:
            h2 = _apply_norm(p, cfg, x, "norm2")
            if spec.ff == "dense":
                ff = _dense_ff(p["ff"], cfg, h2, tile=ff_tile)
            else:
                ff, aux = moe_mod.moe_forward(p["moe"], cfg, h2, ctx)
            if cfg.post_norms:
                ff = _apply_norm(p, cfg, ff, "post2")
            x = x + ff
    if ctx is not None:
        x = ctx.constrain(x, "batch", None, None)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Stack forward
# ---------------------------------------------------------------------------

def _scan_unit(
    unit_params, cfg: ArchConfig, unit: Tuple[LayerSpec, ...], x, positions,
    unit_caches, ctx, decode: bool, remat: bool, tiles=None, chunk_start=None,
    pack_layout=None,
):
    """Scan a repeat unit (tuple of per-position stacked params) ``reps``
    times. unit_caches: matching list of stacked caches (or None); in a
    packed step each element is a TUPLE of per-request stacked caches —
    scan slices every leaf's rep axis, tuples included."""

    def body(carry, xs):
        xc, aux_sum = carry
        lps, lcs = xs
        ncs = []
        for spec, lp, lc in zip(unit, lps, lcs):
            xc, nc, aux = layer_forward(lp, cfg, spec, xc, positions, lc,
                                        ctx, decode, tiles=tiles,
                                        chunk_start=chunk_start,
                                        pack_layout=pack_layout)
            aux_sum = aux_sum + aux
            ncs.append(nc)
        return (xc, aux_sum), ncs

    fn = body
    if remat:
        fn = jax.checkpoint(body, policy=flags.remat_policy())
    if unit_caches is None:
        unit_caches = [None] * len(unit)
    (x, aux), new_caches = jax.lax.scan(
        fn, (x, jnp.zeros((), jnp.float32)),
        (tuple(unit_params), tuple(unit_caches)),
        unroll=flags.scan_unroll(),
    )
    return x, list(new_caches), aux


@dataclasses.dataclass(frozen=True)
class StackOutputs:
    logits: Optional[jnp.ndarray]
    aux_loss: jnp.ndarray
    caches: Optional[List[Any]] = None
    hidden: Optional[jnp.ndarray] = None
    # Updated paged-pool arrays (same structure as ``make_paged_pool``) when
    # the call ran pool-backed; None otherwise.
    pool: Optional[List[Any]] = None


def _cache_for(cfg, spec, batch, max_len, dtype, ring_local, paged=False):
    if spec.mixer in ("attn", "local_attn"):
        if paged:
            # Pool-backed request state: K/V live in the engine's shared
            # page arrays; the request itself carries only its write
            # position (its page table is engine-side bookkeeping, merged
            # in at call time). Windowed layers use the linear paged cache
            # too — the attention mask enforces the window, the ring's
            # memory bound is the pool's job now.
            return {"pos": jnp.zeros((), jnp.int32)}
        ring = ring_local and spec.mixer == "local_attn"
        length = min(max_len, cfg.attn_window) if ring else max_len
        return attn_mod.make_kv_cache(cfg, batch, length, dtype, ring=ring)
    if spec.mixer == "rglru":
        return rglru_mod.make_rglru_state(cfg, batch, dtype)
    if spec.mixer == "ssd":
        return ssm_mod.make_ssm_state(cfg, batch, dtype)
    raise ValueError(spec.mixer)


def make_caches(
    cfg: ArchConfig, batch: int, max_len: int, dtype,
    ring_local: bool = False, paged: bool = False,
) -> List[Any]:
    """Caches mirroring the segment decomposition: seq segments get a list
    of per-layer caches; scan segments get per-position stacked caches.
    ``paged=True`` builds pool-backed request state: attention layers hold
    only their scalar write position (pages come from ``make_paged_pool``),
    recurrent/SSD layers keep their usual carried state."""
    caches = []
    for seg in decompose(cfg):
        if seg[0] == "seq":
            caches.append([
                _cache_for(cfg, spec, batch, max_len, dtype, ring_local,
                           paged=paged)
                for spec in seg[1]
            ])
        else:
            _, unit, reps = seg
            caches.append([
                jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (reps,) + a.shape),
                    _cache_for(cfg, spec, batch, max_len, dtype, ring_local,
                               paged=paged))
                for spec in unit
            ])
    return caches


def make_paged_pool(
    cfg: ArchConfig, n_pages: int, page: int, dtype,
) -> List[Any]:
    """The engine-wide paged KV pool: per attention layer, physical page
    arrays ``[n_pages, Hkv, page, hd]`` (scan segments stack them on the
    rep axis like :func:`make_caches` stacks caches). Non-attention layers
    get ``None`` — their state stays per-request. Structure mirrors the
    segment decomposition so :func:`forward` can zip pool leaves with
    caches layer by layer."""

    def leaf(spec):
        if spec.mixer in ("attn", "local_attn"):
            return attn_mod.make_paged_kv_pages(cfg, n_pages, page, dtype)
        return None

    pool = []
    for seg in decompose(cfg):
        if seg[0] == "seq":
            pool.append([leaf(spec) for spec in seg[1]])
        else:
            _, unit, reps = seg
            pool.append([
                jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (reps,) + a.shape),
                    leaf(spec))
                for spec in unit
            ])
    return pool


def _merge_pool_leaf(cache, pool_leaf, table):
    """Hand a layer its pool pages + page table by merging them into its
    cache dict — the attention paths dispatch on ``k_pages``/``table`` keys,
    so scan/remat plumbing never changes shape."""
    if pool_leaf is None:
        return cache
    return {**cache, **pool_leaf, "table": table}


def _split_pool_leaf(new_cache):
    """Inverse of :func:`_merge_pool_leaf` on a layer's output: returns
    ``(request_state, pool_leaf_or_None)`` with the table dropped (it is
    engine bookkeeping, not model state)."""
    if isinstance(new_cache, dict) and "k_pages" in new_cache:
        pl = {"k_pages": new_cache["k_pages"],
              "v_pages": new_cache["v_pages"]}
        st = {k: v for k, v in new_cache.items()
              if k not in ("k_pages", "v_pages", "table")}
        return st, pl
    return new_cache, None


def forward(
    params, cfg: ArchConfig, tokens: jnp.ndarray,
    ctx: Optional[DistContext] = None,
    caches: Optional[List[Any]] = None,
    patch_embeds: Optional[jnp.ndarray] = None,
    decode: bool = False,
    start_pos: int = 0,
    remat: bool = True,
    logits_mode: str = "full",   # full | last | hidden
    tiles=None,
    chunked: bool = False,
    pool: Optional[List[Any]] = None,
    page_table: Optional[jnp.ndarray] = None,
) -> StackOutputs:
    """tokens [B, S] -> logits [B, S(+P), Vpad].

    ``decode=True``: S must be 1 and ``caches`` supplied (positions come from
    cache state). ``patch_embeds`` [B, P, 1024] (vlm stub) are projected and
    prepended to the token embeddings. ``logits_mode``: "last" applies the
    LM head to the final position only (prefill); "hidden" skips the head
    and returns normed hidden states (pair with fused_lm_loss to avoid
    materializing [B, S, V] logits). ``tiles`` (kernel name -> TileShape,
    from a resolved AOT plan) parameterizes the attention/FF/SSD kernel call
    sites — see ``launch.specs.resolve_model_tiles``.

    ``chunked=True`` runs the stack as one chunk of a multi-step prefill:
    tokens sit at absolute positions ``start_pos..start_pos+S-1`` (static
    ``start_pos``), attention layers attend over the cache written by the
    previous chunks plus the chunk itself (``attn_prefill_chunk``), and
    recurrent/SSD layers continue from their carried state — which they do
    natively, since ``caches`` is their initial state. Requires ``caches``.

    ``pool`` + ``page_table`` run the attention layers pool-backed: caches
    must come from ``make_caches(paged=True)``, the pool from
    ``make_paged_pool``, and ``page_table`` is the request's [n_pt] int32
    logical->physical page map (``serve.pool.PagedKVPool.device_table``).
    The updated page arrays come back in ``StackOutputs.pool``. Only the
    decode and chunked-prefill paths support it (a paged request prefills
    through chunk programs — a whole prompt is just one big chunk).
    """
    b, s = tokens.shape
    x = params["embed"][tokens]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if patch_embeds is not None:
        pe = (
            patch_embeds @ params["vit_proj"]["w"].astype(patch_embeds.dtype)
            + params["vit_proj"]["b"].astype(patch_embeds.dtype)
        )
        x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
        s = x.shape[1]
    if ctx is not None:
        x = ctx.constrain(x, "batch", None, None)

    positions = start_pos + jnp.arange(s)[None, :].astype(jnp.int32)
    positions = jnp.broadcast_to(positions, (b, s))

    if chunked and caches is None:
        raise ValueError("chunked prefill requires caches (serve state)")
    if pool is not None and not (decode or chunked):
        raise ValueError(
            "pool-backed forward supports decode and chunked prefill only")
    chunk_start = start_pos if chunked else None

    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Optional[List[Any]] = [] if caches is not None else None
    new_pool: Optional[List[Any]] = [] if pool is not None else None
    for gi, seg in enumerate(decompose(cfg)):
        gp = params["segments"][gi]
        gc = caches[gi] if caches is not None else None
        pg = pool[gi] if pool is not None else None
        if seg[0] == "seq":
            ncs = []
            nps = []
            for li, spec in enumerate(seg[1]):
                lc = gc[li] if gc is not None else None
                if pg is not None:
                    lc = _merge_pool_leaf(lc, pg[li], page_table)
                x, nc, aux = layer_forward(gp[li], cfg, spec, x, positions,
                                           lc, ctx, decode, tiles=tiles,
                                           chunk_start=chunk_start)
                aux_total = aux_total + aux
                if pg is not None:
                    nc, pl = _split_pool_leaf(nc)
                    nps.append(pl)
                ncs.append(nc)
        else:
            _, unit, reps = seg
            if pg is not None:
                tbl = jnp.broadcast_to(
                    page_table[None], (reps,) + page_table.shape)
                gc = [_merge_pool_leaf(c, pl, tbl)
                      for c, pl in zip(gc, pg)]
            x, ncs, aux = _scan_unit(
                gp, cfg, unit, x, positions, gc, ctx, decode,
                remat=remat and not decode, tiles=tiles,
                chunk_start=chunk_start,
            )
            aux_total = aux_total + aux
            if pg is not None:
                split = [_split_pool_leaf(nc) for nc in ncs]
                ncs = [st for st, _ in split]
                nps = [pl for _, pl in split]
        if new_caches is not None:
            new_caches.append(ncs)
        if new_pool is not None:
            new_pool.append(nps)

    x = _apply_norm(params, cfg, x, "final_norm")
    if logits_mode == "hidden":
        return StackOutputs(logits=None, aux_loss=aux_total,
                            caches=new_caches, hidden=x, pool=new_pool)
    if logits_mode == "last":
        x = x[:, -1:]
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    )
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    if cfg.final_softcap:
        logits = softcap(logits, cfg.final_softcap)
    if ctx is not None:
        logits = ctx.constrain(logits, "batch", None, "vocab")
    return StackOutputs(logits=logits, aux_loss=aux_total, caches=new_caches,
                        hidden=x, pool=new_pool)


def forward_packed(
    params, cfg: ArchConfig, tokens: jnp.ndarray, states, layout,
    ctx: Optional[DistContext] = None, tiles=None,
    pool: Optional[List[Any]] = None, page_tables=None,
):
    """One packed multi-request prefill step over the whole stack.

    ``tokens`` [1, S_packed] segment-concatenates N requests' chunks;
    ``layout`` is the static tuple of per-segment ``(start, len)`` pairs
    and ``states`` the matching tuple of per-request serve states (from
    :func:`make_caches` / the previous chunk). Embedding, norms, and FF
    GEMMs run once over the pack (the step-packing occupancy win);
    attention runs one segment-masked launch per layer
    (``attn_prefill_packed``); recurrent/SSD mixers continue each
    request's carried state on per-segment slices. Per request the math is
    exactly the chunked prefill of ``forward(chunked=True)``.

    Returns ``(logits [N, Vpad], new_states)``: each segment's final-
    position logits (a request's first sampled token when this was its
    last chunk) and the tuple of per-request updated states.

    ``pool`` + ``page_tables`` (one table per segment) run the pack
    pool-backed: states come from ``make_caches(paged=True)`` and the
    SHARED page arrays ride segment 0's merged cache through the stack
    (``attn_prefill_packed``'s convention). The return grows a third
    element — the updated pool — so non-paged callers are untouched.
    """
    b, s = tokens.shape
    if b != 1:
        raise ValueError("packed prefill packs segments, not batch rows")
    if not layout or len(states) != len(layout):
        raise ValueError(f"layout/state mismatch: {len(layout)} segments, "
                         f"{len(states)} states")
    if sum(ln for _, ln in layout) != s:
        raise ValueError(f"layout {layout} does not cover {s} tokens")
    n_req = len(states)
    if pool is not None and (page_tables is None
                             or len(page_tables) != n_req):
        raise ValueError("pool-backed pack needs one page table per segment")

    def _merge_packed(cs, pool_leaf, reps=None):
        # Per-request merged caches: every segment gets its own table,
        # segment 0 additionally carries the shared page arrays.
        if pool is None or pool_leaf is None:
            return cs
        merged = []
        for r, c in enumerate(cs):
            tbl = page_tables[r]
            if reps is not None:
                tbl = jnp.broadcast_to(tbl[None], (reps,) + tbl.shape)
            merged.append(_merge_pool_leaf(
                c, pool_leaf if r == 0 else {}, tbl))
        return tuple(merged)

    def _split_packed(ncs):
        st0, pl = _split_pool_leaf(ncs[0])
        if pl is None:
            return ncs, None
        rest = tuple({k: v for k, v in c.items() if k != "table"}
                     for c in ncs[1:])
        return (st0,) + rest, pl

    x = params["embed"][tokens]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    positions = jnp.concatenate([
        start + jnp.arange(ln, dtype=jnp.int32) for start, ln in layout
    ])[None]
    if ctx is not None:
        x = ctx.constrain(x, "batch", None, None)

    # Per-request new states, mirroring each input state's segment layout.
    new_states: List[List[Any]] = [[] for _ in range(n_req)]
    new_pool: Optional[List[Any]] = [] if pool is not None else None
    for gi, seg in enumerate(decompose(cfg)):
        gp = params["segments"][gi]
        pg = pool[gi] if pool is not None else None
        if seg[0] == "seq":
            ncs = []
            nps = []
            for li, spec in enumerate(seg[1]):
                lc = tuple(st[gi][li] for st in states)
                if pg is not None:
                    lc = _merge_packed(lc, pg[li])
                x, nc, _ = layer_forward(gp[li], cfg, spec, x, positions,
                                         lc, ctx, False, tiles=tiles,
                                         pack_layout=layout)
                if pg is not None:
                    nc, pl = _split_packed(nc)
                    nps.append(pl)
                ncs.append(nc)                    # tuple over requests
            for r in range(n_req):
                new_states[r].append([nc[r] for nc in ncs])
        else:
            _, unit, reps = seg
            gc = [tuple(st[gi][ui] for st in states)
                  for ui in range(len(unit))]
            if pg is not None:
                gc = [_merge_packed(cs, pg[ui], reps=reps)
                      for ui, cs in enumerate(gc)]
            x, ncs, _ = _scan_unit(
                gp, cfg, unit, x, positions, gc, ctx, False, remat=False,
                tiles=tiles, pack_layout=layout,
            )
            if pg is not None:
                nps = []
                stripped = []
                for nc in ncs:
                    nc, pl = _split_packed(nc)
                    stripped.append(nc)
                    nps.append(pl)
                ncs = stripped
            for r in range(n_req):
                new_states[r].append([nc[r] for nc in ncs])
        if new_pool is not None:
            new_pool.append(nps)

    x = _apply_norm(params, cfg, x, "final_norm")
    ends = []
    off = 0
    for _, ln in layout:
        off += ln
        ends.append(off - 1)
    x_last = x[0, jnp.asarray(ends)]              # [N, D]
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    )
    logits = jnp.einsum("nd,dv->nv", x_last, head.astype(x_last.dtype))
    if cfg.final_softcap:
        logits = softcap(logits, cfg.final_softcap)
    if pool is None:
        return logits, tuple(new_states)
    return logits, tuple(new_states), new_pool


def lm_loss(logits: jnp.ndarray, targets: jnp.ndarray, cfg: ArchConfig,
            mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Cross-entropy over the real (unpadded) vocab."""
    v = cfg.padded_vocab
    vocab_ok = jnp.arange(v) < cfg.vocab_size
    logits = jnp.where(vocab_ok[None, None], logits.astype(jnp.float32),
                       -1e30)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def fused_lm_loss(
    head: jnp.ndarray, hidden: jnp.ndarray, targets: jnp.ndarray,
    cfg: ArchConfig, chunk: int = 1024,
) -> jnp.ndarray:
    """Head-projection + cross-entropy scanned over sequence chunks.

    Never materializes [B, S, Vpad] logits: each chunk's logits live only
    inside a checkpointed scan body (recomputed in backward). This is what
    lets 150k-vocab models train at seq 4096 within HBM.
    """
    b, s, d = hidden.shape
    if flags.ANALYSIS_UNROLL:
        chunk = 4096
    chunk = min(chunk, s)
    if s % chunk:
        chunk = s  # fall back to unchunked for odd lengths
    n = s // chunk
    hc = hidden.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, n, chunk).transpose(1, 0, 2)
    vocab_ok = jnp.arange(cfg.padded_vocab) < cfg.vocab_size

    def body(total, xs):
        h, t = xs
        logits = jnp.einsum(
            "bsd,dv->bsv", h.astype(jnp.float32),
            head.astype(jnp.float32),
        )
        if cfg.final_softcap:
            logits = softcap(logits, cfg.final_softcap)
        logits = jnp.where(vocab_ok[None, None], logits, -1e30)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, t[..., None], axis=-1)[..., 0]
        return total + jnp.sum(nll), None

    total, _ = jax.lax.scan(
        jax.checkpoint(body, policy=flags.remat_policy()),
        jnp.zeros((), jnp.float32), (hc, tc),
        unroll=flags.scan_unroll(),
    )
    return total / (b * s)
