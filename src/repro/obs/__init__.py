"""Observability: the serving stack's flight recorder.

``repro.obs.trace`` records request-lifecycle spans, per-step engine spans,
plan-decision audit instants and scheduler queue events against an injected
clock (virtual-clock bench runs trace deterministically);
``repro.obs.export`` emits the Chrome-trace/Perfetto JSON and JSONL
artifacts the ``python -m repro.launch.trace_report`` CLI consumes.
"""
from repro.obs.export import (
    load_trace,
    to_chrome,
    write_jsonl,
    write_trace,
)
from repro.obs.trace import (
    TRACE_SCHEMA_VERSION,
    ProcTrace,
    Tracer,
)

__all__ = [
    "TRACE_SCHEMA_VERSION", "Tracer", "ProcTrace",
    "to_chrome", "write_trace", "write_jsonl", "load_trace",
]
