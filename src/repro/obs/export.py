"""Trace exporters: Chrome-trace/Perfetto JSON and JSONL.

Both formats serialize with sorted keys and compact separators so two
identical virtual-clock runs write **byte-identical** files (CPython's
float repr is deterministic, and the tracer's event order is the
engines' deterministic execution order).

Chrome-trace mapping: each attached process becomes a Perfetto process
row (``process_name`` metadata carries the engine name and hardware),
each lane becomes a named thread row, timestamps convert from clock
seconds to microseconds. Load the file at https://ui.perfetto.dev or
``chrome://tracing``.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List

from repro.obs.trace import TRACE_SCHEMA_VERSION, Tracer, lane_name

_US = 1e6


def to_chrome(tracer: Tracer) -> Dict[str, Any]:
    """Render the tracer's events as a Chrome-trace (Perfetto) dict."""
    tracer.flush()
    events: List[Dict[str, Any]] = []
    lanes_seen: Dict[int, set] = {}
    for proc in tracer.procs:
        args = {"name": proc["name"]}
        if proc.get("hardware"):
            args["name"] = f"{proc['name']} [{proc['hardware']}]"
        events.append({"ph": "M", "name": "process_name", "pid": proc["pid"],
                       "tid": 0, "ts": 0, "args": args})
        events.append({"ph": "M", "name": "process_sort_index",
                       "pid": proc["pid"], "tid": 0, "ts": 0,
                       "args": {"sort_index": proc["pid"]}})
        lanes_seen[proc["pid"]] = set()
    for ev in tracer.events:
        lanes_seen.setdefault(ev["pid"], set()).add(ev["tid"])
        out: Dict[str, Any] = {
            "ph": ev["ph"], "name": ev["name"], "cat": ev["cat"],
            "pid": ev["pid"], "tid": ev["tid"], "ts": ev["ts"] * _US,
        }
        if ev["ph"] == "i":
            out["s"] = "t"  # thread-scoped instant
        if ev["ph"] in ("b", "e"):
            # Async events need an id; rid is unique per process.
            out["id"] = (ev.get("args") or {}).get("id", 0)
        if "dur" in ev:
            out["dur"] = ev["dur"] * _US
        if "args" in ev:
            out["args"] = ev["args"]
        events.append(out)
    for pid in sorted(lanes_seen):
        for tid in sorted(lanes_seen[pid]):
            events.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": tid, "ts": 0,
                           "args": {"name": lane_name(tid)}})
            events.append({"ph": "M", "name": "thread_sort_index", "pid": pid,
                           "tid": tid, "ts": 0, "args": {"sort_index": tid}})
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"trace_schema": TRACE_SCHEMA_VERSION},
    }


def write_trace(tracer: Tracer, path: str) -> None:
    """Write Chrome-trace JSON. Deterministic byte-for-byte for
    deterministic-clock runs."""
    doc = to_chrome(tracer)
    with open(path, "w") as f:
        json.dump(doc, f, sort_keys=True, separators=(",", ":"))
        f.write("\n")


def write_jsonl(tracer: Tracer, path: str) -> None:
    """Write raw events (seconds, uncooked) one JSON object per line,
    preceded by one header line and the process table."""
    tracer.flush()
    with open(path, "w") as f:
        f.write(json.dumps({"trace_schema": TRACE_SCHEMA_VERSION},
                           sort_keys=True, separators=(",", ":")) + "\n")
        for proc in tracer.procs:
            f.write(json.dumps({"proc": proc}, sort_keys=True,
                               separators=(",", ":")) + "\n")
        for ev in tracer.events:
            f.write(json.dumps(ev, sort_keys=True,
                               separators=(",", ":")) + "\n")


def load_trace(path: str) -> Dict[str, Any]:
    """Load a trace written by :func:`write_trace` or :func:`write_jsonl`
    back into ``{"procs": [...], "events": [...]}`` with timestamps in
    seconds — the form ``trace_report`` analyzes."""
    with open(path) as f:
        text = f.read()
    try:
        # One JSON document = the Chrome-trace form. JSONL falls through:
        # its extra lines make this raise.
        return _from_chrome(json.loads(text))
    except json.JSONDecodeError:
        pass
    procs: List[Dict[str, Any]] = []
    events: List[Dict[str, Any]] = []
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        obj = json.loads(line)
        if "proc" in obj:
            procs.append(obj["proc"])
        elif "ph" in obj:
            events.append(obj)
    return {"procs": procs, "events": events}


def _from_chrome(doc: Dict[str, Any]) -> Dict[str, Any]:
    procs: Dict[int, Dict[str, Any]] = {}
    events: List[Dict[str, Any]] = []
    for ev in doc.get("traceEvents", []):
        if ev.get("ph") == "M":
            if ev.get("name") == "process_name":
                # to_chrome renders "name [hardware]"; split it back.
                name, hardware = ev["args"]["name"], None
                if name.endswith("]") and " [" in name:
                    name, _, hw = name.rpartition(" [")
                    hardware = hw[:-1]
                procs[ev["pid"]] = {"pid": ev["pid"], "name": name,
                                    "hardware": hardware}
            continue
        out = dict(ev)
        out["ts"] = ev["ts"] / _US
        if "dur" in ev:
            out["dur"] = ev["dur"] / _US
        out.pop("s", None)
        events.append(out)
    return {"procs": [procs[k] for k in sorted(procs)], "events": events}
