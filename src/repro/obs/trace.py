"""Span/event tracer for the serving stack.

The tracer is the serving fleet's flight recorder: every engine attaches
as a *process* (a Perfetto process row) and records events on fixed
*lanes* (thread rows) against the **injected clock** — the same callable
the engine and its :class:`~repro.serve.metrics.ServeMetrics` run on, so
virtual-clock bench runs produce deterministic, byte-identical traces
while live runs trace wall time.

Event vocabulary (names are a stable contract with
``repro.launch.trace_report``):

- ``submit`` / ``admit`` / ``reject`` / ``first_token`` / ``finish`` —
  request-lifecycle instants on the lifecycle lane, plus one async
  ``req`` span per request (submit → finish) and one complete ``ttft``
  span whose ``ts`` is the submit time and whose ``dur`` is exactly the
  engine's recorded TTFT, so a trace reproduces
  ``ServeMetrics.ttft[...].percentile(0.95)`` by nearest-rank over span
  durations.
- ``step`` — one complete span per engine step. Under a virtual clock
  time only advances *between* steps, so step spans are **deferred**:
  step N's span closes when step N+1 begins (or at flush), giving each
  span the step's modeled duration instead of zero.
- ``chunk`` / ``prefill`` / ``decode`` — work spans. Packed prefill
  chunks land on per-segment pack lanes (``pack 0``, ``pack 1``, …) so
  pack membership is visible as parallel tracks.
- ``plan_resolve`` / ``plan_swap`` / ``shadow`` / ``roll`` / ``route`` —
  the plan-decision audit trail: which tile each kernel launch resolved
  to and from which source (exact / nearest_shape / cross_hardware /
  fallback…), live artifact swaps, shadow measurements, and
  ``roll_plans`` keep/revert decisions as instant events.
- ``queue_push`` / ``queue_pop`` / ``queue_depth`` — scheduler events
  and the backlog counter (sampled on admit/reject as well as inside
  steps, so idle-time backlog is visible).
- ``page_alloc`` / ``page_free`` / ``prefix_hit`` / ``cow_split`` /
  ``pool_occupancy`` — paged-KV-pool lifecycle instants on the pool
  lane (see ``repro.serve.pool``): page allocations and frees with the
  pool's running occupancy, shared-prefix reuse hits, and
  copy-on-write splits.
- ``fault`` / ``fault_detected`` / ``recover`` / ``recover_fail`` /
  ``drain_begin`` / ``drain_done`` / ``join`` / ``steal`` — the fleet
  fault-tolerance lane (``repro.serve.faults`` + ``FleetRouter``):
  scripted fault injections, watchdog/liveness detections with the
  instance's new status, per-request recovery decisions (source, target,
  retries, tokens discarded), graceful drain begin/done, elastic joins,
  and work-stealing moves.
- ``autoscale`` — one instant per autoscaler decision
  (``repro.serve.autoscale``) on the fleet lane: the join/drain action,
  the chosen instance/hardware, the triggering reason, and the full
  signal snapshot (queue depth, windowed p95 TTFT, pool occupancy,
  orphan count) the policy evaluated.

Zero-cost when disabled: components hold ``self._trace = None`` unless a
tracer was injected and guard every site with ``if self._trace is not
None`` — no tracer object, no event construction, no calls on the hot
path. All recording funnels through the single
:meth:`Tracer.record` chokepoint, which the guard test instruments.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Tuple

TRACE_SCHEMA_VERSION = 1

# Fixed lanes (Chrome-trace ``tid``s) within each process. Pack lanes —
# one per prefill segment slot — start at PACK_LANE_BASE.
LANE_LIFECYCLE = 0
LANE_STEPS = 1
LANE_DECODE = 2
LANE_PLAN = 3
LANE_SHADOW = 4
LANE_SCHED = 5
LANE_QUEUE = 6
LANE_POOL = 7
LANE_FLEET = 8
PACK_LANE_BASE = 9

LANE_NAMES = {
    LANE_LIFECYCLE: "lifecycle",
    LANE_STEPS: "steps",
    LANE_DECODE: "decode",
    LANE_PLAN: "plan audit",
    LANE_SHADOW: "shadow",
    LANE_SCHED: "scheduler",
    LANE_QUEUE: "queue depth",
    LANE_POOL: "kv pool",
    LANE_FLEET: "fleet",
}


def lane_name(tid: int) -> str:
    if tid >= PACK_LANE_BASE:
        return f"pack {tid - PACK_LANE_BASE}"
    return LANE_NAMES.get(tid, f"lane {tid}")


class Tracer:
    """Collects raw events (timestamps in clock seconds) across processes.

    ``clock`` is any zero-arg callable returning seconds; inject the same
    virtual clock the engines run on for deterministic traces.
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self.events: List[Dict[str, Any]] = []
        self.procs: List[Dict[str, Any]] = []
        # Deferred spans keyed by (pid, tid): emitted when the next span
        # on the same lane begins, or at flush().
        self._open: Dict[Tuple[int, int], Dict[str, Any]] = {}

    # -- processes ---------------------------------------------------------
    def attach(self, name: str, kind: str = "engine",
               hardware: Optional[str] = None) -> "ProcTrace":
        """Register a process (engine/router/…) and return its handle."""
        pid = len(self.procs) + 1
        self.procs.append(
            {"pid": pid, "name": name, "kind": kind, "hardware": hardware})
        return ProcTrace(self, pid)

    # -- recording chokepoint ---------------------------------------------
    def record(self, ph: str, name: str, cat: str, pid: int, tid: int,
               ts: float, dur: Optional[float] = None,
               args: Optional[Dict[str, Any]] = None) -> None:
        """Append one raw event. Every event passes through here — the
        zero-cost guard test instruments this single method."""
        ev: Dict[str, Any] = {
            "ph": ph, "name": name, "cat": cat,
            "pid": pid, "tid": tid, "ts": ts,
        }
        if dur is not None:
            ev["dur"] = dur
        if args is not None:
            ev["args"] = args
        self.events.append(ev)

    def defer(self, pid: int, tid: int, name: str, cat: str, ts: float,
              args: Optional[Dict[str, Any]] = None) -> None:
        """Open a span that closes when the lane's next defer/flush lands.

        Needed for step spans under virtual clocks: the clock advances
        between engine steps, so a span closed inside its own step would
        have zero duration; closing it at the next step's begin gives it
        the step's modeled cost.
        """
        key = (pid, tid)
        prev = self._open.pop(key, None)
        if prev is not None:
            self.record(
                "X", prev["name"], prev["cat"], pid, tid, prev["ts"],
                dur=max(ts - prev["ts"], 0.0), args=prev.get("args"))
        self._open[key] = {"name": name, "cat": cat, "ts": ts, "args": args}

    def flush(self) -> None:
        """Close all deferred spans at the current clock. Idempotent."""
        if not self._open:
            return
        now = self.clock()
        for (pid, tid), prev in sorted(self._open.items()):
            self.record(
                "X", prev["name"], prev["cat"], pid, tid, prev["ts"],
                dur=max(now - prev["ts"], 0.0), args=prev.get("args"))
        self._open.clear()


class ProcTrace:
    """Per-process handle: the event vocabulary components speak.

    Thin wrappers over :meth:`Tracer.record` that fix the event names,
    categories, and lanes so the engine/scheduler/fleet call sites stay
    one-liners and ``trace_report`` can rely on the schema.
    """

    __slots__ = ("tracer", "pid")

    def __init__(self, tracer: Tracer, pid: int):
        self.tracer = tracer
        self.pid = pid

    def now(self) -> float:
        return self.tracer.clock()

    # -- generic -----------------------------------------------------------
    def instant(self, tid: int, name: str, cat: str,
                args: Optional[Dict[str, Any]] = None) -> None:
        self.tracer.record(
            "i", name, cat, self.pid, tid, self.tracer.clock(), args=args)

    def span(self, tid: int, name: str, cat: str, ts: float, dur: float,
             args: Optional[Dict[str, Any]] = None) -> None:
        self.tracer.record("X", name, cat, self.pid, tid, ts, dur=dur,
                           args=args)

    def counter(self, name: str, value: float) -> None:
        self.tracer.record(
            "C", name, "counter", self.pid, LANE_QUEUE, self.tracer.clock(),
            args={"value": float(value)})

    # -- request lifecycle -------------------------------------------------
    def submit(self, rid: int, prompt_len: int, bucket: int) -> None:
        rid, bucket = int(rid), int(bucket)
        ts = self.tracer.clock()
        self.tracer.record(
            "i", "submit", "lifecycle", self.pid, LANE_LIFECYCLE, ts,
            args={"rid": rid, "prompt_len": prompt_len, "bucket": bucket})
        # Async request span: Perfetto groups b/e pairs by (cat, id, name)
        # into one sub-track per request.
        self.tracer.record(
            "b", "req", "request", self.pid, LANE_LIFECYCLE, ts,
            args={"rid": rid, "id": rid})

    def reject(self, reason: str, prompt_len: int) -> None:
        self.instant(LANE_LIFECYCLE, "reject", "lifecycle",
                     args={"reason": reason, "prompt_len": prompt_len})

    def admit(self, rid: int, prompt_len: int, wait_s: float) -> None:
        self.instant(LANE_LIFECYCLE, "admit", "lifecycle",
                     args={"rid": int(rid), "prompt_len": int(prompt_len),
                           "wait_s": float(wait_s)})

    def first_token(self, rid: int, bucket: int,
                    submit_t: Optional[float]) -> None:
        rid, bucket = int(rid), int(bucket)
        now = self.tracer.clock()
        self.instant(LANE_LIFECYCLE, "first_token", "lifecycle",
                     args={"rid": rid, "bucket": bucket})
        if submit_t is not None:
            # ts = submit, dur = TTFT: nearest-rank percentile over these
            # span durations reproduces ServeMetrics.ttft exactly.
            self.tracer.record(
                "X", "ttft", "lifecycle", self.pid, LANE_LIFECYCLE, submit_t,
                dur=max(now - submit_t, 0.0),
                args={"rid": rid, "bucket": bucket})

    def finish(self, rid: int, n_tokens: int) -> None:
        rid, n_tokens = int(rid), int(n_tokens)
        ts = self.tracer.clock()
        self.tracer.record(
            "i", "finish", "lifecycle", self.pid, LANE_LIFECYCLE, ts,
            args={"rid": rid, "tokens": n_tokens})
        self.tracer.record(
            "e", "req", "request", self.pid, LANE_LIFECYCLE, ts,
            args={"rid": rid, "id": rid})

    # -- engine work -------------------------------------------------------
    def step_mark(self, ts: float, stats: Dict[str, Any],
                  steps_run: int) -> None:
        """Begin step span at ``ts``; the previous step span closes here."""
        args = {"step": steps_run}
        args.update(stats)
        self.tracer.defer(self.pid, LANE_STEPS, "step", "engine", ts,
                          args=args)

    def chunk(self, rid: int, lane: int, ts: float, done: int, take: int,
              pack_n: int, queue_age_s: float) -> None:
        self.span(PACK_LANE_BASE + lane, "chunk", "prefill", ts,
                  max(self.tracer.clock() - ts, 0.0),
                  args={"rid": int(rid), "done": int(done),
                        "take": int(take), "pack_n": int(pack_n),
                        "queue_age_s": float(queue_age_s)})

    def prefill(self, rid: int, ts: float, length: int) -> None:
        self.span(PACK_LANE_BASE, "prefill", "prefill", ts,
                  max(self.tracer.clock() - ts, 0.0),
                  args={"rid": int(rid), "length": int(length)})

    def decode(self, ts: float, rids: List[int]) -> None:
        self.span(LANE_DECODE, "decode", "decode", ts,
                  max(self.tracer.clock() - ts, 0.0),
                  args={"batch": len(rids),
                        "rids": [int(r) for r in rids]})

    def queue_depth(self, depth: int) -> None:
        self.counter("queue_depth", depth)

    # -- paged KV pool -----------------------------------------------------
    def page_alloc(self, rid: int, n_pages: int, used: int,
                   total: int) -> None:
        self.instant(LANE_POOL, "page_alloc", "pool",
                     args={"rid": int(rid), "pages": int(n_pages),
                           "used": int(used), "total": int(total)})

    def page_free(self, rid: int, n_pages: int, used: int,
                  total: int) -> None:
        self.instant(LANE_POOL, "page_free", "pool",
                     args={"rid": int(rid), "pages": int(n_pages),
                           "used": int(used), "total": int(total)})

    def prefix_hit(self, rid: int, hit_tokens: int, n_pages: int) -> None:
        self.instant(LANE_POOL, "prefix_hit", "pool",
                     args={"rid": int(rid), "hit_tokens": int(hit_tokens),
                           "pages": int(n_pages)})

    def cow_split(self, rid: int, src: int, dst: int) -> None:
        self.instant(LANE_POOL, "cow_split", "pool",
                     args={"rid": int(rid), "src": int(src),
                           "dst": int(dst)})

    def pool_occupancy(self, used: int, total: int) -> None:
        self.instant(LANE_POOL, "pool_occupancy", "pool",
                     args={"used": int(used), "total": int(total)})

    # -- scheduler ---------------------------------------------------------
    def queue_push(self, rid: int, bucket: int) -> None:
        self.instant(LANE_SCHED, "queue_push", "scheduler",
                     args={"rid": int(rid), "bucket": int(bucket)})

    def queue_pop(self, rid: int, bucket: int) -> None:
        self.instant(LANE_SCHED, "queue_pop", "scheduler",
                     args={"rid": int(rid), "bucket": int(bucket)})

    # -- plan audit --------------------------------------------------------
    def plan_resolve(self, phase: str, kernel: str, problem: str, tile: Any,
                     source: str, schema: Optional[int]) -> None:
        self.instant(LANE_PLAN, "plan_resolve", "plan",
                     args={"phase": phase, "kernel": kernel,
                           "problem": problem, "tile": list(tile),
                           "source": source, "schema": schema})

    def plan_swap(self, schema: Optional[int],
                  refined_from: Optional[str]) -> None:
        self.instant(LANE_PLAN, "plan_swap", "plan",
                     args={"schema": schema, "refined_from": refined_from})

    def shadow(self, kernel: str, problem: str, incumbent: Any,
               candidate: Any, dt_inc: float, dt_cand: float) -> None:
        self.instant(LANE_SHADOW, "shadow", "plan",
                     args={"kernel": kernel, "problem": problem,
                           "incumbent": [int(x) for x in incumbent],
                           "candidate": [int(x) for x in candidate],
                           "dt_incumbent_s": float(dt_inc),
                           "dt_candidate_s": float(dt_cand)})

    # -- fleet -------------------------------------------------------------
    def route(self, rid: int, instance: str, bucket: int,
              score: float) -> None:
        self.instant(LANE_SCHED, "route", "fleet",
                     args={"rid": int(rid), "instance": instance,
                           "bucket": int(bucket), "score": float(score)})

    def route_reject(self, reason: str) -> None:
        self.instant(LANE_SCHED, "route_reject", "fleet",
                     args={"reason": reason})

    def roll(self, instance: str, pre_p95: Optional[float],
             post_p95: Optional[float], rolled_back: bool,
             clipped: bool) -> None:
        self.instant(LANE_PLAN, "roll", "fleet",
                     args={"instance": instance, "pre_p95": pre_p95,
                           "post_p95": post_p95, "rolled_back": rolled_back,
                           "clipped": clipped})

    # -- fleet fault tolerance ---------------------------------------------
    def fault(self, action: str, instance: str, step: int,
              factor: float = 1.0) -> None:
        self.instant(LANE_FLEET, "fault", "fleet",
                     args={"action": action, "instance": instance,
                           "step": int(step), "factor": float(factor)})

    def fault_detected(self, instance: str, status: str, via: str) -> None:
        """An instance was marked unhealthy: ``via`` is "liveness" (a dead
        engine failed its step) or "watchdog" (no progress past the
        threshold)."""
        self.instant(LANE_FLEET, "fault_detected", "fleet",
                     args={"instance": instance, "status": status,
                           "via": via})

    def recover(self, fid: int, src: str, dst: str, rid: int, retries: int,
                tokens_discarded: int) -> None:
        self.instant(LANE_FLEET, "recover", "fleet",
                     args={"fid": int(fid), "src": src, "dst": dst,
                           "rid": int(rid), "retries": int(retries),
                           "tokens_discarded": int(tokens_discarded)})

    def recover_fail(self, fid: int, reason: str, retries: int) -> None:
        self.instant(LANE_FLEET, "recover_fail", "fleet",
                     args={"fid": int(fid), "reason": reason,
                           "retries": int(retries)})

    def drain_begin(self, instance: str, handoff: int) -> None:
        self.instant(LANE_FLEET, "drain_begin", "fleet",
                     args={"instance": instance, "handoff": int(handoff)})

    def drain_done(self, instance: str) -> None:
        self.instant(LANE_FLEET, "drain_done", "fleet",
                     args={"instance": instance})

    def join(self, instance: str, hardware: Optional[str]) -> None:
        self.instant(LANE_FLEET, "join", "fleet",
                     args={"instance": instance, "hardware": hardware})

    def steal(self, fid: int, src: str, dst: str) -> None:
        self.instant(LANE_FLEET, "steal", "fleet",
                     args={"fid": int(fid), "src": src, "dst": dst})

    def autoscale(self, action: str, instance: str,
                  hardware: Optional[str], reason: str,
                  signals: Dict[str, float]) -> None:
        """One autoscaler decision (``repro.serve.autoscale``): the
        join/drain action plus the full telemetry snapshot that triggered
        it, so a trace alone explains WHY the fleet changed size."""
        self.instant(LANE_FLEET, "autoscale", "fleet",
                     args={"action": action, "instance": instance,
                           "hardware": hardware, "reason": reason,
                           "signals": {k: signals[k]
                                       for k in sorted(signals)}})

    def refine_cell(self, kernel: str, problem: str, old_tile: Any,
                    new_tile: Any, speedup: float, samples: int) -> None:
        self.instant(LANE_PLAN, "refine_cell", "plan",
                     args={"kernel": kernel, "problem": problem,
                           "old_tile": [int(x) for x in old_tile],
                           "new_tile": [int(x) for x in new_tile],
                           "speedup": float(speedup),
                           "samples": int(samples)})
