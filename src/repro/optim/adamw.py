"""AdamW with dtype policies, decoupled weight decay, and global-norm clip.

Pure pytree implementation (no optax dependency). Moments may be kept in
bf16 for memory-constrained configs (the 235B MoE at 256 chips); the update
math is always fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"   # "bfloat16" halves optimizer memory


def init_state(params, cfg: AdamWConfig) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)
    ))


def apply_updates(
    params, grads, state, cfg: AdamWConfig, lr: jnp.ndarray,
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return (
        new_p,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm, "clip_scale": scale},
    )
