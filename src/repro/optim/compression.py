"""Error-feedback int8 gradient compression for the DP all-reduce.

Distributed-optimization trick for scale-out: gradients are quantized to
int8 with a per-tensor scale before the data-parallel all-reduce and
dequantized after; the quantization residual is carried in an error-feedback
buffer so the compression is unbiased over time (1-bit-Adam-style EF).

Used inside a shard_map over the batch axes (see train/train_step.py with
``compress_grads=True``). 4x reduction of DP collective bytes at the cost of
one extra buffer of param size.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def init_error(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_psum(grads, error, axis_names) -> Tuple[Any, Any]:
    """Quantize (grad + error), psum int8 over ``axis_names``, dequantize.

    Returns (mean-reduced grads, new error buffers). Must run inside
    shard_map with ``axis_names`` bound.
    """
    n_dev = 1
    for ax in axis_names:
        n_dev = n_dev * jax.lax.axis_size(ax)

    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = _quantize(x)
        deq_local = q.astype(jnp.float32) * scale
        new_e = x - deq_local                       # residual kept locally
        # int8 payload summed in int32 to avoid overflow; scales averaged.
        summed = jax.lax.psum(q.astype(jnp.int32), axis_names)
        scale_sum = jax.lax.psum(scale, axis_names)
        deq = summed.astype(jnp.float32) * (scale_sum / n_dev)
        return (deq / n_dev).astype(g.dtype), new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        treedef.unflatten([o[0] for o in out]),
        treedef.unflatten([o[1] for o in out]),
    )
