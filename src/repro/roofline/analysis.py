"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), per the task spec:

    compute    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / (links x link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()`` (the per-chip SPMD
module). Collective bytes are parsed from the HLO text: every all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute contributes
its shape bytes x an op-specific wire multiplier (ring algorithms):
all-reduce 2x (reduce-scatter + all-gather phase), others 1x.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.core.hardware import HardwareModel

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
    "ragged-all-to-all": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of a possibly-tuple HLO shape string."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    bytes_by: Dict[str, float] = {}
    count_by: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        # e.g.:  [ROOT] %all-reduce.5 = bf16[8,4096]{1,0} all-reduce(...)
        m = re.match(
            r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^)=]*?\)?)\s+([\w-]+)\(",
            line,
        )
        if not m:
            continue
        op = m.group(2)
        # Strip "-start"/"-done" async suffixes; count only starts.
        base = op
        for suffix in ("-start",):
            if base.endswith(suffix):
                base = base[: -len(suffix)]
        if base not in _COLLECTIVES or op.endswith("-done"):
            continue
        size = _shape_bytes(m.group(1)) * _COLLECTIVES[base]
        bytes_by[base] = bytes_by.get(base, 0.0) + size
        count_by[base] = count_by.get(base, 0) + 1
    return CollectiveStats(bytes_by, count_by)


@dataclasses.dataclass
class RooflineTerms:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    peak_bytes_per_device: Optional[float] = None

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def total_s(self) -> float:
        # Optimistic (fully-overlapped) step time: max of the three.
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self) -> float:
        """compute_s / total_s — 1.0 means MXU-bound (at the roofline)."""
        return self.compute_s / self.total_s if self.total_s else 0.0


def analyze(compiled, hw: HardwareModel, hlo_text: Optional[str] = None,
            ici_links: Optional[int] = None) -> RooflineTerms:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collectives(text)
    links = ici_links if ici_links is not None else hw.ici_links
    mem_stats = None
    try:
        mem_stats = compiled.memory_analysis()
    except Exception:
        pass
    peak = None
    if mem_stats is not None:
        try:
            peak = float(
                mem_stats.temp_size_in_bytes
                + mem_stats.argument_size_in_bytes
                + mem_stats.output_size_in_bytes
            )
        except Exception:
            peak = None
    return RooflineTerms(
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=coll.total_bytes,
        compute_s=flops / hw.peak_flops_bf16,
        memory_s=hbm / hw.hbm_bw,
        collective_s=coll.total_bytes / (links * hw.ici_bw_per_link),
        peak_bytes_per_device=peak,
    )


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE), per step, global."""
    n = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def active_param_count(cfg) -> float:
    """Parameters touched per token (MoE counts top_k + shared experts)."""
    from repro.models import api as _api
    d, v = cfg.d_model, cfg.padded_vocab
    total = v * d * (1 if cfg.tie_embeddings else 2)
    for spec in cfg.layers():
        if spec.mixer in ("attn", "local_attn"):
            hd = cfg.head_dim_
            total += d * hd * (cfg.padded_heads * 2 + cfg.padded_kv_heads * 2)
        elif spec.mixer == "rglru":
            f = cfg.recurrent.lru_width or d
            total += 2 * d * f + 2 * f * f + f * d
        elif spec.mixer == "ssd":
            s = cfg.ssm
            di = s.d_inner(d)
            total += d * (2 * di + 2 * s.d_state + s.n_heads(d)) + di * d
        if spec.ff == "dense":
            total += 3 * d * cfg.d_ff
        elif spec.ff == "moe":
            m = cfg.moe
            total += 3 * d * m.d_expert * m.top_k + d * m.n_experts
            if m.n_shared_experts:
                total += 3 * d * (m.d_shared or m.n_shared_experts * m.d_expert)
    if cfg.encoder is not None and cfg.encoder.kind == "audio":
        hd = cfg.head_dim_
        enc_layer = d * hd * cfg.padded_heads * 4 + 2 * d * cfg.d_ff
        total += cfg.encoder.n_layers * enc_layer
        total += cfg.n_layers * d * hd * cfg.padded_heads * 4  # cross-attn
    return float(total)
