"""Serving subsystem: engine, shape-bucketed scheduler, fleet router,
runtime telemetry. See ``repro.serve.scheduler`` for the admission story."""
from repro.serve.engine import Request, ServeEngine
from repro.serve.fleet import FleetRouter, RouteDecision
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import (
    BucketPolicy,
    FifoScheduler,
    ShapeBucketScheduler,
    make_scheduler,
)

__all__ = [
    "Request", "ServeEngine", "FleetRouter", "RouteDecision", "ServeMetrics",
    "BucketPolicy", "FifoScheduler", "ShapeBucketScheduler", "make_scheduler",
]
