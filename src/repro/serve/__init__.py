"""Serving subsystem: engine, shape-bucketed scheduler, fleet router,
runtime telemetry, online plan refinement. See ``repro.serve.scheduler``
for the admission story and ``repro.serve.refine`` for the telemetry ->
plan feedback loop."""
from repro.serve.autoscale import (
    AutoscalePolicy,
    ScaleCandidate,
    ScaleDecision,
)
from repro.serve.engine import Request, ServeEngine
from repro.serve.faults import (
    EngineFault,
    FaultEvent,
    FaultInjector,
    FaultScript,
)
from repro.serve.fleet import (
    FleetExhausted,
    FleetRouter,
    RollDecision,
    RouteDecision,
)
from repro.serve.metrics import ServeMetrics
from repro.serve.pool import PagedKVPool, supports_prefix_sharing
from repro.serve.refine import PlanRefiner, drift_report, make_shadow_measure
from repro.serve.scheduler import (
    BucketPolicy,
    FifoScheduler,
    ShapeBucketScheduler,
    make_scheduler,
)

__all__ = [
    "AutoscalePolicy", "ScaleCandidate", "ScaleDecision",
    "Request", "ServeEngine", "FleetRouter", "RouteDecision", "RollDecision",
    "FleetExhausted", "EngineFault", "FaultEvent", "FaultInjector",
    "FaultScript",
    "ServeMetrics", "PagedKVPool", "supports_prefix_sharing",
    "PlanRefiner", "make_shadow_measure", "drift_report",
    "BucketPolicy", "FifoScheduler", "ShapeBucketScheduler", "make_scheduler",
]
