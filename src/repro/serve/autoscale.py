"""Telemetry-driven autoscaling: WHEN to join or drain fleet capacity.

PR 9 built the *mechanisms* — ``FleetRouter.drain`` / ``join`` retire and
add instances mid-run with zero request loss — but deciding when to use
them was left to hand-written fault scripts. This module closes the loop:
:class:`AutoscalePolicy` watches the signals the fleet already exports
(per-instance queue depth, windowed p95 TTFT, KV-pool occupancy, orphan
count) and emits deterministic scale decisions.

The paper's cross-model result is what makes the *which hardware* question
non-trivial: per-model tiles mean per-model cost, so the cheapest
instance to add depends on the current traffic mix, not on a static
hardware ranking. Each :class:`ScaleCandidate` carries a ``price``
(relative $/instance-step) and is scored as::

    price * sum_b mix[b] * service_score(candidate, b, avg_new_tokens)

— the plan-resolved service estimate for the *observed* bucket mix. A
compute-heavy mix (long prefills) and a memory-heavy mix (decode-token
heavy) therefore rank a high-FLOPs model and a high-bandwidth model
differently, and the policy joins different hardware for each: the
paper's per-model-optimum claim at fleet-capacity granularity.

Hysteresis so the fleet never flaps:

* decisions are evaluated every ``interval`` steps, never more often;
* any decision starts a ``cooldown`` (counted in evaluations) during
  which no further decision fires — a join must show up in the signals
  before the next one is considered;
* scale-down additionally requires ``low_evals`` *consecutive* low-load
  evaluations (the streak resets on any high signal);
* fleet size is clamped to ``[min_instances, max_instances]``.

The policy is deliberately engine-agnostic: it talks to any "fleet" that
implements the small adapter protocol below, which both the real
:class:`~repro.serve.fleet.FleetRouter` (virtual- or wall-clock engines)
and the million-request queueing simulator in
``benchmarks/bench_autoscale.py`` provide::

    live_instances() -> list[str]         # routable instance names
    known_instances() -> set[str]         # every name ever used
    instance_hardware(name) -> str|None
    queue_depths() -> dict[str, int]      # queued (not in-flight) work
    ttft_marks() -> mark                  # opaque cursor
    ttft_window_since(mark) -> (list[float], clipped)
    traffic_mix() -> (dict[bucket,int], new_tokens_sum, n)   # cumulative
    pool_occupancy() -> float             # max used/total over live, 0-1
    orphan_count() -> int
    price_instance(name, mix, avg_new_tokens) -> float   # s/request
    price_candidate(candidate, mix, avg_new_tokens) -> float
    scale_join(name, engine) -> None
    scale_drain(name) -> None
    record_autoscale(decision) -> None    # trace hook

Every emitted :class:`ScaleDecision` carries the full signal snapshot
that triggered it, lands in ``policy.decisions`` / ``as_dict()`` (the
``metrics()["autoscale"]`` block), and is traced on the fleet lane.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.serve.metrics import nearest_rank

AUTOSCALE_SCHEMA_VERSION = 1

#: Scale-up triggers in priority order (first matching wins; the reason
#: string lands on the decision and in the trace event).
UP_REASONS = ("orphans", "p95_ttft", "queue_depth", "pool_occupancy")


@dataclasses.dataclass(frozen=True)
class ScaleCandidate:
    """One hardware model the policy may join capacity from.

    ``make_engine(name)`` builds a fresh instance (a ``ServeEngine`` for
    the real fleet; any adapter-compatible object for a simulator) — a
    NEW engine per join, never shared. ``price`` is the relative cost of
    keeping one such instance running for one step; the policy minimizes
    ``price x mix-weighted service seconds``, so an expensive fast model
    wins only when the traffic mix actually exploits its strength.
    """

    name: str
    hardware: str
    make_engine: Callable[[str], Any]
    price: float = 1.0

    def __post_init__(self):
        if self.price <= 0:
            raise ValueError(f"candidate {self.name!r}: price must be > 0")


@dataclasses.dataclass(frozen=True)
class ScaleDecision:
    """One autoscale action plus the telemetry snapshot that triggered it."""

    step: int
    action: str                       # "join" | "drain"
    instance: str
    hardware: Optional[str]
    reason: str                       # UP_REASONS entry or "low_load"
    signals: Dict[str, float]

    def as_dict(self) -> Dict[str, Any]:
        return {
            "step": self.step, "action": self.action,
            "instance": self.instance, "hardware": self.hardware,
            "reason": self.reason,
            "signals": {k: self.signals[k] for k in sorted(self.signals)},
        }


class AutoscalePolicy:
    """Deterministic join/drain decisions from fleet telemetry."""

    def __init__(self, candidates=(), *,
                 min_instances: int = 1, max_instances: int = 4,
                 interval: int = 8, cooldown: int = 2,
                 queue_high: float = 8.0, queue_low: float = 1.0,
                 ttft_high: Optional[float] = None,
                 ttft_low: Optional[float] = None,
                 pool_high: float = 0.9,
                 low_evals: int = 3, min_ttft_samples: int = 4,
                 instance_prices: Optional[Dict[str, float]] = None):
        self.candidates: Tuple[ScaleCandidate, ...] = tuple(candidates)
        names = [c.name for c in self.candidates]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate candidate names: {names}")
        if min_instances < 1:
            raise ValueError("min_instances must be >= 1")
        if max_instances < min_instances:
            raise ValueError("max_instances must be >= min_instances")
        if interval < 1:
            raise ValueError("interval must be >= 1")
        if cooldown < 0:
            raise ValueError("cooldown must be >= 0")
        if low_evals < 1:
            raise ValueError("low_evals must be >= 1")
        if queue_low > queue_high:
            raise ValueError("queue_low must be <= queue_high")
        if (ttft_high is not None and ttft_low is not None
                and ttft_low > ttft_high):
            raise ValueError("ttft_low must be <= ttft_high")
        self.min_instances = min_instances
        self.max_instances = max_instances
        self.interval = interval
        self.cooldown = cooldown
        self.queue_high = queue_high
        self.queue_low = queue_low
        self.ttft_high = ttft_high
        self.ttft_low = ttft_low
        self.pool_high = pool_high
        self.low_evals = low_evals
        self.min_ttft_samples = min_ttft_samples
        # Per-member price (for drain-victim costing). Members joined by
        # this policy inherit their candidate's price; pre-existing fleet
        # members default to 1.0 unless listed here.
        self.instance_price: Dict[str, float] = dict(instance_prices or {})
        self.decisions: List[ScaleDecision] = []
        self._last_eval: Optional[int] = None
        self._evals = 0
        self._cooldown_left = 0
        self._low_streak = 0
        self._ttft_mark = None
        self._mix_mark: Tuple[Dict[Any, int], int, int] = ({}, 0, 0)

    # -- signal assembly ---------------------------------------------------
    def _signals(self, fleet, step: int) -> Tuple[Dict[str, float],
                                                  Dict[Any, int], float]:
        """Snapshot the fleet's telemetry for one evaluation.

        Returns ``(signals, window_mix, avg_new_tokens)`` where the mix is
        the bucket histogram of arrivals since the previous evaluation
        (falling back to the cumulative mix when the window is empty, so
        pricing keeps working through idle stretches)."""
        live = sorted(fleet.live_instances())
        depths = fleet.queue_depths()
        queued = sum(int(depths.get(n, 0)) for n in live)
        samples, clipped = fleet.ttft_window_since(self._ttft_mark)
        self._ttft_mark = fleet.ttft_marks()
        p95 = nearest_rank(samples, 0.95) if samples else 0.0
        mix_total, nt_sum, nt_n = fleet.traffic_mix()
        prev_mix, prev_sum, prev_n = self._mix_mark
        window_mix = {b: c - prev_mix.get(b, 0)
                      for b, c in mix_total.items()
                      if c - prev_mix.get(b, 0) > 0}
        win_n = nt_n - prev_n
        avg_new = ((nt_sum - prev_sum) / win_n if win_n > 0
                   else nt_sum / nt_n if nt_n > 0 else 16.0)
        self._mix_mark = (dict(mix_total), nt_sum, nt_n)
        if not window_mix:
            window_mix = dict(mix_total)
        signals = {
            "step": int(step),
            "instances": len(live),
            "queue_depth": queued,
            "queue_per_instance": queued / len(live) if live else float(queued),
            "p95_ttft": float(p95),
            "ttft_samples": len(samples),
            "ttft_clipped": int(bool(clipped)),
            "pool_occupancy": float(fleet.pool_occupancy()),
            "orphans": int(fleet.orphan_count()),
            "arrivals": int(win_n) if win_n > 0 else 0,
        }
        return signals, window_mix, avg_new

    def _up_reason(self, sig: Dict[str, float]) -> Optional[str]:
        if sig["orphans"] > 0:
            return "orphans"
        if (self.ttft_high is not None
                and sig["ttft_samples"] >= self.min_ttft_samples
                and sig["p95_ttft"] > self.ttft_high):
            return "p95_ttft"
        if sig["queue_per_instance"] > self.queue_high:
            return "queue_depth"
        if sig["pool_occupancy"] > self.pool_high:
            return "pool_occupancy"
        return None

    def _is_low(self, sig: Dict[str, float]) -> bool:
        return (sig["orphans"] == 0
                and sig["queue_per_instance"] <= self.queue_low
                and sig["pool_occupancy"] <= self.pool_high
                and (self.ttft_low is None
                     or sig["ttft_samples"] == 0
                     or sig["p95_ttft"] <= self.ttft_low))

    def _join_name(self, fleet, cand: ScaleCandidate) -> str:
        known = set(fleet.known_instances())
        name, k = cand.name, 1
        while name in known:
            k += 1
            name = f"{cand.name}{k}"
        return name

    # -- decision loop -----------------------------------------------------
    def observe(self, fleet, step: int) -> List[ScaleDecision]:
        """Evaluate the fleet at ``step``; apply and return any decision.

        Called by ``FleetRouter.step_all`` (behind ``autoscaler=``) after
        orphan recovery / stealing / drain completion, so signals reflect
        the post-recovery state of this step."""
        if (self._last_eval is not None
                and step - self._last_eval < self.interval):
            return []
        self._last_eval = step
        self._evals += 1
        sig, mix, avg_new = self._signals(fleet, step)
        live = sorted(fleet.live_instances())
        reason = self._up_reason(sig)
        if reason is not None:
            # High load resets the scale-down streak even during cooldown:
            # evidence of load is evidence against draining.
            self._low_streak = 0
        elif self._is_low(sig):
            self._low_streak += 1
        else:
            self._low_streak = 0
        if self._cooldown_left > 0:
            self._cooldown_left -= 1
            return []
        nt = max(1, int(round(avg_new)))
        if (reason is not None and self.candidates
                and len(live) < self.max_instances):
            cand = min(
                self.candidates,
                key=lambda c: (c.price * fleet.price_candidate(c, mix, nt),
                               c.name))
            name = self._join_name(fleet, cand)
            decision = ScaleDecision(
                step=step, action="join", instance=name,
                hardware=cand.hardware, reason=reason, signals=sig)
            fleet.record_autoscale(decision)
            fleet.scale_join(name, cand.make_engine(name))
            self.instance_price[name] = cand.price
            self.decisions.append(decision)
            self._cooldown_left = self.cooldown
            return [decision]
        if (self._low_streak >= self.low_evals
                and len(live) > self.min_instances):
            # Drain the member whose removal is cheapest: the one with the
            # WORST cost-effectiveness (price x per-request seconds) for
            # the current mix — losing it costs the least capacity per $.
            victim = max(
                live,
                key=lambda n: (self.instance_price.get(n, 1.0)
                               * fleet.price_instance(n, mix, nt), n))
            decision = ScaleDecision(
                step=step, action="drain", instance=victim,
                hardware=fleet.instance_hardware(victim),
                reason="low_load", signals=sig)
            fleet.record_autoscale(decision)
            fleet.scale_drain(victim)
            self.decisions.append(decision)
            self._low_streak = 0
            self._cooldown_left = self.cooldown
            return [decision]
        return []

    # -- export ------------------------------------------------------------
    def as_dict(self) -> Dict[str, Any]:
        """The ``metrics()["autoscale"]`` block: deterministic, JSON-clean."""
        return {
            "schema_version": AUTOSCALE_SCHEMA_VERSION,
            "evaluations": self._evals,
            "joins": sum(d.action == "join" for d in self.decisions),
            "drains": sum(d.action == "drain" for d in self.decisions),
            "cooldown_left": self._cooldown_left,
            "low_streak": self._low_streak,
            "candidates": [
                {"name": c.name, "hardware": c.hardware, "price": c.price}
                for c in self.candidates],
            "log": [d.as_dict() for d in self.decisions],
        }
