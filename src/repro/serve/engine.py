"""Batched serving engine: slot-based continuous batching (lite).

Fixed decode batch of ``slots``; requests occupy free slots, prefill runs
per request (left-padded into the shared cache), decode advances all active
slots in one jitted step. Greedy sampling. This is the serving analogue of
the train loop — the decode step is the unit the decode_* dry-run shapes
lower.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import api


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, max_len: int = 512,
                 slots: int = 4, dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.slots = slots
        self.dtype = dtype
        self._active: List[Optional[Request]] = [None] * slots
        self._queue: List[Request] = []
        self._finished: List[Request] = []
        self._next_rid = 0

        # Per-slot independent caches (batch=1) batched by stacking.
        self._states = [None] * slots

        self._decode = jax.jit(
            lambda p, tok, st: api.decode_step(p, cfg, tok, st)
        )
        self._prefill = jax.jit(
            lambda p, batch: api.prefill(
                p, cfg, batch, max_len=max_len, dtype=dtype,
                ring_local=bool(cfg.attn_window))
        )

    def add_request(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid, np.asarray(prompt, np.int32),
                                   max_new_tokens))
        return rid

    def _admit(self):
        for i in range(self.slots):
            if self._active[i] is None and self._queue:
                req = self._queue.pop(0)
                batch = {"tokens": jnp.asarray(req.prompt[None])}
                logits, state = self._prefill(self.params, batch)
                tok = int(jnp.argmax(logits[0, :self.cfg.vocab_size]))
                req.out_tokens.append(tok)
                self._active[i] = req
                self._states[i] = state

    def step(self) -> int:
        """Admit + one decode step for all active slots. Returns #active."""
        self._admit()
        n = 0
        for i, req in enumerate(self._active):
            if req is None:
                continue
            n += 1
            last = jnp.asarray([[req.out_tokens[-1]]], jnp.int32)
            logits, self._states[i] = self._decode(
                self.params, last, self._states[i])
            tok = int(jnp.argmax(logits[0, :self.cfg.vocab_size]))
            req.out_tokens.append(tok)
            if len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                self._active[i] = None
                self._states[i] = None
                self._finished.append(req)
        return n

    def run_until_done(self, max_steps: int = 1000) -> List[Request]:
        self._finished = []
        for _ in range(max_steps):
            if not any(self._active) and not self._queue:
                break
            self.step()
        return self._finished
