"""Batched serving engine: slot-based continuous batching (lite).

Fixed decode batch of ``slots``; requests occupy free slots, prefill runs
per request (left-padded into the shared cache), decode advances all active
slots in one jitted step. Greedy sampling. This is the serving analogue of
the train loop — the decode step is the unit the decode_* dry-run shapes
lower.

Chunked prefill (``chunk_prefill=True``): instead of one monolithic prefill
per admitted request, the engine splits each prompt into plan-sized chunks
and builds **mixed steps** — one prefill chunk co-scheduled with the whole
pending decode batch under ``step_token_budget`` tokens per step. The chunk
length comes from the AOT plan's ``chunked_prefill`` cell for the admitted
bucket (VMEM bounds the resident chunk per hardware model, so different
models prefill the same prompt in different chunk sizes), clamped so chunk
+ decode batch always fits the budget. Up to ``prefill_slots`` requests
hold partially-built caches concurrently and the next chunk goes to the
most urgent one (priority, deadline, then fewest remaining tokens — so a
short prompt admitted behind a 32k prompt produces its first token after
one chunk-time, not after the whole 32k prefill). Chunk N's program closes
over its static start offset and replays the existing q_offset
continuation math in kernels/flash_attention, so chunked and whole-prompt
prefill match position by position (tests/test_serve_chunked.py).

Step packing (``pack_prefill=True``, implies chunked prefill): instead of
ONE chunk per mixed step, the engine packs MULTIPLE in-flight prefills'
chunks — segment-concatenated into a single kernel launch with per-segment
``q_offset``/``kv_pos`` masking (``api.prefill_packed``) — plus the decode
batch, under the same ``step_token_budget``. The pack is chosen by the
scheduler's knapsack (:func:`~repro.serve.scheduler.pick_chunks`): the
SRPT/aging head always runs (progress guarantee), then further whole
chunks greedily fill ``min(step budget - slots, pack width)``, where the
PACK WIDTH is the plan's ``packed_prefill`` tile — VMEM-bounded per
hardware model, so v5e and v6e pack different numbers of chunk tokens per
step for the same bucket set. Per request the math is unchanged (token
parity with one-chunk-per-step and unchunked service is pinned by
``tests/test_serve_packing.py``); only the schedule gets denser.

Paged KV pool (``paged=True``): per-request caches are replaced by ONE
engine-wide page pool (``repro.serve.pool.PagedKVPool``) — attention K/V
live in shared ``[n_pages, Hkv, page, D]`` arrays, each request holds a
page table, and pages are refcount-alloc'd as chunks are written / freed
at completion. The page size is the plan's ``kv_page`` cell (VMEM-bounded
per hardware model, like every other tile in this repo), admission is
pool-headroom reservation accounting instead of slot counting — so the
number of concurrently resident prefills is no longer capped at
``prefill_slots`` — and identical prompt prefixes prefill ONCE, with
copy-on-write splits at the first divergent write. Every prefill goes
through the chunk path (a whole prompt is one big chunk when chunking is
off), decode indirects reads/writes through the page table, and the token
stream is bit-identical with the per-request-cache engine
(tests/test_serve_paged.py pins this differentially per trace family).

Admission is delegated to a scheduler (``repro.serve.scheduler``): the
default :class:`~repro.serve.scheduler.FifoScheduler` preserves the naive
raw-shape behavior; a :class:`~repro.serve.scheduler.ShapeBucketScheduler`
pads prompts to the plan's shape family so every prefill lands on an
exactly-resolved plan cell (and a warm jit cache entry) instead of an
arbitrary shape that silently falls back to heuristics.

Tile selection: pass a compiled :class:`~repro.core.plans.TilePlan` (and the
target :class:`~repro.core.HardwareModel`) and the engine resolves every
decode-path kernel tile at construction time — exact hit, nearest shape, or
cross-hardware transfer — without ever invoking an autotuner sweep on the
request path. Prefill tiles are resolved per admitted shape (cached per
length) and threaded into the model's kernel call sites. Cells the plan
cannot resolve fall back to the zero-cost heuristic default tile, never to
a sweep. Every resolution is counted in ``self.metrics`` (plan hit /
transfer / fallback counters, TTFT/TPOT, queue depth).

Tracing: pass ``tracer=`` (a :class:`repro.obs.trace.Tracer`) and the
engine records the full causal timeline on its injected clock — request
lifecycle (submit → admit/reject → chunks with pack membership and queue
age → first token → decode → finish), per-step spans, and plan-resolution
audit instants. With no tracer (the default) every site short-circuits on
``self._trace is None``: zero allocations, zero calls.
"""
from __future__ import annotations

import dataclasses
import math
import time
import warnings
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.hardware import PRODUCTION_TARGET, HardwareModel
from repro.core.plans import (PLAN_SCHEMA_VERSION, PlanResolution,
                              PlanTransferWarning, TilePlan, problem_key)
from repro.core.tiling import TileShape, cdiv
from repro.models import api
from repro.models import attention as attn_mod
from repro.serve.metrics import ServeMetrics
from repro.serve.pool import PagedKVPool
from repro.serve.scheduler import FifoScheduler


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 16
    priority: int = 0           # lower = more urgent
    deadline: float = math.inf  # absolute, scheduler-clock units
    bucket: Optional[int] = None  # padded length (set at submit)
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    submit_t: Optional[float] = None  # original TTFT anchor (set on eviction)


@dataclasses.dataclass
class _ChunkJob:
    """One request's in-flight chunked prefill (chunk-resumable state)."""

    req: Request
    prompt: np.ndarray            # padded to the admitted length
    chunk_len: int
    state: Any = None             # serve caches, built chunk by chunk
    done: int = 0                 # prompt tokens prefilled so far
    chunks_run: int = 0
    packed_runs: int = 0          # chunks that rode a multi-segment pack
    last_t: float = 0.0           # last prefill progress (chunk queue age)
    # Trace-time tile events from every chunk program this request ran,
    # deduped once at prefill completion so an N-chunk prefill counts each
    # distinct fallback once — not N times (see _finish_prefill).
    events: List[Dict[str, Any]] = dataclasses.field(default_factory=list)

    @property
    def remaining(self) -> int:
        return len(self.prompt) - self.done


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, max_len: int = 512,
                 slots: int = 4, dtype=jnp.float32,
                 plans: Optional[TilePlan] = None,
                 hardware: Optional[HardwareModel] = None,
                 scheduler=None,
                 metrics: Optional[ServeMetrics] = None,
                 clock: Callable[[], float] = time.perf_counter,
                 chunk_prefill: bool = False,
                 step_token_budget: int = 0,
                 prefill_slots: int = 2,
                 pack_prefill: bool = False,
                 shadow_fraction: float = 0.0,
                 shadow_measure=None,
                 refiner=None,
                 tracer=None,
                 instance: Optional[str] = None,
                 paged: bool = False,
                 pool_pages: Optional[int] = None,
                 page_size: Optional[int] = None,
                 prefix_sharing: bool = True):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.slots = slots
        self.dtype = dtype
        self.hardware = hardware or PRODUCTION_TARGET
        self.plans = plans
        self.scheduler = scheduler or FifoScheduler()
        self.metrics = metrics or ServeMetrics(clock=clock)
        self._clock = clock
        # Request-lifecycle / plan-audit tracing (repro.obs.trace). None by
        # default and every call site is guarded with
        # ``if self._trace is not None`` — disabled tracing adds zero
        # object construction and zero calls on the step hot path.
        self._trace = None
        self._plan_schema: Optional[int] = None
        if tracer is not None:
            self._trace = tracer.attach(instance or "engine", kind="engine",
                                        hardware=self.hardware.name)
            bind = getattr(self.scheduler, "bind_trace", None)
            if bind is not None:
                bind(self._trace)
        # Chunked-prefill configuration. ``step_token_budget`` bounds one
        # mixed step's tokens (decode batch + one prefill chunk); 0 = no
        # bound, the plan's chunk length runs unclamped. ``prefill_slots``
        # bounds how many partially-prefilled caches are held at once (the
        # concurrency that lets a short prompt overtake a long one).
        # ``pack_prefill`` packs several chunks per step (implies chunking).
        self.pack_prefill = pack_prefill
        # Paged mode reuses the chunk-program machinery for every prefill
        # (a whole prompt is one big chunk when chunking is off — see
        # _chunk_plan), so the paged engine has ONE prefill path to keep
        # token-identical with the per-request-cache engine.
        self.paged = paged
        self._paged_whole = paged and not (chunk_prefill or pack_prefill)
        self.chunk_prefill = chunk_prefill or pack_prefill or paged
        self.step_token_budget = step_token_budget
        self.prefill_slots = max(1, prefill_slots)
        self._chunking: List[_ChunkJob] = []
        # Paged admission: requests the pool cannot reserve pages for yet
        # (FIFO — the head gets first claim on freed pages).
        self._pool_wait: List[Any] = []
        # rid -> next cache write position for pool-backed decodes.
        self._pos: Dict[int, int] = {}
        self._ready: List[Any] = []   # (Request, state) done prefilling,
        #                               waiting for a free decode slot
        self._held: List[Request] = []  # multi-chunk requests deferred while
        #                                 another multi-chunk prefill runs
        #                                 (FIFO schedulers only; see
        #                                 _next_admission)
        self._single_chunk_edge: Optional[int] = None  # lazy, per engine
        self._chunk_ticks = 0  # aging counter for _next_chunk_job
        self._chunk_plans: Dict[int, Any] = {}      # admit_len -> plan tuple
        self._chunk_fns: Dict[Any, Any] = {}        # (admit_len, start) -> fn
        self._chunk_tile_events: Dict[Any, List[Dict[str, Any]]] = {}
        # Step packing: the plan-resolved pack width + tiles (lazy, per
        # engine), one jitted packed program per static segment layout.
        # Unlike _chunk_fns (whose (admit_len, start) key space is linear
        # in buckets x chunks), layouts are cross-products of per-segment
        # offsets — the cache is LRU-bounded so a long-running server
        # cannot accrete compiled programs without limit.
        self._pack_plan_cache: Optional[Any] = None
        self._pack_fns: Dict[Any, Any] = {}         # layout -> fn
        self._pack_tile_events: Dict[Any, List[Dict[str, Any]]] = {}
        # Per-step mixed-token accounting (virtual-clock drivers read this).
        # ``packed_chunks``/``packed_rids`` describe the step's prefill pack
        # (conformance tests and the bench histogram read them).
        self.last_step_stats: Dict[str, Any] = {"prefill_tokens": 0,
                                                "decode_tokens": 0,
                                                "packed_chunks": 0,
                                                "packed_rids": (),
                                                "prefill_segments": ()}
        # Shadow execution (repro.serve.refine): divert a deterministic
        # fraction of steps to measuring one candidate tile from the plan's
        # sensitivity curve next to the incumbent. Counter-based sampling
        # (fractional accumulator), so tests and CI see the exact same
        # shadow schedule every run — no wall-clock randomness. Shadowing
        # is measurement-only: it never touches the serving math.
        self.shadow_fraction = float(shadow_fraction)
        if not 0.0 <= self.shadow_fraction <= 1.0:
            raise ValueError(
                f"shadow_fraction must be in [0, 1]: {shadow_fraction}")
        self.refiner = refiner
        self._shadow_measure = shadow_measure
        self._shadow_acc = 0.0
        self._shadow_rr = 0                       # round-robin cell cursor
        self._shadow_idx: Dict[str, int] = {}     # cell -> candidate cursor
        # cell key -> (kernel, problem): every plan cell this engine has
        # resolved so far — the shadow candidates' universe.
        self._shadow_cell_map: Dict[str, Any] = {}
        self._shadow_order: List[str] = []
        # cell key -> (incumbent dims, candidate dims tuple) | None.
        self._shadow_views: Dict[str, Any] = {}
        self.steps_run = 0
        # kernel name -> resolved tile for the decode path; populated from
        # the AOT plan at init so serving never pays a sweep.
        self.tiles: Dict[str, TileShape] = {}
        self.tile_resolutions: Dict[str, PlanResolution] = {}
        if plans is not None:
            self._resolve_tiles(plans)
        self._active: List[Optional[Request]] = [None] * slots
        self._finished: List[Request] = []
        self._next_rid = 0
        # Why the most recent add_request returned None ("ok" = it didn't);
        # the fleet router's failover path reads this after a rejection.
        self.last_reject_reason = "ok"

        # Per-slot independent caches (batch=1) batched by stacking.
        self._states = [None] * slots

        # Paged KV pool: page geometry comes from the plan's ``kv_page``
        # cell (VMEM-bounded per hardware model — v5e and v6e resolve
        # different page sizes for the same cache length), overridable with
        # ``page_size``. Default capacity matches what the per-request
        # engine would reserve for every decode + prefill slot, plus the
        # pool's copy-on-write slack — so paged mode never fits FEWER
        # requests, and fits many more whenever prompts only partially
        # fill their reservations.
        self.pool: Optional[PagedKVPool] = None
        if paged:
            kv_tile = self.tiles.get("kv_page")
            page = int(page_size if page_size is not None
                       else kv_tile[0] if kv_tile is not None
                       else min(512, max_len))
            n_pages = pool_pages if pool_pages is not None else (
                (slots + self.prefill_slots)
                * (cdiv(max_len, page) + PagedKVPool.RESERVE_SLACK))
            self.pool = PagedKVPool(
                cfg, n_pages=n_pages, page=page, max_len=max_len,
                dtype=dtype, prefix_sharing=prefix_sharing,
                metrics=self.metrics, trace=self._trace)

        self._decode = jax.jit(
            lambda p, tok, st: api.decode_step(p, cfg, tok, st,
                                               tiles=self.tiles or None)
        )
        self._decode_paged = jax.jit(
            lambda p, tok, st, arrays, table: api.decode_step_paged(
                p, cfg, tok, st, arrays, table, tiles=self.tiles or None)
        )
        # Prefill programs are built per admitted length so each shape
        # family gets its own exactly-resolved tiles (see _prefill_fn).
        self._prefill_fns: Dict[int, Any] = {}
        self._prefill_sources: Dict[int, Dict[str, str]] = {}
        # Tile-dispatch events fire once per jit trace; cache them per
        # length and replay per admitted request so tile_fallback counts in
        # the same unit as the per-request plan-source counters above. The
        # decode program's (deduped) events record once per engine — the
        # same unit as its per-engine plan-source counts from
        # ``_resolve_tiles``. None = decode not yet traced.
        self._prefill_tile_events: Dict[int, List[Dict[str, Any]]] = {}
        self._decode_tile_events: Optional[List[Dict[str, Any]]] = None

    @staticmethod
    def _dedupe_events(events: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
        """Drop retrace duplicates (eval_shape / checkpoint passes and
        identical per-layer call sites re-emit the same event)."""
        seen, out = set(), []
        for ev in events:
            key = tuple(sorted((k, str(v)) for k, v in ev.items()))
            if key not in seen:
                seen.add(key)
                out.append(ev)
        return out

    def _record_tile_event(self, event: Dict[str, Any]) -> None:
        """Trace-time tile-dispatch events -> plan counters.

        A ``fallback`` event means a resolved plan tile did NOT legally
        apply at the call site (clamped to a non-dividing block, or a
        Pallas-eligible tile degraded to the reference lowering); counting
        it as ``tile_fallback`` makes ``plan_hit_rate`` reflect the tiles
        the compiled programs actually consumed, not just the plan-store
        lookups.
        """
        if event.get("fallback"):
            self.metrics.record_plan(event["phase"], event["kernel"],
                                     "tile_fallback")

    def _resolve_tiles(self, plans: TilePlan) -> None:
        """Resolve decode-path kernel tiles from the plan store. No sweeps."""
        from repro.launch.specs import kernel_problems, resolve_model_tiles

        self._plan_schema = int(plans.meta.get(
            "schema_version", PLAN_SCHEMA_VERSION))
        self.tiles, self.tile_resolutions = resolve_model_tiles(
            plans, self.cfg, self.slots, self.max_len, "decode",
            jnp.dtype(self.dtype).name, self.hardware)
        problems = kernel_problems(self.cfg, self.slots, self.max_len,
                                   "decode")
        for kernel in self.tiles:
            res = self.tile_resolutions.get(kernel)
            source = res.source if res else "fallback"
            self.metrics.record_plan("decode", kernel, source)
            if self._trace is not None:
                self._trace.plan_resolve(
                    "decode", kernel, problem_key(problems.get(kernel, {})),
                    tuple(self.tiles[kernel].dims), source,
                    self._plan_schema)
        self._note_shadow_cells(problems)

    def _trace_plan_table(self, phase: str, tiles, sources, problems) -> None:
        """Emit one ``plan_resolve`` audit instant per kernel: which tile
        each launch resolved to, from which source, under which artifact
        schema. Call sites fire once per resolution (per length / geometry),
        mirroring when the plan store was actually consulted."""
        for kernel in sorted(sources):
            tile = tiles.get(kernel)
            self._trace.plan_resolve(
                phase, kernel, problem_key(problems.get(kernel) or {}),
                tuple(tile.dims) if tile is not None else (),
                sources[kernel], self._plan_schema)

    # -- live plan refinement ------------------------------------------------
    def _note_shadow_cells(self, problems: Dict[str, Dict[str, int]]) -> None:
        """Register plan cells this engine resolved as shadow targets."""
        from repro.core.plans import problem_key

        for kernel, problem in problems.items():
            key = f"{kernel}|{problem_key(problem)}"
            if key not in self._shadow_cell_map:
                self._shadow_cell_map[key] = (kernel, dict(problem))
                self._shadow_order.append(key)

    def _shadow_view(self, key: str):
        """(incumbent dims, candidate dims tuple) for one cell, or None.

        The incumbent is the plan-resolved serving tile; the candidates are
        every other tile on the resolved entry's stored sensitivity curve —
        the ranking the paper says cannot be trusted once hardware or
        conditions change, which is exactly why shadow steps re-measure it.
        """
        if key in self._shadow_views:
            return self._shadow_views[key]
        kernel, problem = self._shadow_cell_map[key]
        view = None
        if self.plans is not None:
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", PlanTransferWarning)
                res = self.plans.resolve(kernel, problem,
                                         jnp.dtype(self.dtype).name,
                                         self.hardware)
            if res is not None:
                inc = tuple(int(x) for x in res.tile.dims)
                cands, seen = [], {inc}
                for dims, _score in res.entry.curve:
                    dims = tuple(int(x) for x in dims)
                    if dims not in seen:
                        seen.add(dims)
                        cands.append(dims)
                if cands:
                    view = (inc, tuple(cands))
        self._shadow_views[key] = view
        return view

    def _shadow_measure_fn(self):
        if self._shadow_measure is None:
            from repro.serve.refine import make_shadow_measure

            self._shadow_measure = make_shadow_measure(self.hardware)
        return self._shadow_measure

    def _maybe_shadow(self) -> None:
        """Divert this step to shadow measurement when the deterministic
        fractional accumulator crosses 1: measure ONE candidate tile (and
        the incumbent, for a like-for-like baseline) for the next cell in
        round-robin order, record both, and feed the refiner. Serving state
        is untouched — tokens are identical with shadowing on or off."""
        if not self.shadow_fraction or self.plans is None:
            return
        self._shadow_acc += self.shadow_fraction
        if self._shadow_acc < 1.0:
            return
        self._shadow_acc -= 1.0
        if not self._shadow_order:
            return
        measure = self._shadow_measure_fn()
        dtype = jnp.dtype(self.dtype).name
        for _ in range(len(self._shadow_order)):
            key = self._shadow_order[self._shadow_rr
                                     % len(self._shadow_order)]
            self._shadow_rr += 1
            view = self._shadow_view(key)
            if view is None:
                continue
            inc, cands = view
            kernel, problem = self._shadow_cell_map[key]
            idx = self._shadow_idx.get(key, 0)
            self._shadow_idx[key] = idx + 1
            cand = cands[idx % len(cands)]
            dt_inc = float(measure(kernel, problem, dtype, inc))
            dt_cand = float(measure(kernel, problem, dtype, cand))
            self.metrics.record_shadow(kernel, inc, dt_inc, incumbent=True)
            self.metrics.record_shadow(kernel, cand, dt_cand)
            if self._trace is not None:
                self._trace.shadow(kernel, problem_key(problem), inc, cand,
                                   dt_inc, dt_cand)
            if self.refiner is not None:
                self.refiner.observe(kernel, problem, dtype,
                                     self.hardware.name, inc, dt_inc,
                                     incumbent=True)
                self.refiner.observe(kernel, problem, dtype,
                                     self.hardware.name, cand, dt_cand)
            self.metrics.record_shadow_step()
            return

    def set_plans(self, plans: Optional[TilePlan]) -> None:
        """Swap this engine onto a (refined) plan artifact, live.

        Every plan-derived cache is dropped — prefill/chunk/pack programs,
        chunk plans, tile events, shadow views — and the decode program is
        REBUILT (jax.jit caches the traced graph, so a closure over the old
        tiles would keep serving them). In-flight requests keep their
        states and chunk progress: tiles never change the math (the repo's
        pinned invariant), so a mid-prefill swap is token-transparent.
        """
        self.plans = plans
        self._prefill_fns.clear()
        self._prefill_sources.clear()
        self._prefill_tile_events.clear()
        self._chunk_plans.clear()
        self._chunk_fns.clear()
        self._chunk_tile_events.clear()
        self._pack_plan_cache = None
        self._pack_fns.clear()
        self._pack_tile_events.clear()
        self._single_chunk_edge = None
        self._decode_tile_events = None
        self._shadow_views.clear()
        self.tiles, self.tile_resolutions = {}, {}
        self._plan_schema = None
        if plans is not None:
            self._resolve_tiles(plans)
        cfg = self.cfg
        self._decode = jax.jit(
            lambda p, tok, st: api.decode_step(p, cfg, tok, st,
                                               tiles=self.tiles or None)
        )
        self._decode_paged = jax.jit(
            lambda p, tok, st, arrays, table: api.decode_step_paged(
                p, cfg, tok, st, arrays, table, tiles=self.tiles or None)
        )
        if self._trace is not None:
            refined_from = (plans.meta.get("refined_from")
                            if plans is not None else None)
            self._trace.plan_swap(self._plan_schema, refined_from)

    def _prefill_fn(self, length: int):
        """The jitted prefill program for one admitted prompt length.

        Resolves the (batch=1, seq=length) prefill cell's kernel tiles from
        the plan (cached per length) and closes over them, so a bucketed
        shape family compiles once per bucket with the plan's exact tiles.
        """
        fn = self._prefill_fns.get(length)
        if fn is not None:
            return fn
        tiles: Dict[str, TileShape] = {}
        sources: Dict[str, str] = {}
        if self.plans is not None:
            from repro.launch.specs import resolve_model_tiles

            with warnings.catch_warnings():
                # Transfer warnings already fire once at plan resolution
                # inside resolve; accounting below records them as counters.
                warnings.simplefilter("ignore", PlanTransferWarning)
                tiles, resolutions = resolve_model_tiles(
                    self.plans, self.cfg, 1, length, "prefill",
                    jnp.dtype(self.dtype).name, self.hardware)
            sources = {
                kernel: (resolutions[kernel].source
                         if kernel in resolutions else "fallback")
                for kernel in tiles
            }
        else:
            from repro.launch.specs import kernel_problems

            sources = {
                kernel: "no_plan"
                for kernel in kernel_problems(self.cfg, 1, length, "prefill")
            }
        cfg, max_len, dtype = self.cfg, self.max_len, self.dtype
        fn = jax.jit(
            lambda p, batch: api.prefill(
                p, cfg, batch, max_len=max_len, dtype=dtype,
                ring_local=bool(cfg.attn_window), tiles=tiles or None)
        )
        self._prefill_fns[length] = fn
        self._prefill_sources[length] = sources
        if self._trace is not None:
            from repro.launch.specs import kernel_problems

            self._trace_plan_table(
                "prefill", tiles, sources,
                kernel_problems(self.cfg, 1, length, "prefill"))
        if self.plans is not None:
            from repro.launch.specs import kernel_problems

            self._note_shadow_cells(
                kernel_problems(self.cfg, 1, length, "prefill"))
        return fn

    # -- chunked prefill -----------------------------------------------------
    def _resolve_serve_cell(self, kind: str, seq_len: int):
        """Resolve one serving attention cell (``chunked_prefill`` or
        ``packed_prefill``) from the plan store at one geometry; falls back
        to the kernel's heuristic default tile, never a sweep. Returns
        ``(problem | None, tile | None, source)`` — problem is None for
        attention-free models (the cell never runs). ONE implementation for
        both cell kinds so chunked and packed plan accounting cannot
        drift."""
        from repro import kernels as kernel_pkg
        from repro.core import registry
        from repro.launch.specs import kernel_problems

        kernel_pkg.register_all()
        dtype = jnp.dtype(self.dtype).name
        problem = kernel_problems(self.cfg, 1, seq_len, kind).get(kind)
        tile, source = None, "no_plan"
        if problem is not None:
            if self.plans is not None:
                with warnings.catch_warnings():
                    warnings.simplefilter("ignore", PlanTransferWarning)
                    res = self.plans.resolve(kind, problem, dtype,
                                             self.hardware)
                if res is not None:
                    tile, source = res.tile, res.source
                else:
                    source = "fallback"
            if tile is None:
                tile = registry.get(kind).default_tile(problem, dtype)
        return problem, tile, source

    def _model_tiles_for(self, seq_len: int):
        """The surrounding (FF/recurrent) prefill kernel tiles at one
        geometry, with their plan sources. The whole-sequence
        flash_attention cell is dropped: chunk/pack programs consume the
        chunked_prefill/packed_prefill cells instead, and plan counters
        must reflect the cells the programs actually run."""
        from repro.launch.specs import kernel_problems, resolve_model_tiles

        dtype = jnp.dtype(self.dtype).name
        tiles: Dict[str, TileShape] = {}
        sources: Dict[str, str] = {}
        if self.plans is not None:
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", PlanTransferWarning)
                tiles, resolutions = resolve_model_tiles(
                    self.plans, self.cfg, 1, seq_len, "prefill", dtype,
                    self.hardware)
            tiles.pop("flash_attention", None)
            sources = {
                kernel: (resolutions[kernel].source
                         if kernel in resolutions else "fallback")
                for kernel in tiles
            }
        else:
            sources = {
                kernel: "no_plan"
                for kernel in kernel_problems(self.cfg, 1, seq_len,
                                              "prefill")
                if kernel != "flash_attention"
            }
        return tiles, sources

    def _chunk_plan(self, admit_len: int):
        """(chunk_len, tiles, sources) for prefilling one admitted length.

        The chunk length is the plan-resolved ``chunked_prefill`` tile's
        first dim — chosen per hardware model, so the same prompt prefills
        in different chunk sizes on different models — clamped so one chunk
        plus a full decode batch fits ``step_token_budget``. The remaining
        (FF/recurrent) kernel tiles are resolved at the chunk geometry,
        which is the shape the chunk programs actually run.
        """
        hit = self._chunk_plans.get(admit_len)
        if hit is not None:
            return hit
        problem, tile, source = self._resolve_serve_cell(
            "chunked_prefill", admit_len)
        chunk = int(tile[0]) if tile is not None else min(512, admit_len)
        if self.step_token_budget:
            # A mixed step must fit one chunk + the whole decode batch.
            chunk = min(chunk, max(1, self.step_token_budget - self.slots))
        chunk = max(1, min(chunk, admit_len))
        if self._paged_whole:
            # Paged without explicit chunking: the whole prompt is ONE
            # chunk, so the paged engine reproduces the monolithic-prefill
            # schedule exactly (single program per admitted length).
            chunk = admit_len

        tiles, sources = self._model_tiles_for(chunk)
        if tile is not None:
            tiles["chunked_prefill"] = tile
        if problem is not None:
            # Attention-free models have no chunked_prefill cell — don't
            # tick a phantom plan counter for a kernel that never runs.
            sources["chunked_prefill"] = source
        entry = (chunk, tiles, sources)
        self._chunk_plans[admit_len] = entry
        if self._trace is not None:
            from repro.launch.specs import kernel_problems

            probs = dict(kernel_problems(self.cfg, 1, chunk, "prefill"))
            if problem is not None:
                probs["chunked_prefill"] = problem
            self._trace_plan_table("prefill", tiles, sources, probs)
        if self.plans is not None:
            from repro.launch.specs import kernel_problems

            cells = {k: v for k, v in kernel_problems(
                self.cfg, 1, chunk, "prefill").items()
                if k != "flash_attention"}
            if problem is not None:
                cells["chunked_prefill"] = problem
            self._note_shadow_cells(cells)
        return entry

    def chunk_len_for(self, admit_len: int) -> int:
        """Chunk length one admitted prompt prefills in (= admit_len when
        chunking is off — the whole prefill is one quantum)."""
        if not self.chunk_prefill:
            return admit_len
        return self._chunk_plan(admit_len)[0]

    def _chunk_fn(self, admit_len: int, start: int):
        """The jitted program for one (admitted length, chunk offset) pair.

        ``start`` is closed over statically: the causal q_offset arithmetic
        and the cache-prefix slice stay compile-time constants, so a chunk
        reads only the KV actually written — at the cost of one program per
        chunk offset (bounded by admit_len / chunk_len per bucket).
        """
        key = (admit_len, start)
        fn = self._chunk_fns.get(key)
        if fn is not None:
            return fn
        _, tiles, _ = self._chunk_plan(admit_len)
        cfg = self.cfg
        if self.paged:
            fn = jax.jit(
                lambda p, toks, st, arrays, table: api.prefill_chunk_paged(
                    p, cfg, toks, st, start, arrays, table,
                    tiles=tiles or None)
            )
        else:
            fn = jax.jit(
                lambda p, toks, st: api.prefill_chunk(
                    p, cfg, toks, st, start, tiles=tiles or None)
            )
        self._chunk_fns[key] = fn
        return fn

    # -- step packing --------------------------------------------------------
    def _pack_plan(self):
        """(pack width, tiles, source) for packed multi-chunk steps.

        The pack width — how many prefill-chunk tokens one packed step may
        carry — is the plan-resolved ``packed_prefill`` tile's first dim,
        chosen per hardware model (VMEM bounds the resident pack, so v5e
        and v6e pack different widths for the same bucket set). The cell is
        resolved at the single-chunk bucket bound: the segment class step
        packing exists for is the short prompts that fit one chunk. The
        remaining (FF/recurrent) tiles are resolved at the pack geometry —
        the token count the packed programs actually run.
        """
        if self._pack_plan_cache is not None:
            return self._pack_plan_cache
        policy = getattr(self.scheduler, "policy", None)
        edge = self._single_chunk_bound() or (
            min(policy.edges) if policy is not None else 512)
        problem, tile, source = self._resolve_serve_cell(
            "packed_prefill", edge)
        width = int(tile[0]) if tile is not None else max(512, edge)
        tiles, _ = self._model_tiles_for(min(width, self.max_len))
        if tile is not None:
            tiles["packed_prefill"] = tile
        self._pack_plan_cache = (width, tiles, source)
        if self._trace is not None and problem is not None:
            self._trace_plan_table(
                "prefill", tiles, {"packed_prefill": source},
                {"packed_prefill": problem})
        if self.plans is not None and problem is not None:
            self._note_shadow_cells({"packed_prefill": problem})
        return self._pack_plan_cache

    def _pack_budget(self) -> float:
        """Max prefill-chunk tokens one packed step may carry: the plan's
        pack width, clamped so pack + decode batch fits the step budget."""
        width, _, _ = self._pack_plan()
        if self.step_token_budget:
            return min(width, max(1, self.step_token_budget - self.slots))
        return width

    # Bound on cached packed programs (and their tile events): beyond it
    # the least-recently-USED layout is evicted and would retrace if seen
    # again. Eviction must be LRU, not FIFO: a hot layout (a steady-state
    # pack shape hit every few steps) is also one of the OLDEST insertions,
    # so insertion-order eviction retraces exactly the programs a
    # long-running server needs most (tests/test_serve_paged.py pins a hot
    # layout surviving cap-many cold ones).
    PACK_FN_CACHE_CAP = 256

    def _pack_fn(self, layout):
        """The jitted packed program for one static segment layout
        (tuple of per-segment (start, len) pairs — the packed analogue of
        the per-(admit_len, start) chunk programs)."""
        fn = self._pack_fns.pop(layout, None)
        if fn is not None:
            # Re-insert at the end: recency, not insertion order, decides
            # eviction.
            self._pack_fns[layout] = fn
            return fn
        while len(self._pack_fns) >= self.PACK_FN_CACHE_CAP:
            oldest = next(iter(self._pack_fns))
            del self._pack_fns[oldest]
            self._pack_tile_events.pop(oldest, None)
        _, tiles, _ = self._pack_plan()
        cfg = self.cfg
        if self.paged:
            fn = jax.jit(
                lambda p, toks, sts, arrays, tbls: api.prefill_packed_paged(
                    p, cfg, toks, sts, layout, arrays, tbls,
                    tiles=tiles or None)
            )
        else:
            fn = jax.jit(
                lambda p, toks, sts: api.prefill_packed(
                    p, cfg, toks, sts, layout, tiles=tiles or None)
            )
        self._pack_fns[layout] = fn
        return fn

    def _ensure_state(self, job: _ChunkJob) -> None:
        if job.state is None:
            if self.paged:
                # Attention K/V live in the shared pool; the per-request
                # state carries only scalar positions (+ recurrent/SSD
                # carried state for hybrids).
                job.state = api.make_paged_state(self.cfg, self.dtype)
            else:
                job.state = api.make_serve_state(
                    self.cfg, 1, self.max_len, self.dtype,
                    ring_local=bool(self.cfg.attn_window))

    def _advance_job(self, job: _ChunkJob, take: int, events, logits,
                     packed: bool = False, pack_n: int = 1, lane: int = 0,
                     t0: Optional[float] = None) -> None:
        """Per-chunk bookkeeping shared by the one-chunk and packed paths:
        tile events accrue, chunk telemetry ticks, progress advances, and a
        completed prefill leaves the chunking set. One implementation on
        purpose — packed and one-chunk accounting must never drift (the
        conformance suite pins their observable equality)."""
        job.events.extend(events)
        now = self._clock()
        age = now - job.last_t
        self.metrics.record_chunk(job.req.bucket, age)
        if self._trace is not None:
            self._trace.chunk(job.req.rid, lane, now if t0 is None else t0,
                              job.done, take, pack_n, age)
        job.last_t = now
        job.done += take
        job.chunks_run += 1
        job.packed_runs += packed
        if job.done >= len(job.prompt):
            self._chunking.remove(job)
            self._finish_prefill(job, logits)

    def _run_pack(self, picks) -> int:
        """Advance every picked job by one chunk in ONE packed launch;
        returns the pack's total token count."""
        jobs = [job for job, _ in picks]
        layout = tuple((job.done, take) for job, take in picks)
        t0 = self._clock() if self._trace is not None else None
        for job in jobs:
            self._ensure_state(job)
        toks = jnp.asarray(np.concatenate([
            job.prompt[start:start + take]
            for job, (start, take) in zip(jobs, layout)
        ])[None])
        fn = self._pack_fn(layout)
        states = tuple(job.state for job in jobs)
        events = self._pack_tile_events.get(layout)
        if self.paged:
            for job, (start, take) in zip(jobs, layout):
                self.pool.prepare_span(job.req.rid, start, take)
            tables = tuple(self.pool.device_table(job.req.rid)
                           for job in jobs)
            args = (self.params, toks, states, self.pool.arrays, tables)
            if events is None:
                captured: List[Dict[str, Any]] = []
                with attn_mod.capture_tile_events(captured.append):
                    logits, new_states, self.pool.arrays = fn(*args)
                events = self._dedupe_events(captured)
                self._pack_tile_events[layout] = events
            else:
                logits, new_states, self.pool.arrays = fn(*args)
        elif events is None:
            captured = []
            with attn_mod.capture_tile_events(captured.append):
                logits, new_states = fn(self.params, toks, states)
            events = self._dedupe_events(captured)
            self._pack_tile_events[layout] = events
        else:
            logits, new_states = fn(self.params, toks, states)
        for i, (job, (start, take)) in enumerate(zip(jobs, layout)):
            job.state = new_states[i]
            self._advance_job(job, take, events, logits[i][None],
                              packed=True, pack_n=len(jobs), lane=i, t0=t0)
        return sum(take for _, take in layout)

    def _is_multi_chunk(self, req: Request) -> bool:
        """Will this request's prefill span more than one chunk?"""
        admit_len = req.bucket if req.bucket is not None else len(req.prompt)
        return admit_len > self._chunk_plan(admit_len)[0]

    def _single_chunk_bound(self) -> int:
        """Largest bucket edge whose prefill fits one chunk (0 if none)."""
        if self._single_chunk_edge is None:
            policy = getattr(self.scheduler, "policy", None)
            edges = policy.edges if policy is not None else ()
            self._single_chunk_edge = max(
                (e for e in edges if self._chunk_plan(e)[0] >= e), default=0)
        return self._single_chunk_edge

    def _next_admission(self, long_ok: bool) -> Optional[Request]:
        """Next request to start prefilling.

        With ``long_ok=False`` only single-chunk requests qualify. Bucketed
        schedulers support a filtered pop (``next_request_within``), so
        queued long prompts stay in the scheduler — visible to ``max_queue``
        admission control and the queue-depth metric — while small buckets
        behind them stay reachable no matter how many longs are queued.
        FIFO schedulers cannot pop selectively; deferred longs go to a
        holding pen capped at ``prefill_slots`` entries (beyond the cap the
        engine simply waits for the in-flight long, preserving FIFO order).
        """
        for i, req in enumerate(self._held):
            if long_ok or not self._is_multi_chunk(req):
                return self._held.pop(i)
        within = getattr(self.scheduler, "next_request_within", None)
        if not long_ok and within is not None:
            return within(self._single_chunk_bound())
        while len(self._held) < self.prefill_slots:
            req = self.scheduler.next_request()
            if req is None:
                return None
            if long_ok or not self._is_multi_chunk(req):
                return req
            self._held.append(req)
        return None

    def _admit_chunked(self) -> None:
        """Move ready prefills into decode slots and queued requests into
        free prefill slots (chunk concurrency).

        At most ONE multi-chunk prefill runs at a time: a stream of long
        prompts must not occupy every prefill slot and starve short ones —
        the head-of-line blocking chunking exists to cut. Deferred longs
        keep their order and start as soon as the running one finishes.
        Paged mode lifts the one-long rule: longs cannot starve shorts by
        occupying slots (the pool gate, not ``prefill_slots``, bounds the
        resident set, and the SRPT pack rule still serves shorts first),
        so many partial long prefills accumulate pages concurrently.
        """
        free = [i for i, r in enumerate(self._active) if r is None]
        while free and self._ready:
            req, state = self._ready.pop(0)
            i = free.pop(0)
            self._active[i] = req
            self._states[i] = state
        # Backpressure: a completed prefill holds a full KV cache until a
        # decode slot frees. Once _ready already covers every decode slot,
        # admitting more prefills would only stack further caches (the
        # unchunked engine never holds more than ``slots`` live states) —
        # stall admission until decode catches up. Live states stay
        # bounded: decode slots + in-flight chunking + ready <=
        # 2*slots + 2*prefill_slots.
        if len(self._ready) >= self.slots:
            return
        long_in_flight = any(len(j.prompt) > j.chunk_len
                             for j in self._chunking)
        # Paged mode admits PAST ``prefill_slots``: the pool's reservation
        # accounting (PagedKVPool.can_admit) is the real resident-set gate
        # — a request holds only the pages it has written, so many partial
        # prefills coexist where whole-cache slots fit few. The count cap
        # is only a retrace/bookkeeping safety bound.
        cap = (8 * (self.slots + self.prefill_slots) if self.paged
               else self.prefill_slots)
        while len(self._chunking) < cap:
            req = None
            if self.paged and self._pool_wait:
                # Pool-starved requests hold a FIFO claim on freed pages:
                # the head admits first or nobody does (no overtaking).
                if not self.pool.can_admit(
                        self._pool_estimate(self._pool_wait[0])):
                    break
                req = self._pool_wait.pop(0)
            if req is None:
                req = self._next_admission(
                    long_ok=self.paged or not long_in_flight)
            if req is None:
                break
            if self.paged and not self.pool.can_admit(
                    self._pool_estimate(req)):
                self._pool_wait.append(req)
                break
            prompt = np.asarray(self.scheduler.prepare(req), np.int32)
            chunk_len, _, _ = self._chunk_plan(len(prompt))
            long_in_flight = long_in_flight or len(prompt) > chunk_len
            submit_t = self.metrics.submit_time(req.rid)
            if self._trace is not None:
                now = self._clock()
                self._trace.admit(
                    req.rid, len(prompt),
                    now - submit_t if submit_t is not None else 0.0)
            hit = 0
            if self.paged:
                self.pool.register_request(
                    req.rid, len(prompt) + req.max_new_tokens - 1)
                # A shared-prefix hit maps already-prefilled pages and the
                # job starts its chunks at the divergence point.
                hit = self.pool.lookup_prefix(req.rid, prompt.tolist())
            self._chunking.append(_ChunkJob(
                req=req, prompt=prompt, chunk_len=chunk_len, done=hit,
                last_t=submit_t if submit_t is not None else self._clock()))

    def _pool_estimate(self, req: Request) -> int:
        """Worst-case cache positions a request will write (for the pool
        admission gate): padded prompt + generation minus the never-cached
        final sampled token."""
        admit_len = req.bucket if req.bucket is not None else len(req.prompt)
        return admit_len + req.max_new_tokens - 1

    # Every AGING_PERIOD-th chunk goes to the OLDEST in-flight prefill
    # instead of the shortest-remaining one: a sustained stream of short
    # prompts can otherwise starve a long prefill forever (its `remaining`
    # never shrinks because it never runs). 1/AGING_PERIOD of the chunk
    # bandwidth is a guaranteed progress floor for the long request.
    AGING_PERIOD = 4

    def _next_chunk_job(self) -> Optional[_ChunkJob]:
        """The most urgent in-flight prefill: priority, deadline, then
        fewest remaining tokens (shortest-remaining-prefill-first), so a
        short prompt admitted behind a long one reaches its first token
        after one chunk-time instead of after the long prompt's entire
        prefill — with periodic aging so the long one still progresses."""
        if not self._chunking:
            return None
        self._chunk_ticks += 1
        if self._chunk_ticks % self.AGING_PERIOD == 0:
            return min(self._chunking,
                       key=lambda j: (j.req.priority, j.req.deadline,
                                      j.req.rid))
        return min(self._chunking,
                   key=lambda j: (j.req.priority, j.req.deadline,
                                  j.remaining, j.req.rid))

    def _run_chunk(self, job: _ChunkJob) -> int:
        """Advance one job by one chunk; returns the chunk's token count."""
        start = job.done
        length = min(job.chunk_len, len(job.prompt) - start)
        t0 = self._clock() if self._trace is not None else None
        self._ensure_state(job)
        fn = self._chunk_fn(len(job.prompt), start)
        toks = jnp.asarray(job.prompt[None, start:start + length])
        key = (len(job.prompt), start)
        events = self._chunk_tile_events.get(key)
        if self.paged:
            self.pool.prepare_span(job.req.rid, start, length)
            args = (self.params, toks, job.state, self.pool.arrays,
                    self.pool.device_table(job.req.rid))
            if events is None:
                captured: List[Dict[str, Any]] = []
                with attn_mod.capture_tile_events(captured.append):
                    logits, job.state, self.pool.arrays = fn(*args)
                events = self._dedupe_events(captured)
                self._chunk_tile_events[key] = events
            else:
                logits, job.state, self.pool.arrays = fn(*args)
        elif events is None:
            captured = []
            with attn_mod.capture_tile_events(captured.append):
                logits, job.state = fn(self.params, toks, job.state)
            events = self._dedupe_events(captured)
            self._chunk_tile_events[key] = events
        else:
            logits, job.state = fn(self.params, toks, job.state)
        self._advance_job(job, length, events, logits, t0=t0)
        return length

    def _finish_prefill(self, job: _ChunkJob, logits) -> None:
        """Last chunk done: sample the first token, account the prefill."""
        req = job.req
        _, _, sources = self._chunk_plan(len(job.prompt))
        # Plan + tile-event counters tick once per request prefill, not once
        # per chunk: a 16-chunk prefill must not inflate tile_fallback 16x.
        for kernel, source in sources.items():
            self.metrics.record_plan("prefill", kernel, source)
        if job.packed_runs:
            # The request's chunks (also) rode packed launches: count the
            # packed cell's resolution once per request, like every other
            # prefill cell.
            _, _, pack_source = self._pack_plan()
            self.metrics.record_plan("prefill", "packed_prefill",
                                     pack_source)
        for ev in self._dedupe_events(job.events):
            self._record_tile_event(ev)
        self.metrics.record_prefill_chunks(job.chunks_run)
        tok = int(jnp.argmax(logits[0, :self.cfg.vocab_size]))
        req.out_tokens.append(tok)
        # Submit time must be read BEFORE record_first_token pops it: the
        # ttft trace span is anchored at submit, exactly like the metric.
        sub_t = (self.metrics.submit_time(req.rid)
                 if self._trace is not None else None)
        self.metrics.record_first_token(req.rid, req.bucket)
        if self._trace is not None:
            self._trace.first_token(req.rid, req.bucket, sub_t)
        if self.paged:
            # The prefilled pages become shareable fleet-wide (weak
            # registry — holds no refs, never delays a free).
            self.pool.register_prefix(req.rid, job.prompt.tolist())
        if len(req.out_tokens) >= req.max_new_tokens:
            req.done = True
            if self.paged:
                self.pool.release(req.rid)
            self._finished.append(req)
            self.metrics.record_complete()
            if self._trace is not None:
                self._trace.finish(req.rid, len(req.out_tokens))
        else:
            if self.paged:
                # Next cache write (first decode) lands right after the
                # prompt.
                self._pos[req.rid] = len(job.prompt)
            self._ready.append((req, job.state))

    def add_request(self, prompt: np.ndarray, max_new_tokens: int = 16,
                    priority: int = 0,
                    deadline: float = math.inf,
                    submit_t: Optional[float] = None) -> Optional[int]:
        """Submit a request; returns its rid, or None when admission control
        rejects it (queue full, prompt longer than every bucket edge, or the
        padded prompt plus the generation would overflow the KV cache).

        ``submit_t`` backdates the TTFT anchor: fleet recovery re-queues a
        failed instance's request here with its ORIGINAL submit time, so
        the recovered first token's TTFT spans the whole outage instead of
        restarting the clock (submit-anchored across retries)."""
        prompt = np.asarray(prompt, np.int32)
        shaped = self.scheduler.admit_length(len(prompt))
        if shaped is None:
            return self._reject("over_length", len(prompt))
        # Decode writes KV at positions shaped..shaped+max_new-2 (the last
        # sampled token is never cached); past max_len the update would
        # silently clamp onto the final slot and corrupt attention.
        if shaped + max_new_tokens - 1 > self.max_len:
            return self._reject("cache_overflow", len(prompt))
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, prompt, max_new_tokens,
                      priority=priority, deadline=deadline)
        if not self.scheduler.submit(req):
            return self._reject(
                getattr(self.scheduler, "last_reject_reason", "admission"),
                len(prompt))
        self.metrics.record_submit(rid, t=submit_t)
        self._record_backlog(self.scheduler.pending() + len(self._held)
                             + len(self._pool_wait))
        if self._trace is not None:
            self._trace.submit(rid, len(prompt), req.bucket)
        return rid

    def _reject(self, reason: str, prompt_len: int) -> None:
        """Account one admission rejection: reason counter, backlog sample
        (a rejected submit is exactly when backlog pressure peaked), and a
        trace instant carrying the reason. The reason also lands in
        ``self.last_reject_reason`` so a caller holding only the ``None``
        return (the fleet router's failover path) can read why."""
        self.last_reject_reason = reason
        self.metrics.record_reject(reason=reason)
        self._record_backlog(self.scheduler.pending() + len(self._held)
                             + len(self._pool_wait))
        if self._trace is not None:
            self._trace.reject(reason, prompt_len)
        return None

    def _record_backlog(self, depth: int) -> None:
        """Sample queue depth into metrics (and the trace counter track).
        Called at every step AND at every admit/reject: backlog accrued
        while the engine sits idle between steps was previously invisible
        to the step-only sampling."""
        self.metrics.record_queue_depth(depth)
        if self._trace is not None:
            self._trace.queue_depth(depth)

    def _admit(self):
        """Admit into free slots, running each whole prefill. Returns
        (total prompt tokens prefilled, per-prefill (admit_len, tokens)
        segments) — mixed-step accounting for virtual-clock drivers."""
        prefill_tokens = 0
        segments: List[Any] = []
        free = [i for i, r in enumerate(self._active) if r is None]
        while free:
            req = self.scheduler.next_request()
            if req is None:
                break
            prompt = self.scheduler.prepare(req)
            prefill_tokens += len(prompt)
            segments.append((len(prompt), len(prompt)))
            prefill = self._prefill_fn(len(prompt))
            for kernel, source in self._prefill_sources[len(prompt)].items():
                self.metrics.record_plan("prefill", kernel, source)
            sub_t = (self.metrics.submit_time(req.rid)
                     if self._trace is not None else None)
            t0 = self._clock() if self._trace is not None else None
            batch = {"tokens": jnp.asarray(prompt[None])}
            events = self._prefill_tile_events.get(len(prompt))
            if events is None:
                captured: List[Dict[str, Any]] = []
                with attn_mod.capture_tile_events(captured.append):
                    logits, state = prefill(self.params, batch)
                events = self._dedupe_events(captured)
                self._prefill_tile_events[len(prompt)] = events
            else:
                logits, state = prefill(self.params, batch)
            for ev in events:
                self._record_tile_event(ev)
            tok = int(jnp.argmax(logits[0, :self.cfg.vocab_size]))
            req.out_tokens.append(tok)
            self.metrics.record_first_token(req.rid, req.bucket)
            if self._trace is not None:
                self._trace.admit(
                    req.rid, len(prompt),
                    t0 - sub_t if sub_t is not None else 0.0)
                self._trace.prefill(req.rid, t0, len(prompt))
                self._trace.first_token(req.rid, req.bucket, sub_t)
            if len(req.out_tokens) >= req.max_new_tokens:
                # Satisfied by the prefill token alone — never occupy a
                # slot or run a decode step (which would also write KV one
                # position past the admission bound).
                req.done = True
                self._finished.append(req)
                self.metrics.record_complete()
                if self._trace is not None:
                    self._trace.finish(req.rid, len(req.out_tokens))
                continue
            i = free.pop(0)
            self._active[i] = req
            self._states[i] = state
        return prefill_tokens, tuple(segments)

    def _decode_all(self) -> int:
        """One decode step for every active slot. Returns #active."""
        n = 0
        active_buckets = []
        trace_rids = [] if self._trace is not None else None
        t0 = self._clock()
        for i, req in enumerate(self._active):
            if req is None:
                continue
            n += 1
            active_buckets.append(req.bucket)
            if trace_rids is not None:
                trace_rids.append(req.rid)
            last = jnp.asarray([[req.out_tokens[-1]]], jnp.int32)
            if self.paged:
                # The decode program writes this token's K/V at the next
                # cache position — make its page writable (CoW-splitting a
                # shared one) before the launch.
                pos = self._pos[req.rid]
                self.pool.prepare_span(req.rid, pos, 1)
                self._pos[req.rid] = pos + 1
                args = (self.params, last, self._states[i],
                        self.pool.arrays, self.pool.device_table(req.rid))
                if self._decode_tile_events is None:
                    captured: List[Dict[str, Any]] = []
                    with attn_mod.capture_tile_events(captured.append):
                        (logits, self._states[i],
                         self.pool.arrays) = self._decode_paged(*args)
                    self._decode_tile_events = self._dedupe_events(captured)
                    for ev in self._decode_tile_events:
                        self._record_tile_event(ev)
                else:
                    (logits, self._states[i],
                     self.pool.arrays) = self._decode_paged(*args)
            elif self._decode_tile_events is None:
                captured = []
                with attn_mod.capture_tile_events(captured.append):
                    logits, self._states[i] = self._decode(
                        self.params, last, self._states[i])
                self._decode_tile_events = self._dedupe_events(captured)
                for ev in self._decode_tile_events:
                    self._record_tile_event(ev)
            else:
                logits, self._states[i] = self._decode(
                    self.params, last, self._states[i])
            tok = int(jnp.argmax(logits[0, :self.cfg.vocab_size]))
            req.out_tokens.append(tok)
            if len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                self._active[i] = None
                self._states[i] = None
                if self.paged:
                    self.pool.release(req.rid)
                    self._pos.pop(req.rid, None)
                self._finished.append(req)
                self.metrics.record_complete()
                if self._trace is not None:
                    self._trace.finish(req.rid, len(req.out_tokens))
        self.metrics.record_decode_step(active_buckets, self._clock() - t0)
        if trace_rids is not None and n:
            self._trace.decode(t0, trace_rids)
        return n

    def step(self) -> int:
        """One engine step. Returns the number of requests in service.

        Unchunked: admit (each admission runs its whole prefill) + one
        decode step over the active slots — the pre-chunking behavior.
        Chunked: a **mixed step** — one prefill chunk for the most urgent
        in-flight prefill co-scheduled with the whole decode batch, the two
        together bounded by ``step_token_budget`` tokens.
        """
        if self.chunk_prefill:
            return self._step_chunked()
        t0 = self._clock() if self._trace is not None else 0.0
        prefill_tokens, segments = self._admit()
        self._record_backlog(self.scheduler.pending())
        n = self._decode_all()
        # Second admission pass: requests that FINISHED in this step's
        # decode released their slots (and caches) above — admitting again
        # lets a queued request claim the freed headroom in the same step
        # instead of idling one extra step per turnover. Admission-order
        # and token math are untouched; only the latency of reusing a
        # freed slot changes.
        extra_tokens, extra_segments = self._admit()
        prefill_tokens += extra_tokens
        segments = segments + extra_segments
        self.last_step_stats = {"prefill_tokens": prefill_tokens,
                                "decode_tokens": n,
                                "packed_chunks": 0, "packed_rids": (),
                                "prefill_segments": segments}
        self._maybe_shadow()
        self.steps_run += 1
        if self._trace is not None:
            self._trace.step_mark(t0, self.last_step_stats, self.steps_run)
        return n

    def _step_chunked(self) -> int:
        t0 = self._clock() if self._trace is not None else 0.0
        self._admit_chunked()
        # Held (deferred multi-chunk) requests are still backlog.
        self._record_backlog(self.scheduler.pending() + len(self._held)
                             + len(self._pool_wait))
        prefill_tokens = 0
        packed_rids: tuple = ()
        segments: tuple = ()
        if self.pack_prefill:
            picks = self._next_pack()
            if picks:
                packed_rids = tuple(job.req.rid for job, _ in picks)
                segments = tuple((len(job.prompt), take)
                                 for job, take in picks)
                self.metrics.record_packed_step(len(picks))
                if len(picks) == 1:
                    # Singleton pack: reuse the per-(admit_len, start)
                    # chunk program — same math, warmer jit cache.
                    prefill_tokens = self._run_chunk(picks[0][0])
                else:
                    prefill_tokens = self._run_pack(picks)
                self._admit_chunked()
        else:
            job = self._next_chunk_job()
            if job is not None:
                packed_rids = (job.req.rid,)
                segments = ((len(job.prompt),
                             min(job.chunk_len, job.remaining)),)
                prefill_tokens = self._run_chunk(job)
                # A prefill finished by that chunk may start decoding this
                # very step if a slot is free — its first decode token
                # rides the same mixed step.
                self._admit_chunked()
        n = self._decode_all()
        # Second admission pass (same rationale as step()): decode just
        # released the slots/pool pages of every request it finished, so a
        # waiting request admits THIS step — in paged mode this is also
        # what lets a pool-starved request claim freed pages without a
        # one-step bubble.
        self._admit_chunked()
        if self.paged:
            self.metrics.record_pool(self.pool.used_pages,
                                     self.pool.n_pages)
            if self._trace is not None:
                self._trace.pool_occupancy(self.pool.used_pages,
                                           self.pool.n_pages)
        self.last_step_stats = {"prefill_tokens": prefill_tokens,
                                "decode_tokens": n,
                                "packed_chunks": len(packed_rids),
                                "packed_rids": packed_rids,
                                "prefill_segments": segments}
        self._maybe_shadow()
        self.steps_run += 1
        if self._trace is not None:
            self._trace.step_mark(t0, self.last_step_stats, self.steps_run)
        return (n + len(self._chunking) + len(self._ready)
                + len(self._held) + len(self._pool_wait))

    def _next_pack(self):
        """The chunks this packed step runs: scheduler knapsack over the
        in-flight prefills under min(step budget - decode batch, plan pack
        width), at most ``prefill_slots`` segments, with the same
        SRPT-plus-aging head rule as one-chunk-per-step service."""
        from repro.serve.scheduler import pick_chunks

        if not self._chunking:
            return []
        self._chunk_ticks += 1
        aging = self._chunk_ticks % self.AGING_PERIOD == 0
        return pick_chunks(self._chunking, self._pack_budget(),
                           self.prefill_slots, aging=aging)

    def in_flight(self) -> int:
        """Requests holding engine state (decode slots + partial prefills +
        deferred multi-chunk admissions + pool-starved waiters)."""
        return (sum(r is not None for r in self._active)
                + len(self._chunking) + len(self._ready)
                + len(self._held) + len(self._pool_wait))

    # -- eviction / handoff (fleet fault tolerance) --------------------------
    def _evict_state(self, req: Request) -> None:
        """Tear down one request's engine-held state: pool pages released
        (refcount-balanced; ``missing_ok`` because _held/_pool_wait stages
        never registered), decode cursor dropped, pending TTFT anchor
        dropped (the recovering router re-anchors it on the next engine)."""
        if self.paged:
            self.pool.release(req.rid, missing_ok=True)
            self._pos.pop(req.rid, None)
        t = self.metrics.drop_submit(req.rid)
        if t is not None:
            req.submit_t = t

    def extract_queued(self) -> List[Request]:
        """Hand off every request that has not started prefilling: the
        scheduler queue (drained in urgency order), the multi-chunk holding
        pen, and the pool-wait line. None of these hold device state or
        pool pages — extraction is pure bookkeeping. Generated tokens are
        untouched (there are none). Used by graceful drain and work
        handoff; the caller re-queues them elsewhere."""
        out: List[Request] = []
        while True:
            req = self.scheduler.next_request()
            if req is None:
                break
            out.append(req)
        out.extend(self._held)
        self._held.clear()
        out.extend(self._pool_wait)
        self._pool_wait.clear()
        for req in out:
            self._evict_state(req)
        return out

    def evict_all(self) -> List[Request]:
        """Evict EVERY non-finished request — queued, mid-prefill, ready,
        and decoding — tearing down per-request state (pool pages released
        and refcount-balanced, partial caches dropped). Returns the evicted
        requests with their ``out_tokens`` so far, so fleet recovery can
        account discarded work; recovery re-prefills from the original
        prompt, never from the torn-down caches. Finished requests stay in
        ``self._finished``."""
        out = self.extract_queued()
        for job in list(self._chunking):
            self._evict_state(job.req)
            out.append(job.req)
        self._chunking.clear()
        for req, _state in self._ready:
            self._evict_state(req)
            out.append(req)
        self._ready.clear()
        for i, req in enumerate(self._active):
            if req is None:
                continue
            self._evict_state(req)
            out.append(req)
            self._active[i] = None
            self._states[i] = None
        return out

    def cancel(self, rid: int) -> Optional[Request]:
        """Remove one request wherever it sits in the pipeline (queued,
        held, pool-waiting, mid-chunk-prefill, ready, or decoding), tearing
        down its state exactly like :meth:`evict_all` does for the whole
        engine. Returns the request, or None when ``rid`` is not resident
        (already finished or never admitted)."""
        remove = getattr(self.scheduler, "remove", None)
        req = remove(rid) if remove is not None else None
        if req is None:
            for pen in (self._held, self._pool_wait):
                for i, r in enumerate(pen):
                    if r.rid == rid:
                        req = pen.pop(i)
                        break
                if req is not None:
                    break
        if req is None:
            for job in self._chunking:
                if job.req.rid == rid:
                    req = job.req
                    self._chunking.remove(job)
                    break
        if req is None:
            for i, (r, _state) in enumerate(self._ready):
                if r.rid == rid:
                    req = self._ready.pop(i)[0]
                    break
        if req is None:
            for i, r in enumerate(self._active):
                if r is not None and r.rid == rid:
                    req = r
                    self._active[i] = None
                    self._states[i] = None
                    break
        if req is not None:
            self._evict_state(req)
        return req

    def run_until_done(self, max_steps: int = 1000) -> List[Request]:
        self._finished = []
        for _ in range(max_steps):
            if not self.in_flight() and not self.scheduler.pending():
                break
            self.step()
        return self._finished
