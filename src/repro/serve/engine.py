"""Batched serving engine: slot-based continuous batching (lite).

Fixed decode batch of ``slots``; requests occupy free slots, prefill runs
per request (left-padded into the shared cache), decode advances all active
slots in one jitted step. Greedy sampling. This is the serving analogue of
the train loop — the decode step is the unit the decode_* dry-run shapes
lower.

Tile selection: pass a compiled :class:`~repro.core.plans.TilePlan` (and the
target :class:`~repro.core.HardwareModel`) and the engine resolves every
decode-path kernel tile at construction time — exact hit, nearest shape, or
cross-hardware transfer — without ever invoking an autotuner sweep on the
request path. Cells the plan cannot resolve fall back to the zero-cost
heuristic default tile, never to a sweep.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.hardware import PRODUCTION_TARGET, HardwareModel
from repro.core.plans import PlanResolution, TilePlan
from repro.core.tiling import TileShape
from repro.models import api


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, max_len: int = 512,
                 slots: int = 4, dtype=jnp.float32,
                 plans: Optional[TilePlan] = None,
                 hardware: Optional[HardwareModel] = None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.slots = slots
        self.dtype = dtype
        self.hardware = hardware or PRODUCTION_TARGET
        # kernel name -> resolved tile for the decode path; populated from
        # the AOT plan at init so serving never pays a sweep.
        self.tiles: Dict[str, TileShape] = {}
        self.tile_resolutions: Dict[str, PlanResolution] = {}
        if plans is not None:
            self._resolve_tiles(plans)
        self._active: List[Optional[Request]] = [None] * slots
        self._queue: List[Request] = []
        self._finished: List[Request] = []
        self._next_rid = 0

        # Per-slot independent caches (batch=1) batched by stacking.
        self._states = [None] * slots

        self._decode = jax.jit(
            lambda p, tok, st: api.decode_step(p, cfg, tok, st)
        )
        self._prefill = jax.jit(
            lambda p, batch: api.prefill(
                p, cfg, batch, max_len=max_len, dtype=dtype,
                ring_local=bool(cfg.attn_window))
        )

    def _resolve_tiles(self, plans: TilePlan) -> None:
        """Resolve decode-path kernel tiles from the plan store. No sweeps."""
        from repro.launch.specs import resolve_model_tiles

        self.tiles, self.tile_resolutions = resolve_model_tiles(
            plans, self.cfg, self.slots, self.max_len, "decode",
            jnp.dtype(self.dtype).name, self.hardware)

    def add_request(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid, np.asarray(prompt, np.int32),
                                   max_new_tokens))
        return rid

    def _admit(self):
        for i in range(self.slots):
            if self._active[i] is None and self._queue:
                req = self._queue.pop(0)
                batch = {"tokens": jnp.asarray(req.prompt[None])}
                logits, state = self._prefill(self.params, batch)
                tok = int(jnp.argmax(logits[0, :self.cfg.vocab_size]))
                req.out_tokens.append(tok)
                self._active[i] = req
                self._states[i] = state

    def step(self) -> int:
        """Admit + one decode step for all active slots. Returns #active."""
        self._admit()
        n = 0
        for i, req in enumerate(self._active):
            if req is None:
                continue
            n += 1
            last = jnp.asarray([[req.out_tokens[-1]]], jnp.int32)
            logits, self._states[i] = self._decode(
                self.params, last, self._states[i])
            tok = int(jnp.argmax(logits[0, :self.cfg.vocab_size]))
            req.out_tokens.append(tok)
            if len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                self._active[i] = None
                self._states[i] = None
                self._finished.append(req)
        return n

    def run_until_done(self, max_steps: int = 1000) -> List[Request]:
        self._finished = []
        for _ in range(max_steps):
            if not any(self._active) and not self._queue:
                break
            self.step()
        return self._finished
