"""Batched serving engine: slot-based continuous batching (lite).

Fixed decode batch of ``slots``; requests occupy free slots, prefill runs
per request (left-padded into the shared cache), decode advances all active
slots in one jitted step. Greedy sampling. This is the serving analogue of
the train loop — the decode step is the unit the decode_* dry-run shapes
lower.

Admission is delegated to a scheduler (``repro.serve.scheduler``): the
default :class:`~repro.serve.scheduler.FifoScheduler` preserves the naive
raw-shape behavior; a :class:`~repro.serve.scheduler.ShapeBucketScheduler`
pads prompts to the plan's shape family so every prefill lands on an
exactly-resolved plan cell (and a warm jit cache entry) instead of an
arbitrary shape that silently falls back to heuristics.

Tile selection: pass a compiled :class:`~repro.core.plans.TilePlan` (and the
target :class:`~repro.core.HardwareModel`) and the engine resolves every
decode-path kernel tile at construction time — exact hit, nearest shape, or
cross-hardware transfer — without ever invoking an autotuner sweep on the
request path. Prefill tiles are resolved per admitted shape (cached per
length) and threaded into the model's kernel call sites. Cells the plan
cannot resolve fall back to the zero-cost heuristic default tile, never to
a sweep. Every resolution is counted in ``self.metrics`` (plan hit /
transfer / fallback counters, TTFT/TPOT, queue depth).
"""
from __future__ import annotations

import dataclasses
import math
import time
import warnings
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.hardware import PRODUCTION_TARGET, HardwareModel
from repro.core.plans import PlanResolution, PlanTransferWarning, TilePlan
from repro.core.tiling import TileShape
from repro.models import api
from repro.models import attention as attn_mod
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import FifoScheduler


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 16
    priority: int = 0           # lower = more urgent
    deadline: float = math.inf  # absolute, scheduler-clock units
    bucket: Optional[int] = None  # padded length (set at submit)
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, max_len: int = 512,
                 slots: int = 4, dtype=jnp.float32,
                 plans: Optional[TilePlan] = None,
                 hardware: Optional[HardwareModel] = None,
                 scheduler=None,
                 metrics: Optional[ServeMetrics] = None,
                 clock: Callable[[], float] = time.perf_counter):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.slots = slots
        self.dtype = dtype
        self.hardware = hardware or PRODUCTION_TARGET
        self.plans = plans
        self.scheduler = scheduler or FifoScheduler()
        self.metrics = metrics or ServeMetrics(clock=clock)
        self._clock = clock
        # kernel name -> resolved tile for the decode path; populated from
        # the AOT plan at init so serving never pays a sweep.
        self.tiles: Dict[str, TileShape] = {}
        self.tile_resolutions: Dict[str, PlanResolution] = {}
        if plans is not None:
            self._resolve_tiles(plans)
        self._active: List[Optional[Request]] = [None] * slots
        self._finished: List[Request] = []
        self._next_rid = 0

        # Per-slot independent caches (batch=1) batched by stacking.
        self._states = [None] * slots

        self._decode = jax.jit(
            lambda p, tok, st: api.decode_step(p, cfg, tok, st,
                                               tiles=self.tiles or None)
        )
        # Prefill programs are built per admitted length so each shape
        # family gets its own exactly-resolved tiles (see _prefill_fn).
        self._prefill_fns: Dict[int, Any] = {}
        self._prefill_sources: Dict[int, Dict[str, str]] = {}
        # Tile-dispatch events fire once per jit trace; cache them per
        # length and replay per admitted request so tile_fallback counts in
        # the same unit as the per-request plan-source counters above. The
        # decode program's (deduped) events record once per engine — the
        # same unit as its per-engine plan-source counts from
        # ``_resolve_tiles``. None = decode not yet traced.
        self._prefill_tile_events: Dict[int, List[Dict[str, Any]]] = {}
        self._decode_tile_events: Optional[List[Dict[str, Any]]] = None

    @staticmethod
    def _dedupe_events(events: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
        """Drop retrace duplicates (eval_shape / checkpoint passes and
        identical per-layer call sites re-emit the same event)."""
        seen, out = set(), []
        for ev in events:
            key = tuple(sorted((k, str(v)) for k, v in ev.items()))
            if key not in seen:
                seen.add(key)
                out.append(ev)
        return out

    def _record_tile_event(self, event: Dict[str, Any]) -> None:
        """Trace-time tile-dispatch events -> plan counters.

        A ``fallback`` event means a resolved plan tile did NOT legally
        apply at the call site (clamped to a non-dividing block, or a
        Pallas-eligible tile degraded to the reference lowering); counting
        it as ``tile_fallback`` makes ``plan_hit_rate`` reflect the tiles
        the compiled programs actually consumed, not just the plan-store
        lookups.
        """
        if event.get("fallback"):
            self.metrics.record_plan(event["phase"], event["kernel"],
                                     "tile_fallback")

    def _resolve_tiles(self, plans: TilePlan) -> None:
        """Resolve decode-path kernel tiles from the plan store. No sweeps."""
        from repro.launch.specs import resolve_model_tiles

        self.tiles, self.tile_resolutions = resolve_model_tiles(
            plans, self.cfg, self.slots, self.max_len, "decode",
            jnp.dtype(self.dtype).name, self.hardware)
        for kernel in self.tiles:
            res = self.tile_resolutions.get(kernel)
            self.metrics.record_plan(
                "decode", kernel, res.source if res else "fallback")

    def _prefill_fn(self, length: int):
        """The jitted prefill program for one admitted prompt length.

        Resolves the (batch=1, seq=length) prefill cell's kernel tiles from
        the plan (cached per length) and closes over them, so a bucketed
        shape family compiles once per bucket with the plan's exact tiles.
        """
        fn = self._prefill_fns.get(length)
        if fn is not None:
            return fn
        tiles: Dict[str, TileShape] = {}
        sources: Dict[str, str] = {}
        if self.plans is not None:
            from repro.launch.specs import resolve_model_tiles

            with warnings.catch_warnings():
                # Transfer warnings already fire once at plan resolution
                # inside resolve; accounting below records them as counters.
                warnings.simplefilter("ignore", PlanTransferWarning)
                tiles, resolutions = resolve_model_tiles(
                    self.plans, self.cfg, 1, length, "prefill",
                    jnp.dtype(self.dtype).name, self.hardware)
            sources = {
                kernel: (resolutions[kernel].source
                         if kernel in resolutions else "fallback")
                for kernel in tiles
            }
        else:
            from repro.launch.specs import kernel_problems

            sources = {
                kernel: "no_plan"
                for kernel in kernel_problems(self.cfg, 1, length, "prefill")
            }
        cfg, max_len, dtype = self.cfg, self.max_len, self.dtype
        fn = jax.jit(
            lambda p, batch: api.prefill(
                p, cfg, batch, max_len=max_len, dtype=dtype,
                ring_local=bool(cfg.attn_window), tiles=tiles or None)
        )
        self._prefill_fns[length] = fn
        self._prefill_sources[length] = sources
        return fn

    def add_request(self, prompt: np.ndarray, max_new_tokens: int = 16,
                    priority: int = 0,
                    deadline: float = math.inf) -> Optional[int]:
        """Submit a request; returns its rid, or None when admission control
        rejects it (queue full, prompt longer than every bucket edge, or the
        padded prompt plus the generation would overflow the KV cache)."""
        prompt = np.asarray(prompt, np.int32)
        shaped = self.scheduler.admit_length(len(prompt))
        # Decode writes KV at positions shaped..shaped+max_new-2 (the last
        # sampled token is never cached); past max_len the update would
        # silently clamp onto the final slot and corrupt attention.
        if shaped is None or shaped + max_new_tokens - 1 > self.max_len:
            self.metrics.record_reject()
            return None
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, prompt, max_new_tokens,
                      priority=priority, deadline=deadline)
        if not self.scheduler.submit(req):
            self.metrics.record_reject()
            return None
        self.metrics.record_submit(rid)
        return rid

    def _admit(self):
        free = [i for i, r in enumerate(self._active) if r is None]
        while free:
            req = self.scheduler.next_request()
            if req is None:
                break
            prompt = self.scheduler.prepare(req)
            prefill = self._prefill_fn(len(prompt))
            for kernel, source in self._prefill_sources[len(prompt)].items():
                self.metrics.record_plan("prefill", kernel, source)
            batch = {"tokens": jnp.asarray(prompt[None])}
            events = self._prefill_tile_events.get(len(prompt))
            if events is None:
                captured: List[Dict[str, Any]] = []
                with attn_mod.capture_tile_events(captured.append):
                    logits, state = prefill(self.params, batch)
                events = self._dedupe_events(captured)
                self._prefill_tile_events[len(prompt)] = events
            else:
                logits, state = prefill(self.params, batch)
            for ev in events:
                self._record_tile_event(ev)
            tok = int(jnp.argmax(logits[0, :self.cfg.vocab_size]))
            req.out_tokens.append(tok)
            self.metrics.record_first_token(req.rid, req.bucket)
            if len(req.out_tokens) >= req.max_new_tokens:
                # Satisfied by the prefill token alone — never occupy a
                # slot or run a decode step (which would also write KV one
                # position past the admission bound).
                req.done = True
                self._finished.append(req)
                self.metrics.record_complete()
                continue
            i = free.pop(0)
            self._active[i] = req
            self._states[i] = state

    def step(self) -> int:
        """Admit + one decode step for all active slots. Returns #active."""
        self._admit()
        self.metrics.record_queue_depth(self.scheduler.pending())
        n = 0
        active_buckets = []
        t0 = self._clock()
        for i, req in enumerate(self._active):
            if req is None:
                continue
            n += 1
            active_buckets.append(req.bucket)
            last = jnp.asarray([[req.out_tokens[-1]]], jnp.int32)
            if self._decode_tile_events is None:
                captured: List[Dict[str, Any]] = []
                with attn_mod.capture_tile_events(captured.append):
                    logits, self._states[i] = self._decode(
                        self.params, last, self._states[i])
                self._decode_tile_events = self._dedupe_events(captured)
                for ev in self._decode_tile_events:
                    self._record_tile_event(ev)
            else:
                logits, self._states[i] = self._decode(
                    self.params, last, self._states[i])
            tok = int(jnp.argmax(logits[0, :self.cfg.vocab_size]))
            req.out_tokens.append(tok)
            if len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                self._active[i] = None
                self._states[i] = None
                self._finished.append(req)
                self.metrics.record_complete()
        self.metrics.record_decode_step(active_buckets, self._clock() - t0)
        return n

    def run_until_done(self, max_steps: int = 1000) -> List[Request]:
        self._finished = []
        for _ in range(max_steps):
            if not any(self._active) and not self.scheduler.pending():
                break
            self.step()
        return self._finished
