"""Deterministic fault injection for the serving fleet.

The paper's claim — a tiling strategy tuned under one set of conditions
degrades when "external conditions were changed" — has a fleet-level
analogue: a placement made at admission degrades when the fleet itself
changes. This module scripts exactly those changes so they are
*replayable*: every fault fires at a scripted **router step number**, not
at a wall-clock instant, so two runs of the same script against the same
trace produce byte-identical schedules, recoveries, and exported traces
(the chaos bench pins this).

Vocabulary (``FaultEvent.action``):

- ``kill`` — the instance dies. The router's next ``step_all`` detects it
  as a liveness failure (stepping a killed engine raises
  :class:`EngineFault`), marks it ``dead``, and recovers its queued and
  in-flight requests onto survivors.
- ``stall`` — the instance keeps "stepping" but makes no progress (a hung
  accelerator, a livelocked host). Nothing raises: only the router's
  progress watchdog (steps-without-progress threshold) can detect it.
- ``degrade`` — the instance serves correctly but ``factor`` x slower.
  Pure clock-side: virtual-clock drivers read
  :meth:`FaultInjector.latency_factor` when advancing time; behavior and
  tokens are untouched.
- ``recover`` — undo a prior kill/stall/degrade on the instance (the
  router does NOT automatically re-trust it; requests already recovered
  stay recovered — this models a restarted process rejoining as healthy).
- ``drain`` — scripted graceful drain: the router calls
  ``FleetRouter.drain(instance)``.
- ``join`` — scripted elastic join: the router calls
  ``FleetRouter.join(instance, make_engine())`` — ``make_engine`` is the
  event's engine factory, invoked at the scripted step so construction
  cost lands where the scenario says it does.

No randomness anywhere: a :class:`FaultScript` is a plain sorted list of
events, and :class:`FaultInjector` is a step-indexed cursor over it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Set

ACTIONS = ("kill", "stall", "degrade", "recover", "drain", "join")


class EngineFault(RuntimeError):
    """Raised when a killed instance is stepped — the liveness signal the
    router converts into failure detection + request recovery."""

    def __init__(self, instance: str):
        super().__init__(f"engine {instance!r} is dead (injected fault)")
        self.instance = instance


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scripted fault: ``action`` hits ``instance`` at router ``step``.

    ``factor`` is the step-latency multiplier for ``degrade`` (ignored
    otherwise); ``make_engine`` is the zero-arg engine factory for
    ``join`` (required there, ignored otherwise).
    """

    step: int
    action: str
    instance: str
    factor: float = 1.0
    make_engine: Optional[Callable[[], Any]] = None

    def __post_init__(self):
        if self.action not in ACTIONS:
            raise ValueError(
                f"unknown fault action {self.action!r} (one of {ACTIONS})")
        if self.step < 0:
            raise ValueError(f"fault step must be >= 0: {self.step}")
        if self.action == "degrade" and self.factor <= 0:
            raise ValueError(f"degrade factor must be > 0: {self.factor}")
        if self.action == "join" and self.make_engine is None:
            raise ValueError("join events need a make_engine factory")


class FaultScript:
    """An ordered, replayable fault schedule.

    Events sort by (step, submission order) so two events at the same step
    apply in the order they were scripted — determinism is the contract.
    """

    def __init__(self, events: Sequence[FaultEvent] = ()):
        self.events: List[FaultEvent] = sorted(
            events, key=lambda e: e.step)
        # Stable sort keeps same-step submission order.

    def add(self, event: FaultEvent) -> "FaultScript":
        self.events = sorted(self.events + [event], key=lambda e: e.step)
        return self

    def events_at(self, step: int) -> List[FaultEvent]:
        return [e for e in self.events if e.step == step]

    def max_step(self) -> int:
        return self.events[-1].step if self.events else 0


class FaultInjector:
    """Step-indexed cursor over a :class:`FaultScript` plus the live fault
    state (which instances are currently killed / stalled / degraded).

    The router calls :meth:`advance` once at the top of every
    ``step_all``; the injector applies kill/stall/degrade/recover to its
    own state and returns ALL of the step's events so the router can act
    on ``drain``/``join`` and trace every injection. Virtual-clock
    drivers read :meth:`latency_factor` when advancing time.
    """

    def __init__(self, script: FaultScript):
        self.script = script
        self.killed: Set[str] = set()
        self.stalled: Set[str] = set()
        self.degraded: Dict[str, float] = {}
        self._cursor = 0

    def advance(self, step: int) -> List[FaultEvent]:
        """Apply every scripted event with ``event.step <= step`` that has
        not fired yet; returns them in firing order."""
        fired: List[FaultEvent] = []
        while (self._cursor < len(self.script.events)
               and self.script.events[self._cursor].step <= step):
            ev = self.script.events[self._cursor]
            self._cursor += 1
            if ev.action == "kill":
                self.killed.add(ev.instance)
                self.stalled.discard(ev.instance)
            elif ev.action == "stall":
                self.stalled.add(ev.instance)
            elif ev.action == "degrade":
                self.degraded[ev.instance] = float(ev.factor)
            elif ev.action == "recover":
                self.killed.discard(ev.instance)
                self.stalled.discard(ev.instance)
                self.degraded.pop(ev.instance, None)
            # drain/join mutate the router, not the injector.
            fired.append(ev)
        return fired

    def is_killed(self, instance: str) -> bool:
        return instance in self.killed

    def is_stalled(self, instance: str) -> bool:
        return instance in self.stalled

    def latency_factor(self, instance: str) -> float:
        """Step-latency multiplier for virtual-clock drivers (1.0 =
        healthy)."""
        return self.degraded.get(instance, 1.0)
