"""Hardware-aware fleet router: one engine per accelerator model.

The paper's cross-model result — the optimal tile on one GPU model is not
the optimal tile on another — has a fleet-level corollary: once tiles are
per-model, *cost* is per-model, so the cheapest placement for a request
depends on which hardware the fleet offers and on the request's shape
bucket. The router makes that concrete:

* it holds one :class:`~repro.serve.engine.ServeEngine` per
  :class:`~repro.core.HardwareModel`;
* it prices every ``(bucket, hardware)`` pair with the PR-1 plan + analytic
  cost model — prefill at the bucket edge plus ``max_new_tokens`` decode
  steps, each from the *per-hardware* resolved tiles;
* it routes each request to the instance minimizing
  ``service_estimate * (1 + backlog/slots)`` — the cost-model-optimal
  placement, discounted for instances that are already loaded.

Because memory-bound cells favor high-bandwidth models and compute-bound
cells favor high-FLOPs models, different buckets of the *same* workload
route to different hardware (``placement_table`` exposes the pure-cost
ranking; ``tile_table`` shows the per-model tiles that drive it).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, List, Mapping, Optional, Tuple

import jax.numpy as jnp

from repro.core import registry
from repro.core.plans import PlanTransferWarning, score_tile
from repro.serve.engine import Request, ServeEngine
from repro.serve.faults import EngineFault, FaultInjector
from repro.serve.metrics import nearest_rank
from repro.serve.scheduler import BucketPolicy


class FleetExhausted(RuntimeError):
    """``run_until_done`` hit ``max_steps`` with work still pending.

    Previously the router returned silently in this situation, so callers
    could read a partial result set as a complete run. Now the exhaustion
    is explicit, carrying the per-instance residue so the operator can see
    WHERE the fleet wedged (``pending`` maps instance -> in-flight/queued
    counts; ``orphans`` counts evicted requests awaiting a healthy home).
    """

    def __init__(self, max_steps: int, pending: Dict[str, Dict[str, int]],
                 orphans: int = 0):
        self.max_steps = max_steps
        self.pending = pending
        self.orphans = orphans
        detail = "; ".join(
            f"{name}: {c['in_flight']} in-flight + {c['queued']} queued"
            for name, c in sorted(pending.items()))
        if orphans:
            detail = (detail + "; " if detail else "") + f"{orphans} orphaned"
        super().__init__(
            f"fleet not drained after {max_steps} steps ({detail})")


@dataclasses.dataclass
class _FleetRequest:
    """Fleet-level identity for one request, stable across retries.

    Engines hand out per-engine rids; the fleet keys every request by a
    fleet id (fid) so a request that dies with its instance and re-queues
    on a survivor is still THE SAME request — same original prompt, same
    submit-time TTFT anchor, one results() entry."""

    fid: int
    prompt: Any                       # raw (unpadded) prompt tokens
    max_new_tokens: int
    priority: int
    deadline: float
    submit_t: Optional[float]         # original submit time (TTFT anchor)
    instance: str                     # current (or last) placement
    rid: int                          # rid on that instance
    retries: int = 0                  # recovery attempts consumed
    tokens_discarded: int = 0         # generated-then-lost token count
    lost: bool = False                # retry budget exhausted


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    """Where one request went and why."""

    rid: int
    instance: str
    bucket: int
    score: float                      # chosen instance's loaded score
    scores: Tuple[Tuple[str, float], ...]  # all (instance, loaded score)
    fid: Optional[int] = None         # fleet-level id (stable across retries)


@dataclasses.dataclass(frozen=True)
class RollDecision:
    """One instance's plan-rollout outcome (``FleetRouter.roll_plans``)."""

    instance: str
    pre_p95: float                    # probe p95 TTFT before the swap (s)
    post_p95: float                   # probe p95 TTFT after the swap (s)
    rolled_back: bool
    # True when either probe window outgrew the metrics' circular sample
    # buffer: the window silently misses samples, so the guard treated it
    # as thin (no confident keep/revert) rather than reading it.
    clipped: bool = False


class FleetRouter:
    """Route requests across per-hardware engines by plan-resolved cost."""

    def __init__(self, engines: Mapping[str, ServeEngine],
                 policy: BucketPolicy, tracer=None,
                 watchdog_threshold: int = 8, retry_budget: int = 2,
                 injector: Optional[FaultInjector] = None,
                 autoscaler=None):
        if not engines:
            raise ValueError("FleetRouter needs at least one engine")
        self.engines: Dict[str, ServeEngine] = dict(engines)
        self.policy = policy
        # Fleet-level trace process (repro.obs.trace): routing and plan-
        # rollout decisions as instants. None = tracing off, zero cost.
        self._trace = (tracer.attach("router", kind="router")
                       if tracer is not None else None)
        self.decisions: List[RouteDecision] = []
        # Router-level rejections (no engine was ever asked): reason -> n.
        self.rejects: Dict[str, int] = {}
        # Plan-rollout audit trail (roll_plans appends one entry per
        # instance swapped or reverted).
        self.roll_history: List[RollDecision] = []
        # (instance, kind, length) -> estimated seconds; pure function of
        # the plan + cost model, so cache freely.
        self._cell_cost: Dict[Tuple[str, str, int], float] = {}
        # -- fault tolerance ------------------------------------------------
        # Scripted fault source (kill/stall/degrade/drain/join at step N);
        # None = no injection, everything below still guards real faults.
        self.injector = injector
        # Consecutive no-progress steps (with work pending) before the
        # watchdog declares an instance stalled and evicts its work.
        self.watchdog_threshold = watchdog_threshold
        # Recovery attempts per request before it is declared lost.
        self.retry_budget = retry_budget
        # instance -> "live" | "stalled" | "dead" | "draining" | "drained".
        # Only "live" instances take new work; "draining" finish in place.
        self.status: Dict[str, str] = {name: "live" for name in self.engines}
        # instance -> (last progress reading, consecutive stuck steps).
        # Progress = tokens_out + chunks_run: multi-chunk prefills emit no
        # tokens for many steps, so chunk completions must count.
        self._progress: Dict[str, Tuple[int, int]] = {}
        # fid -> fleet record; (instance, rid) -> fid. The rid mapping is
        # popped when a request leaves an instance (eviction/steal) and
        # re-added at its new home, so finished rids resolve forever.
        self._fleet: Dict[int, _FleetRequest] = {}
        self._rid_map: Dict[Tuple[str, int], int] = {}
        self._next_fid = 0
        # Evicted requests awaiting a healthy instance (retried each step).
        self._orphans: List[_FleetRequest] = []
        self._steps = 0
        self.recoveries = 0
        self.steals = 0
        self.lost = 0
        # Finished results preserved across join-time engine replacement:
        # fid -> tokens. A joiner reusing a dead instance's name replaces
        # the engine object, and with it the old engine's _finished list —
        # requests that completed BEFORE the failure must stay resolvable
        # through results() or the zero-loss invariant silently breaks.
        self._retired_results: Dict[int, List[int]] = {}
        # instance -> status it held when it failed ("live"/"draining").
        # A scripted recover restores THIS status, so an instance that
        # stalled mid-drain resumes draining instead of re-entering
        # rotation (which would cancel the drain the operator requested).
        self._pre_fail: Dict[str, str] = {}
        # -- autoscaling ----------------------------------------------------
        # AutoscalePolicy (repro.serve.autoscale) or None. Consulted at the
        # end of every step_all, after recovery/steal/drain bookkeeping.
        self.autoscaler = autoscaler
        # Powered instance-steps (live + draining), the capacity-cost
        # denominator the autoscale bench compares against a static fleet.
        self.instance_steps = 0
        # Cumulative routed-traffic mix: bucket -> count, plus the
        # max_new_tokens running sum — the policy windows these to price
        # candidates against the CURRENT mix, not a static ranking.
        self._mix_counts: Dict[int, int] = {}
        self._mix_new_tokens = 0
        self._mix_n = 0
        # Pricing engines for scale candidates (one per candidate, built
        # lazily, never joined or stepped — they only feed the cost model).
        self._cand_engines: Dict[str, ServeEngine] = {}

    # -- cost model ----------------------------------------------------------
    def _phase_cost(self, name: str, kind: str, length: int) -> float:
        return self._phase_cost_for(self.engines[name], kind, length, name)

    def _phase_cost_for(self, eng: ServeEngine, kind: str, length: int,
                        cache_name: str) -> float:
        """Estimated seconds of one prefill (kind="prefill" for monolithic,
        "chunked_prefill" for the chunk-decomposed cell, "packed_prefill"
        for the step-packed cell, all batch 1) or one decode step
        (kind="decode", the engine's slot batch) on ``eng``.

        ``eng`` need not be a fleet member: the autoscaler prices *scale
        candidates* through the same path, each against its own plan
        artifact and hardware (``cache_name`` keys the cost cache — member
        names for fleet engines, ``"cand:<name>"`` for candidates).

        The packed cell is scored against a fixed round of
        ``PACK_ROUND_SEGS`` segments (that is what makes pack widths
        comparable in the sweep), so its score is divided back to ONE
        request here — keeping every kind's cost in per-request seconds.
        """
        key = (cache_name, kind, length)
        hit = self._cell_cost.get(key)
        if hit is not None:
            return hit
        from repro.kernels.flash_attention.ops import PACK_ROUND_SEGS
        from repro.launch.specs import kernel_problems

        batch = eng.slots if kind == "decode" else 1
        dtype = jnp.dtype(eng.dtype).name
        total = 0.0
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", PlanTransferWarning)
            for kernel, problem in kernel_problems(
                    eng.cfg, batch, length, kind).items():
                res = (eng.plans.resolve(kernel, problem, dtype, eng.hardware)
                       if eng.plans is not None else None)
                if res is not None:
                    score = res.score_s
                else:
                    tile = registry.get(kernel).default_tile(problem, dtype)
                    score = score_tile(kernel, tile, problem, dtype,
                                       eng.hardware)
                if kernel == "packed_prefill":
                    score /= PACK_ROUND_SEGS
                total += score
        self._cell_cost[key] = total
        return total

    def service_score(self, name: str, bucket: int,
                      max_new_tokens: int) -> float:
        """Estimated service seconds for one request of this bucket.

        Chunk-prefill engines price the prefill through the plan's
        ``chunked_prefill`` cell — the chunk-decomposed cost, including the
        per-chunk dispatch overhead the chunk length was tuned against —
        and step-packing engines through the ``packed_prefill`` cell,
        whose per-step dispatch cost is amortized over the plan's pack
        width — so the estimate reflects how each engine will actually run
        the request.
        """
        return self.service_score_for(self.engines[name], bucket,
                                      max_new_tokens, cache_name=name)

    def service_score_for(self, eng: ServeEngine, bucket: int,
                          max_new_tokens: int,
                          cache_name: Optional[str] = None) -> float:
        """:meth:`service_score` for an arbitrary engine — fleet member or
        not. The autoscaler prices scale *candidates* here, so a joiner's
        cost comes from its own plan artifact before it ever joins."""
        if cache_name is None:
            cache_name = f"id:{id(eng)}"
        prefill_kind = ("packed_prefill" if eng.pack_prefill
                        else "chunked_prefill" if eng.chunk_prefill
                        else "prefill")
        return (self._phase_cost_for(eng, prefill_kind, bucket, cache_name)
                + max_new_tokens
                * self._phase_cost_for(eng, "decode", eng.max_len,
                                       cache_name))

    def _load(self, name: str) -> float:
        """Backlog pressure in slot-equivalents.

        Unchunked engines count every queued request as one monolithic unit
        of head-of-line work. Chunk-prefill engines hold an admitted prompt
        for only one chunk at a time (urgent work overtakes between
        chunks), so a queued request contributes its *chunk fraction* —
        chunk_len / admitted length — and routing stops over-penalizing
        instances that merely hold long prompts.
        """
        eng = self.engines[name]
        busy = sum(r is not None for r in eng._active)
        if not eng.chunk_prefill:
            return (busy + eng.scheduler.pending()) / max(eng.slots, 1)
        frac = float(len(eng._ready))
        for job in eng._chunking:
            frac += job.chunk_len / max(len(job.prompt), 1)
        for req in eng._held:
            bucket = req.bucket or len(req.prompt)
            frac += eng.chunk_len_for(bucket) / max(bucket, 1)
        queued = getattr(eng.scheduler, "queued_buckets", None)
        if queued is None:
            frac += eng.scheduler.pending()
        else:
            for bucket in queued():
                frac += eng.chunk_len_for(bucket) / max(bucket, 1)
        return (busy + frac) / max(eng.slots, 1)

    # -- observability -------------------------------------------------------
    def _routable(self) -> List[str]:
        """Instances that can take new work right now (status "live").
        Dead/drained/stalled members keep their engines around for result
        resolution but must never be *recommended* — a placement table
        pointing at a dead instance is an operator trap."""
        return [n for n in sorted(self.engines) if self.status[n] == "live"]

    def placement_table(self, max_new_tokens: int = 16) -> Dict[int, str]:
        """Pure-cost best ROUTABLE instance per bucket edge (no load term)
        — the paper's per-model-optimum claim at placement granularity.
        Empty when no instance is live."""
        live = self._routable()
        if not live:
            return {}
        table = {}
        for edge in self.policy.edges:
            table[edge] = min(
                live,
                key=lambda n: (self.service_score(n, edge, max_new_tokens), n))
        return table

    def tile_table(self, bucket: int) -> Dict[str, Dict[str, str]]:
        """routable instance -> kernel -> resolved prefill tile at this
        bucket edge (exposes that the same shape wants different tiles per
        model)."""
        from repro.launch.specs import resolve_model_tiles

        out: Dict[str, Dict[str, str]] = {}
        for name in self._routable():
            eng = self.engines[name]
            if eng.plans is None:
                continue
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", PlanTransferWarning)
                tiles, _ = resolve_model_tiles(
                    eng.plans, eng.cfg, 1, bucket, "prefill",
                    jnp.dtype(eng.dtype).name, eng.hardware)
            out[name] = {k: str(t) for k, t in tiles.items()}
        return out

    # -- routing -------------------------------------------------------------
    def route(self, prompt, max_new_tokens: int = 16, priority: int = 0,
              deadline: float = float("inf")) -> Optional[RouteDecision]:
        """Admit one request on the cheapest healthy instance; None when
        rejected everywhere. An engine-level rejection (queue full,
        over-length for that engine's policy) fails over to the next-best
        instance by loaded score instead of dropping the request; only when
        EVERY healthy instance rejects is the terminal reason counted in
        ``self.rejects`` — never dropped silently."""
        bucket, reason = self.policy.admit(len(prompt))
        if bucket is None:
            self.rejects[reason] = self.rejects.get(reason, 0) + 1
            if self._trace is not None:
                self._trace.route_reject(reason)
            return None
        live = [n for n in self.engines if self.status[n] == "live"]
        if not live:
            reason = "no_healthy_instance"
            self.rejects[reason] = self.rejects.get(reason, 0) + 1
            if self._trace is not None:
                self._trace.route_reject(reason)
            return None
        scores = tuple(sorted(
            (name,
             self.service_score(name, bucket, max_new_tokens)
             * (1.0 + self._load(name)))
            for name in live))
        reason = "engine_reject"
        for name, score in sorted(scores, key=lambda kv: (kv[1], kv[0])):
            eng = self.engines[name]
            rid = eng.add_request(
                prompt, max_new_tokens=max_new_tokens, priority=priority,
                deadline=deadline)
            if rid is None:
                reason = getattr(eng, "last_reject_reason", reason)
                continue
            fid = self._register_admit(name, rid, prompt, max_new_tokens,
                                       priority, deadline)
            # Traffic-mix accounting (admits only, never retries/steals —
            # a recovered request is the same traffic, not new demand).
            self._mix_counts[bucket] = self._mix_counts.get(bucket, 0) + 1
            self._mix_new_tokens += max_new_tokens
            self._mix_n += 1
            decision = RouteDecision(
                rid=rid, instance=name, bucket=bucket,
                score=score, scores=scores, fid=fid)
            self.decisions.append(decision)
            if self._trace is not None:
                self._trace.route(rid, name, bucket, decision.score)
            return decision
        self.rejects[reason] = self.rejects.get(reason, 0) + 1
        if self._trace is not None:
            self._trace.route_reject(reason)
        return None

    def _register_admit(self, name: str, rid: int, prompt,
                        max_new_tokens: int, priority: int,
                        deadline: float) -> int:
        """Mint a fleet id for a freshly admitted request, anchoring its
        original submit time (the TTFT anchor recovery preserves)."""
        fid = self._next_fid
        self._next_fid += 1
        self._fleet[fid] = _FleetRequest(
            fid=fid, prompt=prompt, max_new_tokens=max_new_tokens,
            priority=priority, deadline=deadline,
            submit_t=self.engines[name].metrics.submit_time(rid),
            instance=name, rid=rid)
        self._rid_map[(name, rid)] = fid
        return fid

    def placements(self) -> Dict[int, Dict[str, int]]:
        """bucket -> instance -> routed request count (from the live run)."""
        out: Dict[int, Dict[str, int]] = {}
        for d in self.decisions:
            out.setdefault(d.bucket, {}).setdefault(d.instance, 0)
            out[d.bucket][d.instance] += 1
        return out

    # -- execution -----------------------------------------------------------
    def step_all(self) -> int:
        """One engine step on every healthy instance; returns total pending
        work (active slots + partial prefills + orphans awaiting a home).

        This is also the fault-tolerance heartbeat: scripted faults fire
        here (deterministically, keyed by step count — replayable), killed
        instances are detected by liveness (stepping one raises/flags), and
        stalled instances by the progress watchdog. Either way the failed
        instance's queued AND in-flight requests are evicted, re-queued on
        survivors under the retry budget, and re-prefilled from their
        original prompts with submit-anchored TTFT. Work stealing then
        rebalances queued requests from busy to idle live instances."""
        self._steps += 1
        if self.injector is not None:
            for ev in self.injector.advance(self._steps):
                if self._trace is not None:
                    self._trace.fault(ev.action, ev.instance, ev.step,
                                      ev.factor)
                if ev.action == "drain":
                    self.drain(ev.instance)
                elif ev.action == "join":
                    self.join(ev.instance, ev.make_engine())
                elif (ev.action == "recover"
                      and self.status.get(ev.instance) == "stalled"):
                    # The wedge cleared; the instance was already evicted,
                    # so it rejoins empty. Recovery restores the status it
                    # held BEFORE the stall: an instance that stalled while
                    # draining resumes draining (and, being empty, retires
                    # on this step's _finish_drains) instead of silently
                    # re-entering rotation and cancelling the drain.
                    self.status[ev.instance] = self._pre_fail.pop(
                        ev.instance, "live")
                    self._progress.pop(ev.instance, None)
        total = 0
        for name in sorted(self.engines):
            st = self.status[name]
            if st in ("dead", "drained", "stalled"):
                continue
            # Powered instance-step: this member occupies hardware this
            # step whether it is serving or finishing a drain.
            self.instance_steps += 1
            inj = self.injector
            if inj is not None and inj.is_killed(name):
                self._mark_failed(name, "dead", via="liveness")
                continue
            eng = self.engines[name]
            if inj is not None and inj.is_stalled(name):
                # Wedged, not dead: the step is a no-op — it holds its
                # state and makes no progress, so only the watchdog (not
                # liveness) can catch it.
                total += eng.in_flight()
                self._watch(name)
                continue
            try:
                total += eng.step()
            except EngineFault:
                self._mark_failed(name, "dead", via="liveness")
                continue
            self._watch(name)
        self._requeue_orphans()
        self._steal()
        self._finish_drains()
        if self.autoscaler is not None:
            self.autoscaler.observe(self, self._steps)
        return total + len(self._orphans)

    def _watch(self, name: str) -> None:
        """Progress watchdog: an instance with work pending that makes no
        progress (no new tokens, no chunk completions) for
        ``watchdog_threshold`` consecutive steps is declared stalled and
        its work evicted for recovery. Chunk completions count as progress
        because a multi-chunk prefill legitimately emits no tokens for
        many steps."""
        eng = self.engines[name]
        progress = eng.metrics.tokens_out + eng.metrics.chunks_run
        last, stuck = self._progress.get(name, (progress, 0))
        if eng.in_flight() or eng.scheduler.pending():
            stuck = stuck + 1 if progress == last else 0
        else:
            stuck = 0
        self._progress[name] = (progress, stuck)
        if (stuck >= self.watchdog_threshold
                and self.status[name] in ("live", "draining")):
            self._mark_failed(name, "stalled", via="watchdog")

    def _mark_failed(self, name: str, status: str, via: str) -> None:
        """Take an instance out of rotation and orphan its entire resident
        request set (queued + in-flight) for recovery on survivors. Pool
        pages are released refcount-balanced by the eviction; recovery
        re-prefills from original prompts, never from the dead caches."""
        self._pre_fail[name] = self.status[name]
        self.status[name] = status
        self._progress.pop(name, None)
        if self._trace is not None:
            self._trace.fault_detected(name, status, via)
        for req in self.engines[name].evict_all():
            self._absorb(name, req, failure=True)

    def _absorb(self, name: str, req: Request, *, failure: bool) -> None:
        """Fold one evicted engine request back into fleet bookkeeping.
        ``failure=True`` (kill/stall) consumes a retry and accounts the
        discarded generated tokens; ``failure=False`` (drain handoff,
        steal) moves the request for free."""
        fid = self._rid_map.pop((name, req.rid), None)
        if fid is None:
            # Directly-added request (bypassed route()): synthesize a fleet
            # record from the evicted Request — the prompt is the raw
            # unpadded one and the submit anchor was stashed at eviction.
            fr = _FleetRequest(
                fid=self._next_fid, prompt=req.prompt,
                max_new_tokens=req.max_new_tokens, priority=req.priority,
                deadline=req.deadline, submit_t=req.submit_t,
                instance=name, rid=req.rid)
            self._next_fid += 1
            self._fleet[fr.fid] = fr
        else:
            fr = self._fleet[fid]
        if failure:
            fr.retries += 1
            fr.tokens_discarded += len(req.out_tokens)
            if fr.retries > self.retry_budget:
                fr.lost = True
                self.lost += 1
                self.rejects["retry_budget"] = (
                    self.rejects.get("retry_budget", 0) + 1)
                if self._trace is not None:
                    self._trace.recover_fail(fr.fid, "retry_budget",
                                             fr.retries)
                return
        self._orphans.append(fr)

    def _requeue_orphans(self) -> None:
        """Re-place evicted requests on the cheapest live instance, keeping
        the original submit time as the TTFT anchor (recovered requests pay
        their true end-to-end latency, including the failed attempt).
        Requests no live instance will take stay orphaned and are retried
        every step."""
        if not self._orphans:
            return
        live = [n for n in self.engines if self.status[n] == "live"]
        if not live:
            return
        still: List[_FleetRequest] = []
        for fr in self._orphans:
            bucket, _ = self.policy.admit(len(fr.prompt))
            if bucket is None:
                bucket = len(fr.prompt)
            ranked = sorted(
                ((self.service_score(n, bucket, fr.max_new_tokens)
                  * (1.0 + self._load(n)), n) for n in live))
            src = fr.instance
            for _score, name in ranked:
                rid = self.engines[name].add_request(
                    fr.prompt, max_new_tokens=fr.max_new_tokens,
                    priority=fr.priority, deadline=fr.deadline,
                    submit_t=fr.submit_t)
                if rid is None:
                    continue
                fr.instance, fr.rid = name, rid
                self._rid_map[(name, rid)] = fr.fid
                self.recoveries += 1
                if self._trace is not None:
                    self._trace.recover(fr.fid, src, name, rid, fr.retries,
                                        fr.tokens_discarded)
                break
            else:
                still.append(fr)
        self._orphans = still

    # -- drain / join / steal ------------------------------------------------
    def drain(self, name: str) -> int:
        """Gracefully retire an instance: stop admission, hand its queued
        (not-yet-started) requests to the rest of the fleet for free — no
        retry consumed, drain is not a failure — and let in-flight work
        finish in place. The instance flips to "drained" once empty
        (``_finish_drains`` on the step loop). Returns the handoff count."""
        if self.status.get(name) not in ("live",):
            return 0
        self.status[name] = "draining"
        handoff = self.engines[name].extract_queued()
        if self._trace is not None:
            self._trace.drain_begin(name, len(handoff))
        for req in handoff:
            self._absorb(name, req, failure=False)
        self._requeue_orphans()
        return len(handoff)

    def _finish_drains(self) -> None:
        for name in sorted(self.engines):
            if self.status[name] != "draining":
                continue
            eng = self.engines[name]
            if not eng.in_flight() and not eng.scheduler.pending():
                self.status[name] = "drained"
                if self._trace is not None:
                    self._trace.drain_done(name)

    def join(self, name: str, engine: ServeEngine) -> None:
        """Add an instance mid-run. The engine carries its own
        HardwareModel and plan artifact, so its plan cells resolve for its
        own hardware — a heterogeneous joiner prices (and runs) every
        bucket with its own tiles, and routing starts sending it work on
        the next ``route``/steal. Reusing the name of a dead or drained
        instance replaces it — but never its history: results that
        finished on the old engine BEFORE it failed are retired into fleet
        bookkeeping first, so ``results()`` keeps resolving them."""
        if name in self.engines and self.status.get(name) not in (
                "dead", "drained"):
            raise ValueError(f"instance {name!r} is already active")
        old = self.engines.get(name)
        if old is not None:
            for req in old._finished:
                fid = self._rid_map.pop((name, req.rid), None)
                if fid is not None:
                    self._retired_results[fid] = list(req.out_tokens)
        self.engines[name] = engine
        self.status[name] = "live"
        self._progress.pop(name, None)
        self._pre_fail.pop(name, None)
        for key in [k for k in self._cell_cost if k[0] == name]:
            del self._cell_cost[key]
        if self._trace is not None:
            self._trace.join(name, engine.hardware.name)

    def _steal(self) -> None:
        """Rebalance between steps: an idle live instance (nothing queued,
        free slots) pulls the most urgent queued request from the most
        backlogged live instance. The move is free (no retry) and keeps the
        original submit anchor, so stolen requests' TTFT reflects their
        full wait. Deterministic: sorted iteration, max-backlog source."""
        live = [n for n in sorted(self.engines) if self.status[n] == "live"]
        if len(live) < 2:
            return
        for dst in live:
            deng = self.engines[dst]
            if deng.scheduler.pending() or deng.in_flight() >= deng.slots:
                continue
            srcs = [n for n in live
                    if n != dst and self.engines[n].scheduler.pending() > 0]
            if not srcs:
                continue
            src = max(srcs, key=lambda n: (
                self.engines[n].scheduler.pending(), n))
            seng = self.engines[src]
            req = seng.scheduler.next_request()
            if req is None:
                continue
            seng._evict_state(req)
            fid = self._rid_map.pop((src, req.rid), None)
            if fid is None:
                self._absorb(src, req, failure=False)
                fr = self._orphans.pop()
            else:
                fr = self._fleet[fid]
            rid = deng.add_request(
                fr.prompt, max_new_tokens=fr.max_new_tokens,
                priority=fr.priority, deadline=fr.deadline,
                submit_t=fr.submit_t)
            if rid is None:
                self._orphans.append(fr)   # re-placed next step
                continue
            fr.instance, fr.rid = dst, rid
            self._rid_map[(dst, rid)] = fr.fid
            self.steals += 1
            if self._trace is not None:
                self._trace.steal(fr.fid, src, dst)

    # -- autoscale adapter protocol ------------------------------------------
    # The surface repro.serve.autoscale.AutoscalePolicy consumes. Kept
    # deliberately small and duck-typed so the million-request queueing
    # simulator in benchmarks/bench_autoscale.py can implement the same
    # protocol and exercise the REAL policy without real engines.
    def live_instances(self) -> List[str]:
        return [n for n in sorted(self.engines) if self.status[n] == "live"]

    def known_instances(self) -> set:
        return set(self.engines)

    def instance_hardware(self, name: str) -> Optional[str]:
        eng = self.engines.get(name)
        return eng.hardware.name if eng is not None else None

    def queue_depths(self) -> Dict[str, int]:
        """Queued (admitted-but-not-started) requests per instance — the
        backlog the policy reads as load pressure."""
        return {name: eng.scheduler.pending()
                for name, eng in sorted(self.engines.items())}

    def ttft_marks(self) -> Dict[str, Dict[object, int]]:
        """Opaque cursor for :meth:`ttft_window_since` (per-instance
        ``ServeMetrics.ttft_counts`` marks)."""
        return {name: eng.metrics.ttft_counts()
                for name, eng in self.engines.items()}

    def ttft_window_since(self, marks) -> Tuple[List[float], bool]:
        """First-token latencies recorded fleet-wide since ``marks``
        (None = everything), plus a flag when any instance's circular
        sample buffer outgrew the window (the window silently misses
        samples — the policy treats its p95 as untrustworthy only insofar
        as it is surfaced in the decision's signal snapshot)."""
        samples: List[float] = []
        clipped = False
        for name, eng in sorted(self.engines.items()):
            mark = (marks or {}).get(name)
            s, c = eng.metrics.ttft_window(mark)
            samples.extend(s)
            clipped = clipped or c
        return samples, clipped

    def traffic_mix(self) -> Tuple[Dict[int, int], int, int]:
        """Cumulative routed mix: (bucket -> admits, sum of
        max_new_tokens, admit count). The policy windows successive
        snapshots to price capacity against CURRENT demand."""
        return dict(self._mix_counts), self._mix_new_tokens, self._mix_n

    def pool_occupancy(self) -> float:
        """Max used/total page fraction over live paged instances (0.0
        when nothing is paged) — KV-pressure trigger for scale-up."""
        occ = 0.0
        for name in self.live_instances():
            pool = self.engines[name].pool
            if pool is not None and pool.n_pages:
                occ = max(occ, pool.used_pages / pool.n_pages)
        return occ

    def orphan_count(self) -> int:
        return len(self._orphans)

    def price_instance(self, name: str, mix: Mapping[int, int],
                       avg_new_tokens: int) -> float:
        """Mix-weighted service seconds per request on a fleet member."""
        return self._mix_price(self.engines[name], mix, avg_new_tokens, name)

    def price_candidate(self, candidate, mix: Mapping[int, int],
                        avg_new_tokens: int) -> float:
        """Mix-weighted service seconds per request on a scale candidate,
        from the candidate's OWN plan artifact — one pricing engine is
        built per candidate and cached; it never joins and never steps."""
        eng = self._cand_engines.get(candidate.name)
        if eng is None:
            eng = candidate.make_engine(f"price:{candidate.name}")
            self._cand_engines[candidate.name] = eng
        return self._mix_price(eng, mix, avg_new_tokens,
                               f"cand:{candidate.name}")

    def _mix_price(self, eng: ServeEngine, mix: Mapping[int, int],
                   avg_new_tokens: int, cache_name: str) -> float:
        """Expected service seconds over a bucket mix; empty mix (no
        traffic observed yet) prices a uniform mix over the bucket edges."""
        if not mix:
            mix = {edge: 1 for edge in self.policy.edges}
        total_w = sum(mix.values())
        return sum(
            w * self.service_score_for(eng, b, avg_new_tokens, cache_name)
            for b, w in sorted(mix.items())) / max(total_w, 1)

    def scale_join(self, name: str, engine: ServeEngine) -> None:
        self.join(name, engine)

    def scale_drain(self, name: str) -> None:
        self.drain(name)

    def record_autoscale(self, decision) -> None:
        """Trace hook: every policy decision lands on the fleet lane with
        the full signal snapshot that triggered it."""
        if self._trace is not None:
            self._trace.autoscale(decision.action, decision.instance,
                                  decision.hardware, decision.reason,
                                  decision.signals)

    def pending(self) -> int:
        return (sum(eng.scheduler.pending() for eng in self.engines.values())
                + len(self._orphans))

    def run_until_done(self, max_steps: int = 1000
                       ) -> Dict[str, List[Request]]:
        """Drain every instance with interleaved steps (lockstep), so one
        engine's backlog never inflates another's wall-clock TTFT/TPOT.

        Raises :class:`FleetExhausted` when ``max_steps`` elapse with work
        still resident — a partial result set must never read as a
        complete run."""
        for _ in range(max_steps):
            if not self.step_all() and not self.pending():
                break
        else:
            work = {name: {"in_flight": eng.in_flight(),
                           "queued": eng.scheduler.pending()}
                    for name, eng in sorted(self.engines.items())
                    if eng.in_flight() or eng.scheduler.pending()}
            if work or self._orphans:
                raise FleetExhausted(max_steps, work, len(self._orphans))
        return {name: list(eng._finished)
                for name, eng in self.engines.items()}

    def results(self) -> Dict[int, List[int]]:
        """fid -> generated tokens for every finished request the fleet
        tracks (routed or absorbed). The basis for the chaos bench's
        zero-loss / zero-duplication / token-parity assertions: each fid
        appears at most once because rid mappings move with the request
        (and results retired at join-time replacement stay resolvable)."""
        out: Dict[int, List[int]] = dict(self._retired_results)
        for name, eng in self.engines.items():
            for req in eng._finished:
                fid = self._rid_map.get((name, req.rid))
                if fid is not None:
                    out[fid] = list(req.out_tokens)
        return out

    # -- versioned plan rollout ----------------------------------------------
    def roll_plans(self, artifact, drive_fn=None, tolerance: float = 1.10,
                   min_window: int = 4) -> List[RollDecision]:
        """Roll a (refined) plan artifact across the fleet, one instance at
        a time, with a p95-TTFT rollback guard.

        Per instance: ``drive_fn(name)`` (when given) pushes probe traffic
        through that engine BEFORE the swap — the pre-swap p95-TTFT window —
        then the engine is swapped via :meth:`ServeEngine.set_plans` and the
        SAME probe runs again. If the post-swap window regresses past
        ``tolerance`` x the pre-swap p95 (both windows holding at least
        ``min_window`` first-token samples — a thin window must never
        trigger a revert), the instance rolls back to its old artifact.
        A window that outgrew the metrics' circular sample buffer
        (``ttft_window`` reports it ``clipped``) silently misses samples
        and is treated exactly like a thin one: no confident keep/revert,
        the swap stands unguarded and the decision is marked ``clipped``.
        Either way the outcome lands in ``self.roll_history`` and the
        per-instance cost cache is invalidated (costs are a function of the
        plan). Without a ``drive_fn`` the swap is unguarded — every
        instance just moves to the new artifact.
        """
        decisions: List[RollDecision] = []
        for name in sorted(self.engines):
            eng = self.engines[name]
            old = eng.plans
            pre_p95, n_pre, pre_clip = 0.0, 0, False
            if drive_fn is not None:
                mark = eng.metrics.ttft_counts()
                drive_fn(name)
                samples, pre_clip = eng.metrics.ttft_window(mark)
                pre_p95 = nearest_rank(samples, 0.95)
                n_pre = len(samples)
            mark = eng.metrics.ttft_counts()
            eng.set_plans(artifact)
            self._cell_cost.clear()
            post_p95, n_post, post_clip = 0.0, 0, False
            if drive_fn is not None:
                drive_fn(name)
                samples, post_clip = eng.metrics.ttft_window(mark)
                post_p95 = nearest_rank(samples, 0.95)
                n_post = len(samples)
            clipped = pre_clip or post_clip
            rolled_back = (drive_fn is not None and not clipped
                           and n_pre >= min_window and n_post >= min_window
                           and pre_p95 > 0.0
                           and post_p95 > tolerance * pre_p95)
            if rolled_back:
                eng.set_plans(old)
                self._cell_cost.clear()
            decision = RollDecision(instance=name, pre_p95=pre_p95,
                                    post_p95=post_p95,
                                    rolled_back=rolled_back,
                                    clipped=clipped)
            self.roll_history.append(decision)
            decisions.append(decision)
            if self._trace is not None:
                self._trace.roll(name, pre_p95, post_p95, rolled_back,
                                 clipped)
        return decisions

    def metrics(self) -> Dict[str, dict]:
        out = {name: eng.metrics.as_dict()
               for name, eng in self.engines.items()}
        out["router"] = {
            "routed": len(self.decisions),
            "rejects": dict(sorted(self.rejects.items())),
            "placements": {str(b): dict(sorted(p.items()))
                           for b, p in sorted(self.placements().items())},
        }
        out["fleet"] = {
            "status": dict(sorted(self.status.items())),
            "recoveries": self.recoveries,
            "steals": self.steals,
            "lost": self.lost,
            "orphans": len(self._orphans),
            "tokens_discarded": sum(fr.tokens_discarded
                                    for fr in self._fleet.values()),
            "instance_steps": self.instance_steps,
        }
        if self.autoscaler is not None:
            out["autoscale"] = self.autoscaler.as_dict()
        return out
