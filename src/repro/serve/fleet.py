"""Hardware-aware fleet router: one engine per accelerator model.

The paper's cross-model result — the optimal tile on one GPU model is not
the optimal tile on another — has a fleet-level corollary: once tiles are
per-model, *cost* is per-model, so the cheapest placement for a request
depends on which hardware the fleet offers and on the request's shape
bucket. The router makes that concrete:

* it holds one :class:`~repro.serve.engine.ServeEngine` per
  :class:`~repro.core.HardwareModel`;
* it prices every ``(bucket, hardware)`` pair with the PR-1 plan + analytic
  cost model — prefill at the bucket edge plus ``max_new_tokens`` decode
  steps, each from the *per-hardware* resolved tiles;
* it routes each request to the instance minimizing
  ``service_estimate * (1 + backlog/slots)`` — the cost-model-optimal
  placement, discounted for instances that are already loaded.

Because memory-bound cells favor high-bandwidth models and compute-bound
cells favor high-FLOPs models, different buckets of the *same* workload
route to different hardware (``placement_table`` exposes the pure-cost
ranking; ``tile_table`` shows the per-model tiles that drive it).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Mapping, Optional, Tuple

import jax.numpy as jnp

from repro.core import registry
from repro.core.plans import PlanTransferWarning, score_tile
from repro.serve.engine import Request, ServeEngine
from repro.serve.metrics import nearest_rank
from repro.serve.scheduler import BucketPolicy


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    """Where one request went and why."""

    rid: int
    instance: str
    bucket: int
    score: float                      # chosen instance's loaded score
    scores: Tuple[Tuple[str, float], ...]  # all (instance, loaded score)


@dataclasses.dataclass(frozen=True)
class RollDecision:
    """One instance's plan-rollout outcome (``FleetRouter.roll_plans``)."""

    instance: str
    pre_p95: float                    # probe p95 TTFT before the swap (s)
    post_p95: float                   # probe p95 TTFT after the swap (s)
    rolled_back: bool
    # True when either probe window outgrew the metrics' circular sample
    # buffer: the window silently misses samples, so the guard treated it
    # as thin (no confident keep/revert) rather than reading it.
    clipped: bool = False


class FleetRouter:
    """Route requests across per-hardware engines by plan-resolved cost."""

    def __init__(self, engines: Mapping[str, ServeEngine],
                 policy: BucketPolicy, tracer=None):
        if not engines:
            raise ValueError("FleetRouter needs at least one engine")
        self.engines: Dict[str, ServeEngine] = dict(engines)
        self.policy = policy
        # Fleet-level trace process (repro.obs.trace): routing and plan-
        # rollout decisions as instants. None = tracing off, zero cost.
        self._trace = (tracer.attach("router", kind="router")
                       if tracer is not None else None)
        self.decisions: List[RouteDecision] = []
        # Router-level rejections (no engine was ever asked): reason -> n.
        self.rejects: Dict[str, int] = {}
        # Plan-rollout audit trail (roll_plans appends one entry per
        # instance swapped or reverted).
        self.roll_history: List[RollDecision] = []
        # (instance, kind, length) -> estimated seconds; pure function of
        # the plan + cost model, so cache freely.
        self._cell_cost: Dict[Tuple[str, str, int], float] = {}

    # -- cost model ----------------------------------------------------------
    def _phase_cost(self, name: str, kind: str, length: int) -> float:
        """Estimated seconds of one prefill (kind="prefill" for monolithic,
        "chunked_prefill" for the chunk-decomposed cell, "packed_prefill"
        for the step-packed cell, all batch 1) or one decode step
        (kind="decode", the engine's slot batch) on ``name``.

        The packed cell is scored against a fixed round of
        ``PACK_ROUND_SEGS`` segments (that is what makes pack widths
        comparable in the sweep), so its score is divided back to ONE
        request here — keeping every kind's cost in per-request seconds.
        """
        key = (name, kind, length)
        hit = self._cell_cost.get(key)
        if hit is not None:
            return hit
        from repro.kernels.flash_attention.ops import PACK_ROUND_SEGS
        from repro.launch.specs import kernel_problems

        eng = self.engines[name]
        batch = eng.slots if kind == "decode" else 1
        dtype = jnp.dtype(eng.dtype).name
        total = 0.0
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", PlanTransferWarning)
            for kernel, problem in kernel_problems(
                    eng.cfg, batch, length, kind).items():
                res = (eng.plans.resolve(kernel, problem, dtype, eng.hardware)
                       if eng.plans is not None else None)
                if res is not None:
                    score = res.score_s
                else:
                    tile = registry.get(kernel).default_tile(problem, dtype)
                    score = score_tile(kernel, tile, problem, dtype,
                                       eng.hardware)
                if kernel == "packed_prefill":
                    score /= PACK_ROUND_SEGS
                total += score
        self._cell_cost[key] = total
        return total

    def service_score(self, name: str, bucket: int,
                      max_new_tokens: int) -> float:
        """Estimated service seconds for one request of this bucket.

        Chunk-prefill engines price the prefill through the plan's
        ``chunked_prefill`` cell — the chunk-decomposed cost, including the
        per-chunk dispatch overhead the chunk length was tuned against —
        and step-packing engines through the ``packed_prefill`` cell,
        whose per-step dispatch cost is amortized over the plan's pack
        width — so the estimate reflects how each engine will actually run
        the request.
        """
        eng = self.engines[name]
        prefill_kind = ("packed_prefill" if eng.pack_prefill
                        else "chunked_prefill" if eng.chunk_prefill
                        else "prefill")
        return (self._phase_cost(name, prefill_kind, bucket)
                + max_new_tokens
                * self._phase_cost(name, "decode", eng.max_len))

    def _load(self, name: str) -> float:
        """Backlog pressure in slot-equivalents.

        Unchunked engines count every queued request as one monolithic unit
        of head-of-line work. Chunk-prefill engines hold an admitted prompt
        for only one chunk at a time (urgent work overtakes between
        chunks), so a queued request contributes its *chunk fraction* —
        chunk_len / admitted length — and routing stops over-penalizing
        instances that merely hold long prompts.
        """
        eng = self.engines[name]
        busy = sum(r is not None for r in eng._active)
        if not eng.chunk_prefill:
            return (busy + eng.scheduler.pending()) / max(eng.slots, 1)
        frac = float(len(eng._ready))
        for job in eng._chunking:
            frac += job.chunk_len / max(len(job.prompt), 1)
        for req in eng._held:
            bucket = req.bucket or len(req.prompt)
            frac += eng.chunk_len_for(bucket) / max(bucket, 1)
        queued = getattr(eng.scheduler, "queued_buckets", None)
        if queued is None:
            frac += eng.scheduler.pending()
        else:
            for bucket in queued():
                frac += eng.chunk_len_for(bucket) / max(bucket, 1)
        return (busy + frac) / max(eng.slots, 1)

    # -- observability -------------------------------------------------------
    def placement_table(self, max_new_tokens: int = 16) -> Dict[int, str]:
        """Pure-cost best instance per bucket edge (no load term) — the
        paper's per-model-optimum claim at placement granularity."""
        table = {}
        for edge in self.policy.edges:
            table[edge] = min(
                self.engines,
                key=lambda n: (self.service_score(n, edge, max_new_tokens), n))
        return table

    def tile_table(self, bucket: int) -> Dict[str, Dict[str, str]]:
        """instance -> kernel -> resolved prefill tile at this bucket edge
        (exposes that the same shape wants different tiles per model)."""
        from repro.launch.specs import resolve_model_tiles

        out: Dict[str, Dict[str, str]] = {}
        for name, eng in self.engines.items():
            if eng.plans is None:
                continue
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", PlanTransferWarning)
                tiles, _ = resolve_model_tiles(
                    eng.plans, eng.cfg, 1, bucket, "prefill",
                    jnp.dtype(eng.dtype).name, eng.hardware)
            out[name] = {k: str(t) for k, t in tiles.items()}
        return out

    # -- routing -------------------------------------------------------------
    def route(self, prompt, max_new_tokens: int = 16, priority: int = 0,
              deadline: float = float("inf")) -> Optional[RouteDecision]:
        """Admit one request on the cheapest instance; None when rejected.
        Router-level rejections (over-length prompt under a no-overflow
        policy) are counted in ``self.rejects`` — never dropped silently."""
        bucket, reason = self.policy.admit(len(prompt))
        if bucket is None:
            self.rejects[reason] = self.rejects.get(reason, 0) + 1
            if self._trace is not None:
                self._trace.route_reject(reason)
            return None
        scores = tuple(sorted(
            (name,
             self.service_score(name, bucket, max_new_tokens)
             * (1.0 + self._load(name)))
            for name in self.engines))
        name = min(scores, key=lambda kv: (kv[1], kv[0]))[0]
        rid = self.engines[name].add_request(
            prompt, max_new_tokens=max_new_tokens, priority=priority,
            deadline=deadline)
        if rid is None:
            return None
        decision = RouteDecision(
            rid=rid, instance=name, bucket=bucket,
            score=dict(scores)[name], scores=scores)
        self.decisions.append(decision)
        if self._trace is not None:
            self._trace.route(rid, name, bucket, decision.score)
        return decision

    def placements(self) -> Dict[int, Dict[str, int]]:
        """bucket -> instance -> routed request count (from the live run)."""
        out: Dict[int, Dict[str, int]] = {}
        for d in self.decisions:
            out.setdefault(d.bucket, {}).setdefault(d.instance, 0)
            out[d.bucket][d.instance] += 1
        return out

    # -- execution -----------------------------------------------------------
    def step_all(self) -> int:
        """One engine step on every instance; returns total active slots."""
        return sum(eng.step() for eng in self.engines.values())

    def pending(self) -> int:
        return sum(eng.scheduler.pending() for eng in self.engines.values())

    def run_until_done(self, max_steps: int = 1000
                       ) -> Dict[str, List[Request]]:
        """Drain every instance with interleaved steps (lockstep), so one
        engine's backlog never inflates another's wall-clock TTFT/TPOT."""
        for _ in range(max_steps):
            if not self.step_all() and not self.pending():
                break
        return {name: list(eng._finished)
                for name, eng in self.engines.items()}

    # -- versioned plan rollout ----------------------------------------------
    def roll_plans(self, artifact, drive_fn=None, tolerance: float = 1.10,
                   min_window: int = 4) -> List[RollDecision]:
        """Roll a (refined) plan artifact across the fleet, one instance at
        a time, with a p95-TTFT rollback guard.

        Per instance: ``drive_fn(name)`` (when given) pushes probe traffic
        through that engine BEFORE the swap — the pre-swap p95-TTFT window —
        then the engine is swapped via :meth:`ServeEngine.set_plans` and the
        SAME probe runs again. If the post-swap window regresses past
        ``tolerance`` x the pre-swap p95 (both windows holding at least
        ``min_window`` first-token samples — a thin window must never
        trigger a revert), the instance rolls back to its old artifact.
        A window that outgrew the metrics' circular sample buffer
        (``ttft_window`` reports it ``clipped``) silently misses samples
        and is treated exactly like a thin one: no confident keep/revert,
        the swap stands unguarded and the decision is marked ``clipped``.
        Either way the outcome lands in ``self.roll_history`` and the
        per-instance cost cache is invalidated (costs are a function of the
        plan). Without a ``drive_fn`` the swap is unguarded — every
        instance just moves to the new artifact.
        """
        decisions: List[RollDecision] = []
        for name in sorted(self.engines):
            eng = self.engines[name]
            old = eng.plans
            pre_p95, n_pre, pre_clip = 0.0, 0, False
            if drive_fn is not None:
                mark = eng.metrics.ttft_counts()
                drive_fn(name)
                samples, pre_clip = eng.metrics.ttft_window(mark)
                pre_p95 = nearest_rank(samples, 0.95)
                n_pre = len(samples)
            mark = eng.metrics.ttft_counts()
            eng.set_plans(artifact)
            self._cell_cost.clear()
            post_p95, n_post, post_clip = 0.0, 0, False
            if drive_fn is not None:
                drive_fn(name)
                samples, post_clip = eng.metrics.ttft_window(mark)
                post_p95 = nearest_rank(samples, 0.95)
                n_post = len(samples)
            clipped = pre_clip or post_clip
            rolled_back = (drive_fn is not None and not clipped
                           and n_pre >= min_window and n_post >= min_window
                           and pre_p95 > 0.0
                           and post_p95 > tolerance * pre_p95)
            if rolled_back:
                eng.set_plans(old)
                self._cell_cost.clear()
            decision = RollDecision(instance=name, pre_p95=pre_p95,
                                    post_p95=post_p95,
                                    rolled_back=rolled_back,
                                    clipped=clipped)
            self.roll_history.append(decision)
            decisions.append(decision)
            if self._trace is not None:
                self._trace.roll(name, pre_p95, post_p95, rolled_back,
                                 clipped)
        return decisions

    def metrics(self) -> Dict[str, dict]:
        out = {name: eng.metrics.as_dict()
               for name, eng in self.engines.items()}
        out["router"] = {
            "routed": len(self.decisions),
            "rejects": dict(sorted(self.rejects.items())),
            "placements": {str(b): dict(sorted(p.items()))
                           for b, p in sorted(self.placements().items())},
        }
        return out
