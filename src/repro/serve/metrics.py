"""Runtime serving telemetry: per-bucket latency, queue depth, plan counters.

One :class:`ServeMetrics` instance rides along with a ``ServeEngine`` (the
fleet router aggregates one per instance). Everything is plain Python — no
jax — so recording on the request path costs nanoseconds and the whole
object exports as a dict (``as_dict``) for logging / the launcher to print.

Measured quantities follow serving convention:

* **TTFT** (time to first token): request *submit* -> end of the prefill
  that produced the request's first token, per bucket. Submit-anchored on
  purpose: with chunked prefill a request's first token can trail its
  admission by many engine steps, and measuring from admission would hide
  exactly the queueing the chunk scheduler manages. Means come with
  p50/p95/p99 — tail latency is what head-of-line blocking moves.
* **TPOT** (time per output token): decode-step wall time divided by the
  number of active slots, attributed to each active request's bucket.
* **Queue depth**: scheduler backlog sampled at every engine step AND at
  every admit/reject, so backlog accrued while an engine sits idle between
  steps is visible instead of silently missing.
* **Plan counters**: how each kernel-tile lookup was satisfied — ``exact``,
  ``nearest_shape``, ``cross_hardware`` (the paper's transferred-optimum
  case), ``fallback`` (heuristic default), or ``no_plan`` — split by phase
  (``prefill`` / ``decode``). ``plan_hit_rate()`` is the exact-hit fraction,
  the quantity the shape-bucketed scheduler exists to maximize.
* **Chunked prefill**: per-chunk queue age (gap since the request last made
  prefill progress), a chunks-per-prefill histogram, a packed-chunks-per-
  step histogram (how many prefill chunks rode each packed step), and
  per-step mixed token counts. Rejections carry an explicit reason
  (``over_length`` / ``queue_full`` / ``cache_overflow``) — admission
  never drops silently.
* **Shadow execution**: ``record_shadow`` keeps per-(kernel, tile) timing
  stats for the candidate tiles the engine measures on diverted steps (see
  ``repro.serve.refine``) next to the incumbent's, so the telemetry export
  carries the raw material the :class:`~repro.serve.refine.PlanRefiner`
  re-ranks from. ``ttft_counts``/``ttft_window``/``ttft_p95`` support
  windowed p95 reads (samples since a marked count), the rollback guard's
  regression signal; a window wider than the retained circular buffer is
  flagged ``clipped`` so guards don't act on a corrupted window.

* **Paged KV pool**: page alloc/free counts, copy-on-write splits,
  shared-prefix lookup/hit counts with tokens-reused, and pool occupancy
  samples (peak + mean pages in use) — the ``repro.serve.pool`` health
  readout (``prefix_hit_rate`` is the fleet-wide prefill-dedup win).

Metrics are aggregates; the causal, per-event record (which requests shared
a packed step, which plan entry resolved each kernel launch, where a chunk
sat queued) is the trace layer — see :mod:`repro.obs.trace` and the
``python -m repro.launch.trace_report`` CLI. ``as_dict()`` output is
deterministic (sorted keys, stable nesting) and stamped with
``metrics_schema`` = :data:`METRICS_SCHEMA_VERSION` so golden tests and CI
artifact diffs are ordering-insensitive.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import Counter, defaultdict
from typing import Callable, Dict, List, Optional, Tuple

# Resolution sources, in decreasing order of trustworthiness. "fallback" is
# the heuristic default tile (plan had nothing usable); "tile_fallback"
# means a resolved tile did not legally apply at the kernel call site (the
# lowering degraded to a reference path or an adjusted chunk — see
# ``models.attention.capture_tile_events``); "no_plan" means the engine was
# constructed without an artifact at all.
PLAN_SOURCES = ("exact", "nearest_shape", "cross_hardware", "fallback",
                "tile_fallback", "no_plan")

# Bump on any change to the ``as_dict()`` layout (keys, nesting, units) so
# downstream consumers of exported metrics artifacts can gate on it.
# v2: added the "pool" section (paged KV pool occupancy, prefix reuse,
# copy-on-write splits).
METRICS_SCHEMA_VERSION = 2


def nearest_rank(xs: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 1]) over ``xs`` (0.0 if empty).

    The single percentile definition shared by ``_LatencyStat``, the
    windowed TTFT reads, ``FleetRouter.roll_plans`` and the trace-report
    CLI — one formula, so a trace's span durations reproduce the metrics'
    percentiles exactly.
    """
    if not xs:
        return 0.0
    ordered = sorted(xs)
    return ordered[max(0, math.ceil(q * len(ordered)) - 1)]


@dataclasses.dataclass
class _LatencyStat:
    count: int = 0
    total_s: float = 0.0
    max_s: float = 0.0
    # Raw samples for percentiles, capped to bound memory on long runs:
    # beyond the cap the buffer is circular, so percentiles describe the
    # most recent ``sample_cap`` observations (a sliding window) while
    # count/mean/max keep covering the whole run.
    samples: List[float] = dataclasses.field(default_factory=list)
    sample_cap: int = 8192

    def record(self, dt: float) -> None:
        self.count += 1
        self.total_s += dt
        self.max_s = max(self.max_s, dt)
        if len(self.samples) < self.sample_cap:
            self.samples.append(dt)
        else:
            # count was already incremented: sample #count lives at slot
            # (count - 1) % cap, keeping the window exactly the newest cap.
            self.samples[(self.count - 1) % self.sample_cap] = dt

    @property
    def mean_s(self) -> float:
        return self.total_s / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile over the recorded samples (0 if none)."""
        return nearest_rank(self.samples, q / 100.0)

    def recent(self, n: int) -> List[float]:
        """The newest ``n`` samples, oldest first (bounded by the window)."""
        n = min(n, len(self.samples))
        if n <= 0:
            return []
        if len(self.samples) < self.sample_cap:
            return self.samples[-n:]
        # Circular: the newest sample lives at (count - 1) % cap.
        return [self.samples[(self.count - n + i) % self.sample_cap]
                for i in range(n)]

    def as_dict(self) -> Dict[str, float]:
        return {"count": self.count, "mean_s": self.mean_s,
                "max_s": self.max_s,
                "p50_s": self.percentile(50),
                "p95_s": self.percentile(95),
                "p99_s": self.percentile(99)}


class ServeMetrics:
    """Mutable counters; ``clock`` is injectable for deterministic tests."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self.submitted = 0
        self.rejected = 0
        self.completed = 0
        self.tokens_out = 0
        self._submit_t: Dict[int, float] = {}          # rid -> submit time
        self.ttft: Dict[object, _LatencyStat] = defaultdict(_LatencyStat)
        self.tpot: Dict[object, _LatencyStat] = defaultdict(_LatencyStat)
        self.queue_depth_max = 0
        self._queue_depth_sum = 0
        self._queue_depth_n = 0
        # (phase, source) -> count and (phase, kernel) -> source breakdown.
        self.plan_counts: Counter = Counter()
        self.plan_by_kernel: Dict[str, Counter] = defaultdict(Counter)
        # Chunked-prefill telemetry.
        self.reject_reasons: Counter = Counter()
        self.chunks_run = 0
        self.chunk_age: Dict[object, _LatencyStat] = defaultdict(_LatencyStat)
        self.chunks_per_prefill: Counter = Counter()
        # Step packing: how many prefill chunks rode each packed step — the
        # occupancy histogram the packing bench uploads as a CI artifact.
        self.packed_chunks_per_step: Counter = Counter()
        # Shadow execution: per-(kernel, tile) measured timings from the
        # engine's diverted steps, plus which tile was the incumbent when
        # last measured. Keys are str(tile) so the export is JSON-clean.
        self.shadow_steps = 0
        self.shadow_time: Dict[tuple, _LatencyStat] = defaultdict(_LatencyStat)
        self.shadow_incumbents: Dict[str, str] = {}
        # Paged KV pool (repro.serve.pool): page churn, shared-prefix
        # reuse, copy-on-write splits, and occupancy samples.
        self.pool_page_allocs = 0
        self.pool_page_frees = 0
        self.pool_cow_splits = 0
        self.prefix_lookups = 0
        self.prefix_hits = 0
        self.prefix_tokens_reused = 0
        self.pool_used_max = 0
        self.pool_total = 0
        self._pool_used_sum = 0
        self._pool_used_n = 0

    # -- request lifecycle ---------------------------------------------------
    def record_submit(self, rid: int, t: Optional[float] = None) -> None:
        """Record one submit. ``t`` backdates the anchor: a request
        re-queued after an engine failure keeps its ORIGINAL submit time,
        so its recovered first token's TTFT covers the whole outage —
        tail metrics tell the truth across retries."""
        self.submitted += 1
        self._submit_t[rid] = self.clock() if t is None else t

    def drop_submit(self, rid: int) -> Optional[float]:
        """Forget a pending submit anchor (the request was evicted, stolen,
        or handed off before its first token here). Returns the dropped
        timestamp so fleet recovery can re-anchor it on the next engine;
        None (and a no-op) when the request already produced its first
        token."""
        return self._submit_t.pop(rid, None)

    def record_reject(self, bucket: Optional[object] = None,
                      reason: str = "admission") -> None:
        del bucket  # per-bucket reject split not tracked yet
        self.rejected += 1
        self.reject_reasons[reason] += 1

    def submit_time(self, rid: int) -> Optional[float]:
        """Submit timestamp of a not-yet-first-token request (else None)."""
        return self._submit_t.get(rid)

    def record_first_token(self, rid: int, bucket: object) -> None:
        self.tokens_out += 1   # prefill samples the request's first token
        t0 = self._submit_t.pop(rid, None)
        if t0 is not None:
            self.ttft[bucket].record(self.clock() - t0)

    def record_decode_step(self, buckets, dt: float) -> None:
        """One engine decode step over ``buckets`` (one entry per active
        slot); each slot produced one token in ``dt`` seconds total."""
        n = len(buckets)
        if not n:
            return
        per_tok = dt / n
        for b in buckets:
            self.tpot[b].record(per_tok)
        self.tokens_out += n

    def record_complete(self) -> None:
        self.completed += 1

    # -- chunked prefill -----------------------------------------------------
    def record_chunk(self, bucket: object, queue_age_s: float) -> None:
        """One prefill chunk ran; ``queue_age_s`` is how long the request
        sat without prefill progress before this chunk (submit -> first
        chunk, then chunk -> chunk) — the quantity the per-step token
        budget trades against decode latency."""
        self.chunks_run += 1
        self.chunk_age[bucket].record(queue_age_s)

    def record_prefill_chunks(self, n_chunks: int) -> None:
        """A request's prefill completed after ``n_chunks`` chunks."""
        self.chunks_per_prefill[n_chunks] += 1

    def record_packed_step(self, n_chunks: int) -> None:
        """A packed step ran ``n_chunks`` prefill chunks in one launch."""
        self.packed_chunks_per_step[n_chunks] += 1

    # -- shadow execution ----------------------------------------------------
    def record_shadow_step(self) -> None:
        """One engine step was diverted to shadow measurement."""
        self.shadow_steps += 1

    def record_shadow(self, kernel: str, tile, dt: float,
                      incumbent: bool = False) -> None:
        """One shadow measurement: ``tile`` (a dims tuple/TileShape) ran the
        ``kernel`` cell in ``dt`` measured seconds. ``incumbent`` marks the
        serving tile's own measurement, recorded next to each candidate's so
        the refiner's speedup gate compares like with like."""
        key = str(tuple(tile))
        self.shadow_time[(kernel, key)].record(dt)
        if incumbent:
            self.shadow_incumbents[kernel] = key

    # -- paged KV pool -------------------------------------------------------
    def record_page_alloc(self, n: int = 1) -> None:
        self.pool_page_allocs += n

    def record_page_free(self, n: int = 1) -> None:
        self.pool_page_frees += n

    def record_cow_split(self, n: int = 1) -> None:
        self.pool_cow_splits += n

    def record_prefix_lookup(self, hit_tokens: int) -> None:
        """One shared-prefix lookup; ``hit_tokens`` > 0 means the request
        mapped that many already-prefilled tokens instead of recomputing
        them (the fleet-wide prefill dedup win)."""
        self.prefix_lookups += 1
        if hit_tokens > 0:
            self.prefix_hits += 1
            self.prefix_tokens_reused += hit_tokens

    def record_pool(self, used: int, total: int) -> None:
        """One pool-occupancy sample (pages in use / pool size)."""
        self.pool_total = total
        self.pool_used_max = max(self.pool_used_max, used)
        self._pool_used_sum += used
        self._pool_used_n += 1

    def prefix_hit_rate(self) -> float:
        return (self.prefix_hits / self.prefix_lookups
                if self.prefix_lookups else 0.0)

    @property
    def pool_used_mean(self) -> float:
        return (self._pool_used_sum / self._pool_used_n
                if self._pool_used_n else 0.0)

    # -- TTFT windows (rollout guard) ----------------------------------------
    def ttft_counts(self) -> Dict[object, int]:
        """Per-bucket TTFT sample counts — a mark for windowed reads."""
        return {b: s.count for b, s in self.ttft.items()}

    def ttft_window(self, marks: Optional[Dict[object, int]] = None
                    ) -> "Tuple[List[float], bool]":
        """(samples recorded after ``marks``, clipped) — every bucket pooled.

        ``clipped`` is True when any bucket's window is wider than its
        retained circular buffer (``_LatencyStat.sample_cap``): the buffer
        overwrote samples inside the window, so the returned list silently
        misses observations. Guards (``FleetRouter.roll_plans``) must treat
        a clipped window as inconclusive rather than reading it as a
        faithful record. With no marks the window is the whole run, so
        clipping means "the run outgrew the buffer".
        """
        out: List[float] = []
        clipped = False
        for b, s in self.ttft.items():
            n_new = s.count - (marks.get(b, 0) if marks else 0)
            if n_new > len(s.samples):
                clipped = True
            out.extend(s.recent(n_new))
        return out, clipped

    def ttft_since(self, marks: Optional[Dict[object, int]] = None
                   ) -> List[float]:
        """All TTFT samples recorded after ``marks`` (every bucket pooled);
        with no marks, every retained sample. Bounded by the per-bucket
        sliding sample window — use :meth:`ttft_window` to learn whether
        the window was clipped by that bound."""
        return self.ttft_window(marks)[0]

    def ttft_p95(self, marks: Optional[Dict[object, int]] = None) -> float:
        """Nearest-rank p95 over the (windowed) pooled TTFT samples."""
        return nearest_rank(self.ttft_since(marks), 0.95)

    def record_queue_depth(self, depth: int) -> None:
        self.queue_depth_max = max(self.queue_depth_max, depth)
        self._queue_depth_sum += depth
        self._queue_depth_n += 1

    # -- plan resolution -----------------------------------------------------
    def record_plan(self, phase: str, kernel: str, source: str) -> None:
        if source not in PLAN_SOURCES:
            source = "fallback"
        self.plan_counts[(phase, source)] += 1
        self.plan_by_kernel[kernel][source] += 1

    def plan_hit_rate(self, phase: Optional[str] = None) -> float:
        """Exact-hit fraction over all recorded resolutions (0.0 if none)."""
        total = hits = 0
        for (ph, source), n in self.plan_counts.items():
            if phase is not None and ph != phase:
                continue
            total += n
            if source == "exact":
                hits += n
        return hits / total if total else 0.0

    # -- export --------------------------------------------------------------
    @property
    def queue_depth_mean(self) -> float:
        return (self._queue_depth_sum / self._queue_depth_n
                if self._queue_depth_n else 0.0)

    def as_dict(self) -> Dict[str, object]:
        plan = {src: 0 for src in PLAN_SOURCES}
        by_phase: Dict[str, Dict[str, int]] = defaultdict(
            lambda: {src: 0 for src in PLAN_SOURCES})
        for (phase, source), n in self.plan_counts.items():
            plan[source] += n
            by_phase[phase][source] += n
        return {
            "metrics_schema": METRICS_SCHEMA_VERSION,
            "requests": {
                "submitted": self.submitted,
                "rejected": self.rejected,
                "completed": self.completed,
                "tokens_out": self.tokens_out,
            },
            "rejects": dict(sorted(self.reject_reasons.items())),
            "queue_depth": {
                "max": self.queue_depth_max,
                "mean": self.queue_depth_mean,
            },
            "chunked_prefill": {
                "chunks_run": self.chunks_run,
                "chunks_per_prefill": {
                    str(n): c for n, c in
                    sorted(self.chunks_per_prefill.items())},
                "packed_chunks_per_step": {
                    str(n): c for n, c in
                    sorted(self.packed_chunks_per_step.items())},
                "chunk_age_s": {str(b): s.as_dict() for b, s in sorted(
                    self.chunk_age.items(), key=lambda kv: str(kv[0]))},
            },
            "shadow": {
                "steps": self.shadow_steps,
                "incumbents": dict(sorted(self.shadow_incumbents.items())),
                "samples": {
                    kernel: {
                        tile: stat.as_dict()
                        for (k, tile), stat in sorted(
                            self.shadow_time.items(),
                            key=lambda kv: kv[0]) if k == kernel
                    }
                    for kernel in sorted({k for k, _ in self.shadow_time})
                },
            },
            "pool": {
                "page_allocs": self.pool_page_allocs,
                "page_frees": self.pool_page_frees,
                "cow_splits": self.pool_cow_splits,
                "prefix_lookups": self.prefix_lookups,
                "prefix_hits": self.prefix_hits,
                "prefix_hit_rate": self.prefix_hit_rate(),
                "prefix_tokens_reused": self.prefix_tokens_reused,
                "pages_total": self.pool_total,
                "pages_used_max": self.pool_used_max,
                "pages_used_mean": self.pool_used_mean,
            },
            "ttft_s": {str(b): s.as_dict() for b, s in sorted(
                self.ttft.items(), key=lambda kv: str(kv[0]))},
            "tpot_s": {str(b): s.as_dict() for b, s in sorted(
                self.tpot.items(), key=lambda kv: str(kv[0]))},
            "plan": {
                "counts": plan,
                "by_phase": {k: dict(v) for k, v in sorted(by_phase.items())},
                "hit_rate": self.plan_hit_rate(),
                "hit_rate_prefill": self.plan_hit_rate("prefill"),
                "hit_rate_decode": self.plan_hit_rate("decode"),
                # Inner dicts sorted too: Counter order is insertion order,
                # which varies with resolution order across runs.
                "by_kernel": {
                    k: {s: c[s] for s in sorted(c)}
                    for k, c in sorted(self.plan_by_kernel.items())},
            },
        }

    def render(self) -> str:
        """Human-readable multi-line summary (the launcher prints this)."""
        d = self.as_dict()
        lines = [
            "serve metrics:",
            f"  requests: {d['requests']['submitted']} submitted, "
            f"{d['requests']['rejected']} rejected, "
            f"{d['requests']['completed']} completed, "
            f"{d['requests']['tokens_out']} tokens",
            f"  queue depth: max {d['queue_depth']['max']}, "
            f"mean {d['queue_depth']['mean']:.1f}",
            f"  plan hit rate: {d['plan']['hit_rate']:.2f} "
            f"(prefill {d['plan']['hit_rate_prefill']:.2f}, "
            f"decode {d['plan']['hit_rate_decode']:.2f}) "
            f"counts {d['plan']['counts']}",
        ]
        if d["rejects"]:
            lines.append(f"  rejects: {d['rejects']}")
        if self.chunks_run:
            lines.append(
                f"  chunked prefill: {self.chunks_run} chunks, "
                f"chunks/prefill "
                f"{d['chunked_prefill']['chunks_per_prefill']}")
        if self.packed_chunks_per_step:
            lines.append(
                f"  step packing: chunks/step "
                f"{d['chunked_prefill']['packed_chunks_per_step']}")
        if self.shadow_steps:
            lines.append(
                f"  shadow: {self.shadow_steps} diverted steps, "
                f"{len(self.shadow_time)} (kernel, tile) cells measured")
        if self.pool_total:
            lines.append(
                f"  kv pool: {self.pool_used_max}/{self.pool_total} pages "
                f"peak ({self.pool_used_mean:.1f} mean), "
                f"{self.pool_page_allocs} allocs / "
                f"{self.pool_page_frees} frees, "
                f"{self.pool_cow_splits} cow splits, "
                f"prefix hit rate {self.prefix_hit_rate():.2f} "
                f"({self.prefix_tokens_reused} tokens reused)")
        for label, table in (("ttft", d["ttft_s"]), ("tpot", d["tpot_s"])):
            for bucket, stat in table.items():
                lines.append(
                    f"  {label}[{bucket}]: n={stat['count']} "
                    f"mean={stat['mean_s'] * 1e3:.2f}ms "
                    f"p95={stat['p95_s'] * 1e3:.2f}ms "
                    f"max={stat['max_s'] * 1e3:.2f}ms")
        return "\n".join(lines)
