"""Runtime serving telemetry: per-bucket latency, queue depth, plan counters.

One :class:`ServeMetrics` instance rides along with a ``ServeEngine`` (the
fleet router aggregates one per instance). Everything is plain Python — no
jax — so recording on the request path costs nanoseconds and the whole
object exports as a dict (``as_dict``) for logging / the launcher to print.

Measured quantities follow serving convention:

* **TTFT** (time to first token): submit -> end of the prefill that produced
  the request's first token, per bucket.
* **TPOT** (time per output token): decode-step wall time divided by the
  number of active slots, attributed to each active request's bucket.
* **Queue depth**: scheduler backlog sampled at every engine step.
* **Plan counters**: how each kernel-tile lookup was satisfied — ``exact``,
  ``nearest_shape``, ``cross_hardware`` (the paper's transferred-optimum
  case), ``fallback`` (heuristic default), or ``no_plan`` — split by phase
  (``prefill`` / ``decode``). ``plan_hit_rate()`` is the exact-hit fraction,
  the quantity the shape-bucketed scheduler exists to maximize.
"""
from __future__ import annotations

import dataclasses
import time
from collections import Counter, defaultdict
from typing import Callable, Dict, Optional

# Resolution sources, in decreasing order of trustworthiness. "fallback" is
# the heuristic default tile (plan had nothing usable); "tile_fallback"
# means a resolved tile did not legally apply at the kernel call site (the
# lowering degraded to a reference path or an adjusted chunk — see
# ``models.attention.capture_tile_events``); "no_plan" means the engine was
# constructed without an artifact at all.
PLAN_SOURCES = ("exact", "nearest_shape", "cross_hardware", "fallback",
                "tile_fallback", "no_plan")


@dataclasses.dataclass
class _LatencyStat:
    count: int = 0
    total_s: float = 0.0
    max_s: float = 0.0

    def record(self, dt: float) -> None:
        self.count += 1
        self.total_s += dt
        self.max_s = max(self.max_s, dt)

    @property
    def mean_s(self) -> float:
        return self.total_s / self.count if self.count else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {"count": self.count, "mean_s": self.mean_s,
                "max_s": self.max_s}


class ServeMetrics:
    """Mutable counters; ``clock`` is injectable for deterministic tests."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self.submitted = 0
        self.rejected = 0
        self.completed = 0
        self.tokens_out = 0
        self._submit_t: Dict[int, float] = {}          # rid -> submit time
        self.ttft: Dict[object, _LatencyStat] = defaultdict(_LatencyStat)
        self.tpot: Dict[object, _LatencyStat] = defaultdict(_LatencyStat)
        self.queue_depth_max = 0
        self._queue_depth_sum = 0
        self._queue_depth_n = 0
        # (phase, source) -> count and (phase, kernel) -> source breakdown.
        self.plan_counts: Counter = Counter()
        self.plan_by_kernel: Dict[str, Counter] = defaultdict(Counter)

    # -- request lifecycle ---------------------------------------------------
    def record_submit(self, rid: int) -> None:
        self.submitted += 1
        self._submit_t[rid] = self.clock()

    def record_reject(self, bucket: Optional[object] = None) -> None:
        del bucket  # per-bucket reject split not tracked yet
        self.rejected += 1

    def record_first_token(self, rid: int, bucket: object) -> None:
        self.tokens_out += 1   # prefill samples the request's first token
        t0 = self._submit_t.pop(rid, None)
        if t0 is not None:
            self.ttft[bucket].record(self.clock() - t0)

    def record_decode_step(self, buckets, dt: float) -> None:
        """One engine decode step over ``buckets`` (one entry per active
        slot); each slot produced one token in ``dt`` seconds total."""
        n = len(buckets)
        if not n:
            return
        per_tok = dt / n
        for b in buckets:
            self.tpot[b].record(per_tok)
        self.tokens_out += n

    def record_complete(self) -> None:
        self.completed += 1

    def record_queue_depth(self, depth: int) -> None:
        self.queue_depth_max = max(self.queue_depth_max, depth)
        self._queue_depth_sum += depth
        self._queue_depth_n += 1

    # -- plan resolution -----------------------------------------------------
    def record_plan(self, phase: str, kernel: str, source: str) -> None:
        if source not in PLAN_SOURCES:
            source = "fallback"
        self.plan_counts[(phase, source)] += 1
        self.plan_by_kernel[kernel][source] += 1

    def plan_hit_rate(self, phase: Optional[str] = None) -> float:
        """Exact-hit fraction over all recorded resolutions (0.0 if none)."""
        total = hits = 0
        for (ph, source), n in self.plan_counts.items():
            if phase is not None and ph != phase:
                continue
            total += n
            if source == "exact":
                hits += n
        return hits / total if total else 0.0

    # -- export --------------------------------------------------------------
    @property
    def queue_depth_mean(self) -> float:
        return (self._queue_depth_sum / self._queue_depth_n
                if self._queue_depth_n else 0.0)

    def as_dict(self) -> Dict[str, object]:
        plan = {src: 0 for src in PLAN_SOURCES}
        by_phase: Dict[str, Dict[str, int]] = defaultdict(
            lambda: {src: 0 for src in PLAN_SOURCES})
        for (phase, source), n in self.plan_counts.items():
            plan[source] += n
            by_phase[phase][source] += n
        return {
            "requests": {
                "submitted": self.submitted,
                "rejected": self.rejected,
                "completed": self.completed,
                "tokens_out": self.tokens_out,
            },
            "queue_depth": {
                "max": self.queue_depth_max,
                "mean": self.queue_depth_mean,
            },
            "ttft_s": {str(b): s.as_dict() for b, s in sorted(
                self.ttft.items(), key=lambda kv: str(kv[0]))},
            "tpot_s": {str(b): s.as_dict() for b, s in sorted(
                self.tpot.items(), key=lambda kv: str(kv[0]))},
            "plan": {
                "counts": plan,
                "by_phase": {k: dict(v) for k, v in sorted(by_phase.items())},
                "hit_rate": self.plan_hit_rate(),
                "hit_rate_prefill": self.plan_hit_rate("prefill"),
                "hit_rate_decode": self.plan_hit_rate("decode"),
                "by_kernel": {k: dict(c) for k, c in sorted(
                    self.plan_by_kernel.items())},
            },
        }

    def render(self) -> str:
        """Human-readable multi-line summary (the launcher prints this)."""
        d = self.as_dict()
        lines = [
            "serve metrics:",
            f"  requests: {d['requests']['submitted']} submitted, "
            f"{d['requests']['rejected']} rejected, "
            f"{d['requests']['completed']} completed, "
            f"{d['requests']['tokens_out']} tokens",
            f"  queue depth: max {d['queue_depth']['max']}, "
            f"mean {d['queue_depth']['mean']:.1f}",
            f"  plan hit rate: {d['plan']['hit_rate']:.2f} "
            f"(prefill {d['plan']['hit_rate_prefill']:.2f}, "
            f"decode {d['plan']['hit_rate_decode']:.2f}) "
            f"counts {d['plan']['counts']}",
        ]
        for label, table in (("ttft", d["ttft_s"]), ("tpot", d["tpot_s"])):
            for bucket, stat in table.items():
                lines.append(
                    f"  {label}[{bucket}]: n={stat['count']} "
                    f"mean={stat['mean_s'] * 1e3:.2f}ms "
                    f"max={stat['max_s'] * 1e3:.2f}ms")
        return "\n".join(lines)
