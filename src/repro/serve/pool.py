"""Fleet-wide paged KV-cache pool with shared-prefix copy-on-write reuse.

Per-request KV caches reserve ``max_len`` tokens of HBM for the whole
request lifetime, so engine occupancy is bounded by how many full-size
caches fit — the ``prefill_slots`` ceiling the ROADMAP calls out. The pool
replaces that with vLLM-style paging: one shared set of physical pages per
engine (``[n_pages, Hkv, page, D]`` K/V arrays per attention layer), a
per-request **page table** mapping logical page index -> physical page id,
and refcounted alloc/free. A request holds only the pages it has actually
written, so many partially-prefilled requests coexist where whole-cache
reservations fit few.

**Page size is a plan cell** (``kv_page`` in kernels/flash_attention/ops.py):
the VMEM-bounded tile argument of the source paper applies to page geometry
exactly as to ``bkv``, so tpu_v5e and tpu_v6e resolve different page sizes
for the same cache length and the engine reads its page from the resolved
plan.

**Shared prefixes** prefill once fleet-wide: at prefill completion a
request registers its prompt (and every full-page-boundary prefix of it)
in a *weak* registry — ``(page id, generation)`` snapshots, no refcounts —
and a later request with an identical prefix maps those pages read-only
(refcount bump) and prefills only the divergent tail. Sharing is
copy-on-write: *any* write into a page with refcount > 1 (the recipient's
first divergent token, or the donor still decoding into its shared partial
tail page) first copies the page. Registry entries are validated lazily at
lookup (page still allocated, generation unchanged since the snapshot) so
registration never pins pages and refcounts balance to zero when the fleet
drains — the invariant ``check_balanced`` asserts in the property tests.

**Admission accounting** is reservation-based: each resident request
reserves its worst-case remaining demand (pages for prompt + max new
tokens, plus ``RESERVE_SLACK`` pages of copy-on-write headroom — a request
can split at most its one shared partial tail page as recipient and its
own registered tail page as donor). ``can_admit`` admits only when the
free list covers every resident's outstanding reservation plus the
newcomer's, so a mid-flight allocation can never fail; because pages are
allocated incrementally as chunks are written, actual occupancy tracks
written tokens, not reserved caches — the occupancy unlock.

All device-side state lives in ``self.arrays`` (a pytree mirroring the
model's cache segment structure; see ``transformer.make_paged_pool``) and
is threaded *functionally* through the jitted decode/prefill programs: the
engine passes ``pool.arrays`` in, the program returns the updated arrays,
and the engine stores them back. Host-side bookkeeping (tables, refcounts,
free list, prefix registry) is plain Python — nanoseconds per request, no
jax on the admission path.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig
from repro.core.tiling import cdiv


def supports_prefix_sharing(cfg: ArchConfig) -> bool:
    """Prefix reuse requires every layer's state for positions [0, hit) to
    live in pool pages. Attention layers (windowed included — their linear
    paged cache keeps the full prefix) qualify; recurrent/SSD layers carry
    non-addressable state a prefix hit would skip computing, so hybrids
    prefill every token themselves."""
    return all(spec.mixer in ("attn", "local_attn") for spec in cfg.layers())


@dataclasses.dataclass(frozen=True)
class _PrefixEntry:
    """Weak snapshot of the pages holding one registered token prefix."""
    length: int
    pages: Tuple[int, ...]
    gens: Tuple[int, ...]


class PagedKVPool:
    """Host-side page bookkeeping + device page arrays for one engine."""

    # Copy-on-write headroom reserved per request: at most one split as a
    # prefix recipient (its shared partial tail page) plus one as a donor
    # (its registered tail page, split when its own decode write lands in a
    # now-shared page).
    RESERVE_SLACK = 2

    # Weak prefix entries kept before the oldest is evicted.
    MAX_PREFIX_ENTRIES = 512

    def __init__(self, cfg: ArchConfig, *, n_pages: int, page: int,
                 max_len: int, dtype, prefix_sharing: bool = True,
                 metrics=None, trace=None):
        from repro.models import api

        if n_pages <= 0 or page <= 0:
            raise ValueError(f"bad pool geometry: {n_pages} pages of {page}")
        self.cfg = cfg
        self.page = int(page)
        self.n_pages = int(n_pages)
        self.max_len = int(max_len)
        # Static per-request page-table length: every jitted program sees
        # the same [n_pt] table shape regardless of how many pages are
        # actually mapped (unmapped entries point at physical page 0 and
        # are position-masked inside the kernels).
        self.n_pt = cdiv(max_len, page)
        self.arrays = api.make_paged_pool(cfg, n_pages, page, dtype)
        self.prefix_sharing = bool(prefix_sharing) and \
            supports_prefix_sharing(cfg)
        self.metrics = metrics
        self._trace = trace

        self.refcount: List[int] = [0] * self.n_pages
        # Bumped when a page returns to the free list, so a stale prefix
        # entry pointing at a recycled page id fails its generation check.
        self.generation: List[int] = [0] * self.n_pages
        self._free: List[int] = list(range(self.n_pages - 1, -1, -1))
        self.tables: Dict[int, List[int]] = {}
        self._need: Dict[int, int] = {}
        self._allocs: Dict[int, int] = {}
        self._prefix: "OrderedDict[Tuple[int, ...], _PrefixEntry]" = \
            OrderedDict()

    # -- occupancy ---------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    def pages_needed(self, total_tokens: int) -> int:
        return cdiv(max(int(total_tokens), 1), self.page)

    def _outstanding(self) -> int:
        """Worst-case future page demand of every resident request."""
        return sum(
            max(0, self._need[r] + self.RESERVE_SLACK - self._allocs[r])
            for r in self._need)

    # -- request lifecycle -------------------------------------------------
    def can_admit(self, total_tokens: int) -> bool:
        """True when admitting a request that will write ``total_tokens``
        positions can never exhaust the pool mid-flight."""
        need = self.pages_needed(total_tokens) + self.RESERVE_SLACK
        return need + self._outstanding() <= self.free_pages

    def register_request(self, rid: int, total_tokens: int) -> None:
        if rid in self.tables:
            raise ValueError(f"request {rid} already registered")
        self.tables[rid] = []
        self._need[rid] = self.pages_needed(total_tokens)
        self._allocs[rid] = 0

    def release(self, rid: int, missing_ok: bool = False) -> int:
        """Drop every page reference ``rid`` holds; pages whose refcount
        reaches zero return to the free list (generation bumped). Raises
        ``KeyError`` on an unknown/already-released rid — a double release
        is a lifecycle bug, never silent — unless ``missing_ok`` is set:
        the eviction path (engine fault recovery, request cancel) tears
        down requests that may sit anywhere in the admission pipeline,
        including stages that never registered with the pool, and must be
        idempotent. Returns pages freed."""
        if missing_ok and rid not in self.tables:
            return 0
        table = self.tables.pop(rid)
        del self._need[rid], self._allocs[rid]
        freed = 0
        for pid in table:
            if self.refcount[pid] <= 0:
                raise RuntimeError(
                    f"double free: page {pid} (rid {rid}) has refcount "
                    f"{self.refcount[pid]}")
            self.refcount[pid] -= 1
            if self.refcount[pid] == 0:
                self.generation[pid] += 1
                self._free.append(pid)
                freed += 1
        if self.metrics is not None:
            self.metrics.record_page_free(freed)
            self.metrics.record_pool(self.used_pages, self.n_pages)
        if self._trace is not None:
            self._trace.page_free(rid, freed, self.used_pages, self.n_pages)
        return freed

    # -- page allocation / copy-on-write -----------------------------------
    def _alloc(self, rid: int) -> int:
        if not self._free:
            raise RuntimeError(
                "paged KV pool exhausted — reservation accounting should "
                "make this unreachable (can_admit gate bypassed?)")
        pid = self._free.pop()
        assert self.refcount[pid] == 0, (pid, self.refcount[pid])
        self.refcount[pid] = 1
        self._allocs[rid] += 1
        if self.metrics is not None:
            self.metrics.record_page_alloc()
        return pid

    def prepare_span(self, rid: int, start: int, length: int) -> None:
        """Make positions ``[start, start+length)`` writable by ``rid``:
        allocate pages for unmapped logical indices and copy-on-write-split
        mapped pages whose refcount exceeds one (page copies are applied to
        the device arrays here). Must run before every cache write — chunk
        prefill and each decode step alike; writes are append-only, so the
        span starts at or before the table's current end."""
        if length <= 0:
            return
        table = self.tables[rid]
        first = start // self.page
        last = (start + length - 1) // self.page
        if first > len(table):
            raise ValueError(
                f"non-contiguous write: rid {rid} start {start} but only "
                f"{len(table)} pages mapped")
        copies: List[Tuple[int, int]] = []
        fresh = 0
        for idx in range(first, last + 1):
            if idx < len(table):
                pid = table[idx]
                if self.refcount[pid] > 1:
                    dst = self._alloc(rid)
                    self.refcount[pid] -= 1
                    table[idx] = dst
                    copies.append((pid, dst))
                    if self.metrics is not None:
                        self.metrics.record_cow_split()
                    if self._trace is not None:
                        self._trace.cow_split(rid, pid, dst)
            else:
                table.append(self._alloc(rid))
                fresh += 1
        if self.metrics is not None and (fresh or copies):
            self.metrics.record_pool(self.used_pages, self.n_pages)
        if self._trace is not None and fresh:
            self._trace.page_alloc(rid, fresh, self.used_pages, self.n_pages)
        self._apply_copies(copies)

    def _apply_copies(self, copies: List[Tuple[int, int]]) -> None:
        """Copy page contents src -> dst across every layer's K/V arrays.
        Eager device ops outside jit — a handful of page-sized copies per
        split, dispatched asynchronously."""
        if not copies:
            return
        import jax
        import jax.numpy as jnp

        src = jnp.asarray([s for s, _ in copies], jnp.int32)
        dst = jnp.asarray([d for _, d in copies], jnp.int32)

        def _copy(a):
            # Page axis: 0 for seq-segment leaves [n_pages, Hkv, page, D],
            # 1 for scan-segment leaves [reps, n_pages, Hkv, page, D].
            if a.ndim == 4:
                return a.at[dst].set(a[src])
            return a.at[:, dst].set(a[:, src])

        self.arrays = jax.tree.map(_copy, self.arrays)

    # -- device views ------------------------------------------------------
    def device_table(self, rid: int):
        """The request's page table as a device array of static length
        ``n_pt`` (unmapped tail entries point at physical page 0 — masked
        positionally inside the kernels)."""
        import jax.numpy as jnp

        table = self.tables[rid]
        return jnp.asarray(
            table + [0] * (self.n_pt - len(table)), jnp.int32)

    # -- shared prefixes ---------------------------------------------------
    def lookup_prefix(self, rid: int, tokens: Sequence[int]) -> int:
        """Map the longest valid registered prefix of ``tokens`` into
        ``rid``'s (empty) page table and return its token length (0 =
        miss). The hit is capped at ``len(tokens) - 1`` so at least one
        token always prefills — the request's first-token logits must come
        from its own forward pass. Invalid entries (donor pages freed or
        recycled since the snapshot) are dropped lazily here."""
        if not self.prefix_sharing:
            return 0
        table = self.tables[rid]
        assert not table, "lookup_prefix must precede any page mapping"
        hit = 0
        n_map = 0
        toks = tuple(int(t) for t in tokens)
        for ln in sorted({e.length for e in self._prefix.values()},
                         reverse=True):
            if ln > len(toks):
                continue
            key = toks[:ln]
            entry = self._prefix.get(key)
            if entry is None:
                continue
            if not self._entry_valid(entry):
                del self._prefix[key]
                continue
            hit = min(ln, len(toks) - 1)
            if hit <= 0:
                continue
            n_map = cdiv(hit, self.page)
            for pid in entry.pages[:n_map]:
                self.refcount[pid] += 1
                table.append(pid)
            break
        if self.metrics is not None:
            self.metrics.record_prefix_lookup(hit)
        if self._trace is not None and hit:
            self._trace.prefix_hit(rid, hit, n_map)
        return hit

    def register_prefix(self, rid: int, tokens: Sequence[int]) -> None:
        """Register ``rid``'s prefilled prompt as shareable: one weak entry
        per full-page boundary plus the whole prompt. Snapshots carry page
        generations — no refcounts — so the registry never delays a free."""
        if not self.prefix_sharing:
            return
        table = self.tables[rid]
        toks = tuple(int(t) for t in tokens)
        total = len(toks)
        if total < 2:
            return  # a 1-token prefix can never be reused (hit cap)
        lengths = list(range(self.page, total, self.page)) + [total]
        for ln in lengths:
            n_p = cdiv(ln, self.page)
            if n_p > len(table):
                break
            pages = tuple(table[:n_p])
            self._prefix[toks[:ln]] = _PrefixEntry(
                length=ln, pages=pages,
                gens=tuple(self.generation[p] for p in pages))
            self._prefix.move_to_end(toks[:ln])
        while len(self._prefix) > self.MAX_PREFIX_ENTRIES:
            self._prefix.popitem(last=False)

    def _entry_valid(self, entry: _PrefixEntry) -> bool:
        return all(
            self.refcount[p] > 0 and self.generation[p] == g
            for p, g in zip(entry.pages, entry.gens))

    # -- invariants --------------------------------------------------------
    def check_balanced(self) -> None:
        """Assert the drained-pool invariant the property tests pin: with
        no resident requests, every refcount is zero and the free list
        covers the whole pool exactly once."""
        assert not self.tables, f"live page tables: {sorted(self.tables)}"
        leaked = [i for i, c in enumerate(self.refcount) if c != 0]
        assert not leaked, f"nonzero refcounts after drain: {leaked}"
        assert sorted(self._free) == list(range(self.n_pages)), (
            f"free list does not cover the pool: "
            f"{len(self._free)}/{self.n_pages}")
