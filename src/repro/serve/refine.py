"""Online plan refinement: close the loop from fleet telemetry to the plan.

The paper's punchline is that a tiling optimum tuned on one model of GPU
rots when the hardware — or the conditions around it — changes. The AOT
plan artifacts (``repro.core.plans``) are exactly such offline optima:
ranked once, by an analytic cost model, for a modelled hardware descriptor.
A serving fleet contradicts them in real time with measured step latencies.
This module feeds that evidence back:

* **Shadow execution** — each engine diverts a deterministic fraction of
  its steps (``shadow_fraction``, counter-based: no wall-clock randomness)
  to *measure* one candidate tile drawn from the plan's stored sensitivity
  curve next to the incumbent, through the shared timing path
  (:func:`make_shadow_measure` -> ``launch.measure.make_cell_timer``:
  wall-clock on real hardware, the analytic model otherwise). Shadow
  measurements never touch the serving math — candidates are timed out of
  band, so served tokens are bit-identical with shadowing on or off (the
  refinement-conformance suite pins this).
* **Online re-ranking** — :class:`PlanRefiner` aggregates the samples per
  ``(hardware, kernel, problem, dtype)`` cell behind a confidence gate
  (``min_samples`` per tile and ``min_speedup`` over the *measured*
  incumbent) and :meth:`PlanRefiner.refine` emits a new schema-v3 artifact:
  every donor entry kept, plus one measured entry per confidently-better
  cell keyed to the observing hardware — so post-rollout resolution is an
  *exact* hit and the cross-hardware transfer warnings stop. Provenance
  rides in ``meta["refined_from"]`` / ``meta["measurements"]``.
* **Versioned rollout** — ``FleetRouter.roll_plans`` (``repro.serve.fleet``)
  swaps engines onto the refined artifact one at a time with a p95-TTFT
  rollback guard; :func:`drift_report` renders the incumbent-vs-refined
  tile table CI uploads as the plan-drift artifact.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional, Tuple

from repro.core.hardware import HardwareModel
from repro.core.plans import (
    PLAN_SCHEMA_VERSION,
    PlanEntry,
    TilePlan,
    problem_key,
)
from repro.core.tiling import TileShape

# (kernel, problem, dtype, tile dims) -> measured seconds.
ShadowMeasureFn = Callable[[str, Mapping[str, int], str, Tuple[int, ...]],
                           float]


def make_shadow_measure(hw: HardwareModel) -> ShadowMeasureFn:
    """The default shadow timing path for one hardware target.

    Delegates to ``launch.measure.make_cell_timer`` — wall-clock on a real
    backend, analytic cost-model seconds otherwise — with the per-cell
    timer (and its synthetic operands) cached across shadow steps, so a
    long-running engine builds each cell's operands once.
    """
    from repro.launch.measure import make_cell_timer

    timers: Dict[Tuple[str, str, str], Callable] = {}

    def measure(kernel: str, problem: Mapping[str, int], dtype: str,
                tile) -> float:
        key = (kernel, problem_key(problem), dtype)
        timer = timers.get(key)
        if timer is None:
            timer = make_cell_timer(kernel, dict(problem), dtype, hw)
            timers[key] = timer
        return float(timer(tuple(tile)))

    return measure


@dataclasses.dataclass
class _TileStats:
    count: int = 0
    total_s: float = 0.0

    @property
    def mean_s(self) -> float:
        return self.total_s / self.count if self.count else 0.0


@dataclasses.dataclass
class _CellStats:
    """Shadow evidence for one (hardware, kernel, problem, dtype) cell."""

    kernel: str
    problem: Dict[str, int]
    dtype: str
    hardware: str
    tiles: Dict[Tuple[int, ...], _TileStats] = dataclasses.field(
        default_factory=dict)
    incumbent: Optional[Tuple[int, ...]] = None


class PlanRefiner:
    """Aggregate shadow measurements and re-rank a plan artifact from them.

    One refiner is shared by every engine in a fleet (cells are keyed by
    the observing engine's hardware name, so a heterogeneous fleet refines
    each model's cells independently). The confidence gate is deliberately
    conservative: a cell is only re-ranked when BOTH the winner and the
    measured incumbent have at least ``min_samples`` observations and the
    winner's mean beats the incumbent's by at least ``min_speedup`` — a
    noisy single fast sample must never flip a fleet's tile.
    """

    def __init__(self, min_samples: int = 3, min_speedup: float = 1.05):
        if min_samples < 1:
            raise ValueError("min_samples must be >= 1")
        if min_speedup < 1.0:
            raise ValueError("min_speedup must be >= 1.0")
        self.min_samples = min_samples
        self.min_speedup = min_speedup
        self._cells: Dict[Tuple[str, str, str, str], _CellStats] = {}

    # -- evidence ------------------------------------------------------------
    def observe(self, kernel: str, problem: Mapping[str, int], dtype: str,
                hardware: str, tile, dt: float,
                incumbent: bool = False) -> None:
        """One shadow measurement: ``tile`` ran the cell in ``dt`` seconds.

        ``incumbent`` marks the tile the engine is actually serving with;
        it anchors the speedup gate (candidates are compared against the
        incumbent's *measured* mean, not its stale plan score).
        """
        key = (hardware, kernel, problem_key(problem), dtype)
        cell = self._cells.get(key)
        if cell is None:
            cell = _CellStats(kernel=kernel, problem=dict(problem),
                              dtype=dtype, hardware=hardware)
            self._cells[key] = cell
        dims = tuple(int(x) for x in tile)
        stats = cell.tiles.setdefault(dims, _TileStats())
        stats.count += 1
        stats.total_s += float(dt)
        if incumbent:
            cell.incumbent = dims

    def n_samples(self) -> int:
        return sum(s.count for c in self._cells.values()
                   for s in c.tiles.values())

    def cells(self) -> List[Tuple[str, str, str, str]]:
        return sorted(self._cells)

    # -- the confidence gate -------------------------------------------------
    def _decide(self, cell: _CellStats) -> Optional[dict]:
        """A confidently-better tile for this cell, or None."""
        inc = cell.incumbent
        if inc is None:
            return None
        inc_stats = cell.tiles.get(inc)
        if inc_stats is None or inc_stats.count < self.min_samples:
            return None
        ranked = sorted(
            ((s.mean_s, dims) for dims, s in cell.tiles.items()
             if s.count >= self.min_samples),
            key=lambda p: (p[0], p[1]),
        )
        if not ranked:
            return None
        best_s, best = ranked[0]
        if best == inc or best_s <= 0.0:
            return None
        speedup = inc_stats.mean_s / best_s
        if speedup < self.min_speedup:
            return None
        return {
            "tile": best,
            "score_s": best_s,
            "incumbent": inc,
            "incumbent_s": inc_stats.mean_s,
            "speedup": speedup,
            "samples": cell.tiles[best].count,
        }

    # -- re-ranking ----------------------------------------------------------
    def refine(self, plan: TilePlan, trace=None) -> TilePlan:
        """Emit a schema-v3 artifact: the donor plan plus one measured entry
        per confidently re-ranked cell, keyed to the observing hardware so
        post-rollout resolution is exact. The provenance block records what
        the artifact was refined from and every re-rank decision.

        ``trace`` (a :class:`repro.obs.trace.ProcTrace`, optional) gets one
        ``refine_cell`` instant per re-ranked cell, so the audit trail shows
        *when* the fleet's evidence flipped each tile, next to the shadow
        measurements that justified it."""
        refined = TilePlan(entries=plan.entries(), meta=dict(plan.meta))
        measurements: List[dict] = []
        for key in sorted(self._cells):
            cell = self._cells[key]
            decision = self._decide(cell)
            if decision is None:
                continue
            if trace is not None:
                trace.refine_cell(cell.kernel, problem_key(cell.problem),
                                  decision["incumbent"], decision["tile"],
                                  decision["speedup"], decision["samples"])
            curve = tuple(sorted(
                ((dims, s.mean_s) for dims, s in cell.tiles.items()
                 if s.count >= self.min_samples),
                key=lambda p: (p[1], p[0]),
            ))
            finite = [s for _, s in curve if s > 0.0]
            refined.add(PlanEntry(
                kernel=cell.kernel,
                hardware=cell.hardware,
                dtype=cell.dtype,
                problem=tuple(sorted(cell.problem.items())),
                tile=TileShape(decision["tile"]),
                score_s=decision["score_s"],
                dominant="measured",
                sensitivity=(max(finite) / min(finite) if finite else 1.0),
                curve=curve,
            ))
            measurements.append({
                "kernel": cell.kernel,
                "problem": dict(cell.problem),
                "dtype": cell.dtype,
                "hardware": cell.hardware,
                "incumbent": list(decision["incumbent"]),
                "incumbent_s": decision["incumbent_s"],
                "tile": list(decision["tile"]),
                "score_s": decision["score_s"],
                "speedup": decision["speedup"],
                "samples": decision["samples"],
            })
        refined.meta["refined_from"] = {
            "entries": len(plan),
            "hardware": plan.hardware_names(),
            "generated_by": plan.meta.get("generated_by"),
            "schema_version": PLAN_SCHEMA_VERSION,
        }
        refined.meta["measurements"] = measurements
        refined.meta["shadow_samples"] = self.n_samples()
        return refined


def drift_report(refined: TilePlan) -> dict:
    """Incumbent-vs-refined tile per re-ranked cell (the CI drift artifact).

    Reads the provenance block a :meth:`PlanRefiner.refine` call wrote, so
    the report can be regenerated from the artifact alone.
    """
    measurements = refined.meta.get("measurements", [])
    cells = [
        {
            "cell": (f"{m['kernel']}|{problem_key(m['problem'])}"
                     f"|{m['dtype']}|{m['hardware']}"),
            "incumbent": m["incumbent"],
            "refined": m["tile"],
            "incumbent_s": m["incumbent_s"],
            "refined_s": m["score_s"],
            "speedup": m["speedup"],
            "samples": m["samples"],
        }
        for m in measurements
    ]
    return {
        "n_refined": len(cells),
        "shadow_samples": refined.meta.get("shadow_samples", 0),
        "refined_from": refined.meta.get("refined_from", {}),
        "cells": cells,
    }
