"""Shape-bucketed continuous-batching scheduler.

The paper's result is that a tile optimum holds for one *(problem shape,
hardware model)* cell. PR 1 compiled those cells into AOT plans, but a
serving engine that prefills requests at their raw prompt lengths lands on
arbitrary shapes: almost every lookup degrades to nearest-shape (or a
heuristic), and every distinct length is a fresh XLA compile. The scheduler
fixes this at admission time: prompts are padded to a small family of
**bucket edges**, so every prefill lands on an exactly-compiled plan cell
and a warm jit cache entry.

Components:

* :class:`BucketPolicy` — the shape family (ascending pad targets) plus the
  admission bound. ``from_plan`` derives edges from a compiled
  :class:`~repro.core.plans.TilePlan` so the scheduler's shapes are, by
  construction, the plan's shapes.
* :class:`ShapeBucketScheduler` — per-bucket queues with priority/deadline
  ordering (FIFO among equals), admission control (full queue or
  over-length prompt -> reject), and left-padding to the bucket edge.
* :class:`FifoScheduler` — the naive baseline: one queue, raw shapes. This
  is the pre-scheduler engine behavior, kept as the default so existing
  callers are unchanged and benchmarks have a control arm.

The engine owns the slots; the scheduler only decides *which request is
admitted next and at what shape*.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """Shape family + admission bound for bucketed scheduling.

    ``edges`` are ascending prompt-length pad targets; a prompt is assigned
    the smallest edge >= its length. Prompts longer than the largest edge
    are rejected with an explicit reason (admission control) unless
    ``allow_overflow`` is set — the chunked-prefill admission mode, where
    an over-length prompt pads to the smallest *multiple* of the largest
    edge that covers it and the engine prefills it chunk by chunk. Submits
    beyond ``max_queue`` total backlog are rejected either way. Rejections
    are never silent: ``admit`` reports why.
    """

    edges: Tuple[int, ...]
    max_queue: int = 256
    allow_overflow: bool = False

    def __post_init__(self):
        if not self.edges:
            raise ValueError("BucketPolicy needs at least one edge")
        if list(self.edges) != sorted(set(self.edges)):
            raise ValueError(f"edges must be ascending/unique: {self.edges}")
        if any(e <= 0 for e in self.edges):
            raise ValueError(f"edges must be positive: {self.edges}")

    @classmethod
    def pow2(cls, lo: int = 16, hi: int = 1024, max_queue: int = 256,
             allow_overflow: bool = False) -> "BucketPolicy":
        edges = []
        e = lo
        while e < hi:
            edges.append(e)
            e *= 2
        edges.append(hi)
        return cls(tuple(edges), max_queue=max_queue,
                   allow_overflow=allow_overflow)

    @classmethod
    def from_plan(cls, plan, kernel: str = "flash_attention",
                  hardware: Optional[str] = None, dtype: Optional[str] = None,
                  max_queue: int = 256,
                  allow_overflow: bool = False) -> "BucketPolicy":
        """Derive the shape family from a compiled plan's prefill cells.

        Uses the full-sequence (sq > 1) cells of ``kernel`` — i.e. the
        shapes the plan was actually compiled for — so bucketed admission
        resolves exactly by construction.
        """
        edges = set()
        for e in plan.entries():
            if e.kernel != kernel:
                continue
            if hardware is not None and e.hardware != hardware:
                continue
            if dtype is not None and e.dtype != dtype:
                continue
            sq = e.problem_dict.get("sq", 0)
            if sq > 1:
                edges.add(sq)
        if not edges:
            raise ValueError(
                f"plan has no full-sequence {kernel!r} cells to derive "
                f"bucket edges from")
        return cls(tuple(sorted(edges)), max_queue=max_queue,
                   allow_overflow=allow_overflow)

    def bucket_for(self, prompt_len: int) -> Optional[int]:
        """Smallest admitted pad length >= prompt_len.

        Within the shape family this is the smallest edge that covers the
        prompt. Beyond the largest edge: with ``allow_overflow`` the prompt
        is still admitted — at the smallest multiple of the largest edge
        covering it, so a chunking engine splits it at bucket-edge-sized
        boundaries — otherwise None (the caller must surface an explicit
        over-length rejection, never drop silently; see ``admit``).
        """
        for e in self.edges:
            if prompt_len <= e:
                return e
        if self.allow_overflow:
            top = self.edges[-1]
            return math.ceil(prompt_len / top) * top
        return None

    def admit(self, prompt_len: int) -> Tuple[Optional[int], str]:
        """(pad length, reason) — reason is "ok" or why admission failed."""
        bucket = self.bucket_for(prompt_len)
        if bucket is None:
            return None, "over_length"
        return bucket, "ok"

    @staticmethod
    def parse(spec: str, max_queue: int = 256,
              allow_overflow: bool = False) -> "BucketPolicy":
        """Parse a CLI spec: "64,128,512" or "pow2:16:1024"."""
        if spec.startswith("pow2"):
            parts = spec.split(":")
            lo = int(parts[1]) if len(parts) > 1 else 16
            hi = int(parts[2]) if len(parts) > 2 else 1024
            return BucketPolicy.pow2(lo, hi, max_queue=max_queue,
                                     allow_overflow=allow_overflow)
        return BucketPolicy(
            tuple(sorted({int(x) for x in spec.split(",") if x})),
            max_queue=max_queue, allow_overflow=allow_overflow)


class FifoScheduler:
    """Naive admission: one unbounded queue, raw prompt shapes."""

    name = "fifo"

    def __init__(self, max_queue: Optional[int] = None):
        self.max_queue = max_queue
        self._queue: deque = deque()
        self.last_reject_reason = "ok"
        self._trace = None

    def bind_trace(self, trace) -> None:
        """Attach a per-engine trace handle (repro.obs.trace.ProcTrace):
        queue push/pop become instant events on the scheduler lane."""
        self._trace = trace

    def admit_length(self, prompt_len: int) -> int:
        """The sequence length a prompt would prefill at (raw — no padding)."""
        return prompt_len

    def submit(self, req) -> bool:
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            self.last_reject_reason = "queue_full"
            return False
        req.bucket = len(req.prompt)
        self._queue.append(req)
        if self._trace is not None:
            self._trace.queue_push(req.rid, req.bucket)
        return True

    def next_request(self):
        req = self._queue.popleft() if self._queue else None
        if req is not None and self._trace is not None:
            self._trace.queue_pop(req.rid, req.bucket)
        return req

    def prepare(self, req) -> np.ndarray:
        return req.prompt

    def pending(self) -> int:
        return len(self._queue)

    def remove(self, rid: int):
        """Pop one queued request by rid (cancel / fleet recovery / work
        stealing); None when not queued here."""
        for i, req in enumerate(self._queue):
            if req.rid == rid:
                del self._queue[i]
                return req
        return None

    def queued_buckets(self) -> List[int]:
        """Admitted length of every queued request (fleet load estimates)."""
        return [len(r.prompt) for r in self._queue]


class ShapeBucketScheduler:
    """Per-bucket queues, priority/deadline ordering, padded admission.

    Ordering within and across buckets is by ``(priority, deadline, seq)``:
    lower priority value = more urgent; ``deadline`` defaults to +inf;
    ``seq`` is the global submit order, so requests that tie on priority and
    deadline pop FIFO (fairness). Across buckets the scheduler picks the
    bucket whose *head* sorts first, which keeps bursts of one shape
    draining together (warm compile + exact plan cell) without starving an
    urgent request in another bucket.
    """

    name = "bucket"

    def __init__(self, policy: BucketPolicy, pad_id: int = 0):
        self.policy = policy
        self.pad_id = pad_id
        self._queues: Dict[int, List] = {e: [] for e in policy.edges}
        self._seq = 0
        self.last_reject_reason = "ok"
        self._trace = None

    def bind_trace(self, trace) -> None:
        """Attach a per-engine trace handle (repro.obs.trace.ProcTrace):
        queue push/pop become instant events on the scheduler lane."""
        self._trace = trace

    def admit_length(self, prompt_len: int):
        """The padded prefill length (bucket edge, or the overflow multiple
        under ``allow_overflow``); None when over-length."""
        return self.policy.bucket_for(prompt_len)

    def submit(self, req) -> bool:
        bucket, reason = self.policy.admit(len(req.prompt))
        if bucket is None:
            self.last_reject_reason = reason
            return False
        if self.pending() >= self.policy.max_queue:
            self.last_reject_reason = "queue_full"
            return False
        req.bucket = bucket
        key = (req.priority, req.deadline, self._seq)
        self._seq += 1
        # Overflow buckets (allow_overflow multiples of the top edge) get
        # their queue lazily — they are not part of the static edge family.
        heapq.heappush(self._queues.setdefault(bucket, []), (key, req))
        if self._trace is not None:
            self._trace.queue_push(req.rid, req.bucket)
        return True

    def next_request(self):
        return self.next_request_within(None)

    def next_request_within(self, max_bucket: Optional[int]):
        """Most urgent head among buckets with edge <= ``max_bucket``.

        The chunked engine's selective admission: while a multi-chunk
        prefill is in flight it only admits single-chunk (small-bucket)
        requests, and the per-bucket queues make that a filtered pop —
        queued long prompts stay in the scheduler, visible to ``max_queue``
        admission control and the queue-depth metric, without blocking the
        small buckets behind them.
        """
        heads = [(q[0][0], bucket) for bucket, q in self._queues.items()
                 if q and (max_bucket is None or bucket <= max_bucket)]
        if not heads:
            return None
        _, bucket = min(heads)
        _, req = heapq.heappop(self._queues[bucket])
        if self._trace is not None:
            self._trace.queue_pop(req.rid, req.bucket)
        return req

    def prepare(self, req) -> np.ndarray:
        """Left-pad the prompt to its bucket edge.

        Left padding keeps the prompt's last token at the final position, so
        the engine's last-position prefill logits stay the request's first
        sampled token. The pad prefix is visible to attention (no mask in
        this synthetic stack) — bucketed outputs are deterministic per
        bucket but not bit-identical to unpadded serving; that trade is the
        point of shape binding.
        """
        pad = req.bucket - len(req.prompt)
        if pad <= 0:
            return req.prompt
        return np.concatenate([
            np.full((pad,), self.pad_id, np.int32),
            np.asarray(req.prompt, np.int32),
        ])

    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def remove(self, rid: int):
        """Pop one queued request by rid (cancel / fleet recovery / work
        stealing); None when not queued here. The affected bucket's heap is
        rebuilt — removal is O(queue), fine for a control-path operation."""
        for bucket, q in self._queues.items():
            for i, (_key, req) in enumerate(q):
                if req.rid == rid:
                    del q[i]
                    heapq.heapify(q)
                    return req
        return None

    def queue_depths(self) -> Dict[int, int]:
        return {bucket: len(q) for bucket, q in self._queues.items()}

    def queued_buckets(self) -> List[int]:
        """Admitted length of every queued request (fleet load estimates)."""
        return [req.bucket for q in self._queues.values() for _, req in q]


def pick_chunks(jobs: Sequence, budget: float, slots: int,
                aging: bool = False) -> List[Tuple[object, int]]:
    """Knapsack-style pick of the prefill chunks one packed step runs.

    ``jobs`` are the in-flight chunk-resumable prefills (objects with
    ``remaining``, ``chunk_len`` and a ``req`` carrying priority/deadline/
    rid — the engine's ``_ChunkJob`` view). The head job is the most urgent
    by SRPT order — priority, deadline, fewest remaining tokens — or, with
    ``aging`` set (the engine raises it every AGING_PERIOD-th step), the
    oldest by submit order, so a sustained stream of short prompts cannot
    starve a long prefill. The head ALWAYS packs (progress guarantee, even
    when the budget is smaller than its chunk); the remaining budget then
    fills greedily with further jobs in SRPT order — each contributes
    ``min(chunk_len, remaining)`` tokens and is skipped (not truncated)
    when it no longer fits, so every packed segment is a whole plan-sized
    chunk and the smaller-chunk jobs behind a skipped one stay reachable
    (the greedy knapsack step). At most ``slots`` segments ride one step.

    Returns ``[(job, take), ...]`` in pick order; ``sum(take)`` exceeds
    ``budget`` only via the guaranteed head chunk.
    """
    if not jobs:
        return []
    srpt = sorted(jobs, key=lambda j: (j.req.priority, j.req.deadline,
                                       j.remaining, j.req.rid))
    if aging:
        head = min(jobs, key=lambda j: (j.req.priority, j.req.deadline,
                                        j.req.rid))
        srpt.remove(head)
        srpt.insert(0, head)
    picks: List[Tuple[object, int]] = []
    left = budget
    for job in srpt:
        if len(picks) >= max(1, slots):
            break
        take = min(job.chunk_len, job.remaining)
        if picks and take > left:
            continue
        picks.append((job, take))
        left -= take
    return picks


def make_scheduler(kind: str, policy: Optional[BucketPolicy] = None,
                   pad_id: int = 0):
    """CLI-facing factory: "fifo" or "bucket" (bucket requires a policy)."""
    if kind == "fifo":
        return FifoScheduler()
    if kind == "bucket":
        if policy is None:
            policy = BucketPolicy.pow2()
        return ShapeBucketScheduler(policy, pad_id=pad_id)
    raise ValueError(f"unknown scheduler kind {kind!r} (fifo|bucket)")
