"""Train step factory: value_and_grad + AdamW, mesh-aware, donation-ready.

The returned step is a pure function suitable for jax.jit with explicit
in/out shardings (launch/dryrun.py, launch/train.py). Microbatch gradient
accumulation is handled with lax.scan over microbatches (compute/comm
overlap comes from XLA pipelining the accumulation loop).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import api
from repro.models.context import DistContext
from repro.optim import adamw
from repro.optim.schedule import warmup_cosine


def make_train_step(
    cfg: ArchConfig,
    ctx: Optional[DistContext],
    opt_cfg: adamw.AdamWConfig,
    lr_fn: Optional[Callable] = None,
    microbatches: int = 1,
    remat: bool = True,
    accum_dtype=jnp.float32,
    tiles=None,
):
    lr_fn = lr_fn or (lambda step: jnp.asarray(3e-4, jnp.float32))

    def loss_fn(params, batch):
        return api.train_loss(params, cfg, batch, ctx, remat=remat,
                              tiles=tiles)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                # Strided split: microbatch m takes rows {m, m+mb, ...} so a
                # data-sharded batch dim stays data-sharded per microbatch
                # (a plain reshape would put the split dim on the devices).
                return x.reshape(
                    b // microbatches, microbatches, *x.shape[1:]
                ).swapaxes(0, 1)

            mb = jax.tree.map(split, batch)

            def acc_body(carry, mbatch):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mbatch)
                return (
                    jax.tree.map(
                        lambda a, b: a + b.astype(a.dtype), g_acc, g),
                    l_acc + l,
                ), None

            # Derive the accumulator from params so SPMD propagates the
            # parameter sharding onto it (a fresh zeros() would be
            # ambiguously sharded and can end up replicated).
            zeros = jax.tree.map(
                lambda p: (p * 0).astype(accum_dtype), params)
            (g_sum, l_sum), _ = jax.lax.scan(
                acc_body, (zeros, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / microbatches, g_sum)
            loss = l_sum / microbatches
            metrics = {"loss": loss}

        lr = lr_fn(opt_state["step"])
        params, opt_state, om = adamw.apply_updates(
            params, grads, opt_state, opt_cfg, lr)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["lr"] = lr
        return params, opt_state, metrics

    return train_step


def make_serve_steps(cfg: ArchConfig, ctx: Optional[DistContext],
                     max_len: int, dtype=jnp.float32, tiles=None):
    """(prefill_fn, decode_fn) pair for serving / dry-run lowering."""

    def prefill_step(params, batch):
        # Window (local) attention layers always use ring caches: their
        # effective KV is the window, independent of total context length.
        return api.prefill(params, cfg, batch, max_len=max_len, dtype=dtype,
                           ctx=ctx, ring_local=bool(cfg.attn_window),
                           tiles=tiles)

    def decode_step(params, token, state):
        return api.decode_step(params, cfg, token, state, ctx=ctx,
                               tiles=tiles)

    return prefill_step, decode_step
