"""Trainer: the fault-tolerant training loop.

Responsibilities: jit the train step with explicit shardings, drive the data
pipeline, checkpoint every N steps (async, atomic), restore-and-continue
after a failure (simulated or real), track health/straggler stats, and log.

The loop is deliberately restart-oriented: all state lives in
(params, opt_state, data_step), all of which round-trips through the
CheckpointManager — a process can die at any step and resume.

Tile selection: ``TrainerConfig.tile_plans`` names a compiled
:class:`~repro.core.plans.TilePlan` artifact (or pass the object as
``plans=``). The trainer resolves every train-step kernel tile from it at
construction time — a corrupt or missing artifact degrades to the heuristic
default, and no code path on the step loop ever invokes a sweep.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ArchConfig
from repro.core.hardware import PRODUCTION_TARGET
from repro.core.hardware import get as get_hardware
from repro.core.plans import PlanResolution, TilePlan
from repro.core.tiling import TileShape
from repro.data.pipeline import DataConfig, make_batch
from repro.distributed import sharding_rules as rules
from repro.distributed.fault_tolerance import HealthMonitor, StepTimer
from repro.models import api
from repro.optim import adamw
from repro.optim.schedule import warmup_cosine
from repro.train.step import make_train_step

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    peak_lr: float = 3e-4
    warmup_steps: int = 10
    microbatches: int = 1
    seed: int = 0
    param_dtype: Any = jnp.float32
    log_every: int = 10
    # AOT tile plans: path to a compiled artifact + the hardware to resolve
    # for ("" = the production target). Corrupt/missing artifacts are
    # tolerated (heuristic fallback), never swept around.
    tile_plans: Optional[str] = None
    hardware: str = ""


class Trainer:
    def __init__(self, cfg: ArchConfig, data_cfg: DataConfig,
                 tcfg: TrainerConfig, mesh=None,
                 opt_cfg: Optional[adamw.AdamWConfig] = None,
                 plans: Optional[TilePlan] = None):
        self.cfg = cfg
        self.data_cfg = data_cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.opt_cfg = opt_cfg or adamw.AdamWConfig()
        self.ctx = rules.make_context(mesh) if mesh is not None else None
        self.monitor = HealthMonitor()
        self.ckpt = CheckpointManager(tcfg.checkpoint_dir, keep=tcfg.keep)
        self.hardware = (get_hardware(tcfg.hardware) if tcfg.hardware
                         else PRODUCTION_TARGET)
        self.tiles: Dict[str, TileShape] = {}
        self.tile_resolutions: Dict[str, PlanResolution] = {}
        if plans is None:
            plans = TilePlan.load_or_none(tcfg.tile_plans)
        if plans is not None:
            self._resolve_tiles(plans)

        lr_fn = lambda step: warmup_cosine(
            step, peak_lr=tcfg.peak_lr, warmup_steps=tcfg.warmup_steps,
            total_steps=tcfg.steps)
        step_fn = make_train_step(
            cfg, self.ctx, self.opt_cfg, lr_fn,
            microbatches=tcfg.microbatches, tiles=self.tiles or None)
        self._step = jax.jit(step_fn, donate_argnums=(0, 1))

    def _resolve_tiles(self, plans: TilePlan) -> None:
        """Resolve train-step kernel tiles from the plan store. No sweeps."""
        from repro.launch.specs import resolve_model_tiles

        # The jitted step consumes per-host batches (data/pipeline.py), so
        # tune for host_batch, not global_batch.
        self.tiles, self.tile_resolutions = resolve_model_tiles(
            plans, self.cfg, self.data_cfg.host_batch, self.data_cfg.seq_len,
            "train", jnp.dtype(self.tcfg.param_dtype).name, self.hardware)

    # -- state --------------------------------------------------------------
    def init_state(self):
        params = api.init_params(
            self.cfg, jax.random.PRNGKey(self.tcfg.seed),
            dtype=self.tcfg.param_dtype)
        opt_state = adamw.init_state(params, self.opt_cfg)
        return params, opt_state, 0

    def try_restore(self):
        latest = self.ckpt.latest_step()
        if latest is None:
            return self.init_state()
        params, opt_state, _ = self.init_state()
        tree = self.ckpt.restore({"params": params, "opt": opt_state})
        meta = self.ckpt.meta()
        log.info("restored checkpoint at step %d", meta["step"])
        return tree["params"], tree["opt"], meta["step"]

    # -- loop ---------------------------------------------------------------
    def run(self, fail_at: Optional[int] = None,
            max_restarts: int = 2) -> Dict[str, Any]:
        """Run to tcfg.steps; survives ``max_restarts`` worker failures.

        ``fail_at``: raise an injected RuntimeError at that step once
        (fault-tolerance test hook).
        """
        restarts = 0
        failed_once = False
        losses = []
        while True:
            try:
                params, opt_state, start = self.try_restore()
                for step in range(start, self.tcfg.steps):
                    if fail_at is not None and step == fail_at and not failed_once:
                        failed_once = True
                        raise RuntimeError("injected worker failure")
                    batch = {
                        k: jnp.asarray(v)
                        for k, v in make_batch(self.data_cfg, step).items()
                    }
                    with StepTimer() as t:
                        params, opt_state, metrics = self._step(
                            params, opt_state, batch)
                        loss = float(metrics["loss"])
                    straggler = self.monitor.record_step(t.seconds)
                    if straggler:
                        log.warning("straggler step %d: %.3fs (baseline %.3fs)",
                                    step, t.seconds, self.monitor.baseline_s)
                    losses.append(loss)
                    if step % self.tcfg.log_every == 0:
                        log.info("step %d loss %.4f (%.3fs)", step, loss,
                                 t.seconds)
                    if (step + 1) % self.tcfg.checkpoint_every == 0:
                        self.ckpt.save(
                            step + 1, {"params": params, "opt": opt_state},
                            extra={"data_step": step + 1})
                self.ckpt.save(self.tcfg.steps,
                               {"params": params, "opt": opt_state},
                               extra={"data_step": self.tcfg.steps})
                self.ckpt.wait()
                return {
                    "losses": losses,
                    "restarts": restarts,
                    "straggler_events": self.monitor.straggler_events,
                    "params": params,
                }
            except RuntimeError as e:
                restarts += 1
                log.warning("worker failure (%s); restart %d", e, restarts)
                if restarts > max_restarts:
                    raise
