"""Autoscaling policy: hysteresis, signal priorities, mix-priced candidate
selection, and the FleetRouter integration behind ``autoscaler=``.

The fast tests drive :class:`~repro.serve.autoscale.AutoscalePolicy`
against a scriptable fake fleet implementing the adapter protocol — the
same duck-typed surface ``FleetRouter`` and the autoscale bench's
simulator expose — so every decision rule (trigger priority, cooldown,
consecutive-low scale-down, min/max clamps, price-weighted candidate
ranking) is pinned without a model. The ``slow`` tests run the policy
inside a real ``FleetRouter``: a backlogged single-instance fleet joins a
second engine, serves everything, then drains back to ``min_instances``,
with decisions in ``metrics()["autoscale"]``.
"""
import jax
import numpy as np
import pytest

from repro import configs, kernels
from repro.models import api
from repro.serve import (
    AutoscalePolicy, BucketPolicy, FleetRouter, ScaleCandidate,
    ScaleDecision, ServeEngine, ShapeBucketScheduler,
)

EDGES = (8, 64)
NEW_TOKENS = 3


# ---------------------------------------------------------------------------
# Scriptable fake fleet (fast; no model)
# ---------------------------------------------------------------------------

class FakeFleet:
    """Adapter-protocol fleet with hand-settable signals."""

    def __init__(self, members=(("a", "hw_base"),), cand_cost=None):
        self.members = dict(members)          # name -> hardware
        self.queues = {n: 0 for n in self.members}
        self.ttfts = []
        self.mix = {}
        self.nt_sum = 0
        self.nt_n = 0
        self.occupancy = 0.0
        self.orphans = 0
        # hardware -> mix-weighted seconds/request (what price_candidate/
        # price_instance report; tests steer selection through this).
        self.cand_cost = dict(cand_cost or {})
        self.joined = []
        self.drained = []
        self.recorded = []

    # -- protocol ----------------------------------------------------------
    def live_instances(self):
        return sorted(n for n in self.members if n not in self.drained)

    def known_instances(self):
        return set(self.members)

    def instance_hardware(self, name):
        return self.members.get(name)

    def queue_depths(self):
        return dict(self.queues)

    def ttft_marks(self):
        return len(self.ttfts)

    def ttft_window_since(self, mark):
        return list(self.ttfts[mark or 0:]), False

    def traffic_mix(self):
        return dict(self.mix), self.nt_sum, self.nt_n

    def pool_occupancy(self):
        return self.occupancy

    def orphan_count(self):
        return self.orphans

    def price_instance(self, name, mix, nt):
        return self.cand_cost.get(self.members[name], 1.0)

    def price_candidate(self, cand, mix, nt):
        return self.cand_cost.get(cand.hardware, 1.0)

    def scale_join(self, name, engine):
        self.members[name] = engine["hw"]
        self.queues[name] = 0
        self.joined.append(name)

    def scale_drain(self, name):
        self.drained.append(name)

    def record_autoscale(self, decision):
        self.recorded.append(decision)


def _cand(hw, price=1.0, name=None):
    return ScaleCandidate(name=name or hw, hardware=hw,
                          make_engine=lambda n, hw=hw: {"name": n, "hw": hw},
                          price=price)


def _policy(**kw):
    defaults = dict(min_instances=1, max_instances=4, interval=1, cooldown=0,
                    queue_high=4.0, queue_low=1.0, low_evals=2,
                    min_ttft_samples=2)
    defaults.update(kw)
    cands = defaults.pop("candidates", (_cand("hw_fast"),))
    return AutoscalePolicy(cands, **defaults)


def test_policy_validation():
    with pytest.raises(ValueError):
        _policy(min_instances=0)
    with pytest.raises(ValueError):
        _policy(min_instances=3, max_instances=2)
    with pytest.raises(ValueError):
        _policy(interval=0)
    with pytest.raises(ValueError):
        _policy(cooldown=-1)
    with pytest.raises(ValueError):
        _policy(low_evals=0)
    with pytest.raises(ValueError):
        _policy(queue_high=1.0, queue_low=2.0)
    with pytest.raises(ValueError):
        _policy(ttft_high=1.0, ttft_low=2.0)
    with pytest.raises(ValueError):
        _policy(candidates=(_cand("hw_a"), _cand("hw_a")))
    with pytest.raises(ValueError):
        _cand("hw_a", price=0.0)


def test_interval_gates_evaluations():
    fleet = FakeFleet()
    pol = _policy(interval=4)
    for step in range(8):
        pol.observe(fleet, step)
    # Evaluated at steps 0 and 4 only.
    assert pol.as_dict()["evaluations"] == 2


def test_scale_up_on_queue_depth_with_cooldown():
    fleet = FakeFleet()
    fleet.queues["a"] = 9
    pol = _policy(cooldown=1)
    d = pol.observe(fleet, 0)
    assert len(d) == 1 and d[0].action == "join"
    assert d[0].reason == "queue_depth"
    assert d[0].signals["queue_per_instance"] == 9.0
    assert fleet.joined == ["hw_fast"]
    assert fleet.recorded == d                 # traced with the decision
    assert pol.instance_price["hw_fast"] == 1.0
    # Still overloaded, but the cooldown eats the next evaluation.
    fleet.queues["a"] = 9
    assert pol.observe(fleet, 1) == []
    assert len(pol.observe(fleet, 2)) == 1     # cooldown over -> joins again


def test_scale_up_priority_order_and_bounds():
    fleet = FakeFleet()
    fleet.queues["a"] = 9
    fleet.occupancy = 0.99
    fleet.orphans = 2
    fleet.ttfts = [5.0] * 8
    pol = _policy(ttft_high=1.0, max_instances=5)
    d = pol.observe(fleet, 0)
    assert d[0].reason == "orphans"            # orphans outrank everything
    fleet.orphans = 0
    fleet.ttfts += [5.0] * 8                   # fresh window, still slow
    fleet.queues = {n: 9 for n in fleet.members}
    d = pol.observe(fleet, 1)
    assert d[0].reason == "p95_ttft"           # then windowed p95 TTFT
    fleet.ttfts += [0.0] * 8                   # window recovered
    fleet.queues = {n: 9 for n in fleet.members}
    d = pol.observe(fleet, 2)
    assert d[0].reason == "queue_depth"        # then queue depth
    fleet.queues = {n: 0 for n in fleet.members}
    d = pol.observe(fleet, 3)
    assert d[0].reason == "pool_occupancy"     # then pool pressure
    # max_instances=5 reached: no further join, however loud the signals.
    fleet.orphans = 5
    assert pol.observe(fleet, 4) == []
    assert pol.as_dict()["joins"] == 4


def test_ttft_trigger_needs_min_samples():
    fleet = FakeFleet()
    fleet.ttfts = [9.0]                        # loud but thin window
    pol = _policy(ttft_high=1.0, min_ttft_samples=4)
    assert pol.observe(fleet, 0) == []
    fleet.ttfts += [9.0] * 4
    d = pol.observe(fleet, 1)
    assert len(d) == 1 and d[0].reason == "p95_ttft"


def test_candidate_selection_is_price_weighted_by_mix():
    # hw_fast serves a request in 1s but costs 3x; hw_cheap takes 2s at
    # 1x. Effective: fast 3.0 vs cheap 2.0 -> cheap wins; flip the costs
    # and fast wins. This is the cross-model divergence mechanism the
    # autoscale bench exercises with real compiled costs.
    cands = (_cand("hw_fast", price=3.0), _cand("hw_cheap", price=1.0))
    fleet = FakeFleet(cand_cost={"hw_fast": 1.0, "hw_cheap": 2.0})
    fleet.queues["a"] = 9
    pol = _policy(candidates=cands)
    assert pol.observe(fleet, 0)[0].hardware == "hw_cheap"
    fleet2 = FakeFleet(cand_cost={"hw_fast": 0.25, "hw_cheap": 2.0})
    fleet2.queues["a"] = 9
    pol2 = _policy(candidates=cands)
    d = pol2.observe(fleet2, 0)
    assert d[0].hardware == "hw_fast"          # 3*0.25 < 1*2.0
    assert pol2.instance_price[d[0].instance] == 3.0


def test_join_names_never_collide():
    fleet = FakeFleet()
    pol = _policy(max_instances=3)
    fleet.queues["a"] = 9
    assert pol.observe(fleet, 0)[0].instance == "hw_fast"
    fleet.queues["hw_fast"] = 9
    d = pol.observe(fleet, 1)
    assert d[0].instance == "hw_fast2"         # base name already taken


def test_scale_down_needs_consecutive_low_evals():
    fleet = FakeFleet((("a", "hw_base"), ("b", "hw_base")),
                      cand_cost={"hw_base": 1.0})
    pol = _policy(low_evals=3)
    assert pol.observe(fleet, 0) == []         # low #1
    assert pol.observe(fleet, 1) == []         # low #2
    # Blip in the dead band (1 < 3/2 instances < 4): no decision either
    # way, but the streak must reset.
    fleet.queues["a"] = 3
    assert pol.observe(fleet, 2) == []
    assert pol.as_dict()["low_streak"] == 0
    fleet.queues["a"] = 0
    assert pol.observe(fleet, 4) == []         # low #1
    assert pol.observe(fleet, 5) == []         # low #2
    d = pol.observe(fleet, 6)                  # low #3 -> drain
    assert len(d) == 1 and d[0].action == "drain"
    assert d[0].reason == "low_load"
    assert fleet.drained == [d[0].instance]
    # min_instances=1: the survivor is never drained.
    for step in range(7, 20):
        assert pol.observe(fleet, step) == []
    assert len(fleet.live_instances()) == 1


def test_scale_down_drains_worst_price_cost_member():
    # b runs on pricey hardware with no offsetting speed for this mix:
    # its removal is cheapest, so it is the drain victim.
    fleet = FakeFleet((("a", "hw_cheap"), ("b", "hw_fast")),
                      cand_cost={"hw_cheap": 1.0, "hw_fast": 0.9})
    pol = _policy(low_evals=1,
                  instance_prices={"a": 1.0, "b": 3.0})
    d = pol.observe(fleet, 0)
    assert d[0].action == "drain" and d[0].instance == "b"
    assert d[0].hardware == "hw_fast"


def test_max_instances_blocks_join_but_tracks_streak():
    fleet = FakeFleet((("a", "hw_base"),))
    pol = _policy(max_instances=1, low_evals=2)
    fleet.queues["a"] = 9
    assert pol.observe(fleet, 0) == []         # at max: no join
    fleet.queues["a"] = 0
    assert pol.observe(fleet, 1) == []
    assert pol.observe(fleet, 2) == []         # min_instances=1: no drain
    assert pol.as_dict() == {**pol.as_dict()}  # JSON-clean export
    assert pol.as_dict()["joins"] == 0


def test_decision_export_shape():
    fleet = FakeFleet()
    fleet.queues["a"] = 9
    pol = _policy()
    pol.observe(fleet, 7)
    out = pol.as_dict()
    assert out["joins"] == 1 and out["drains"] == 0
    (entry,) = out["log"]
    assert entry["step"] == 7 and entry["action"] == "join"
    assert set(entry["signals"]) >= {
        "queue_depth", "queue_per_instance", "p95_ttft", "pool_occupancy",
        "orphans", "instances"}
    assert isinstance(ScaleDecision(**{
        "step": 1, "action": "drain", "instance": "x", "hardware": None,
        "reason": "low_load", "signals": {}}).as_dict(), dict)


# ---------------------------------------------------------------------------
# FleetRouter integration (slow; real engines)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_model():
    kernels.register_all()
    cfg = configs.get_smoke("qwen2-1.5b")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, n, seed=3, lo=4, hi=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, cfg.vocab_size,
                         size=rng.integers(lo, hi)).astype(np.int32)
            for _ in range(n)]


@pytest.mark.slow
def test_router_autoscales_up_then_back_down(smoke_model):
    cfg, params = smoke_model
    policy = BucketPolicy(EDGES, max_queue=99)

    def make_engine(name):
        return ServeEngine(cfg, params, max_len=max(EDGES) + 16, slots=1,
                           scheduler=ShapeBucketScheduler(policy),
                           instance=name)

    scaler = AutoscalePolicy(
        (ScaleCandidate(name="b", hardware="tpu_v5e",
                        make_engine=make_engine),),
        min_instances=1, max_instances=2, interval=1, cooldown=0,
        queue_high=2.0, queue_low=0.0, low_evals=3)
    router = FleetRouter({"a": make_engine("a")}, policy,
                         autoscaler=scaler)
    for p in _prompts(cfg, 6):
        assert router.route(p, max_new_tokens=NEW_TOKENS) is not None
    # Backlog on the lone instance: the first step's evaluation joins.
    router.step_all()
    assert [d.action for d in scaler.decisions] == ["join"]
    join = scaler.decisions[0]
    assert join.instance == "b" and join.reason == "queue_depth"
    assert router.status["b"] == "live"
    assert scaler.instance_price["b"] == 1.0
    # Serve everything, then idle: three consecutive low evaluations
    # drain back to min_instances.
    for _ in range(200):
        router.step_all()
        if any(d.action == "drain" for d in scaler.decisions):
            break
    assert [d.action for d in scaler.decisions] == ["join", "drain"]
    drained = scaler.decisions[1].instance
    router.step_all()                         # empty drainer retires
    assert router.status[drained] == "drained"
    assert len(router.live_instances()) == 1
    assert router.lost == 0
    assert len(router.results()) == 6
    m = router.metrics()
    assert m["autoscale"]["joins"] == 1 and m["autoscale"]["drains"] == 1
    assert len(m["autoscale"]["log"]) == 2
    assert m["fleet"]["instance_steps"] > 0
    # The joiner genuinely carried load (stolen and/or routed work).
    assert len(router.engines["b"]._finished) >= 1


@pytest.mark.slow
def test_router_adapter_protocol_surface(smoke_model):
    """The FleetRouter side of the adapter protocol the policy consumes:
    traffic mix accumulates on admits only, TTFT windows concatenate
    per-engine samples, pool occupancy is 0 for unpaged engines."""
    cfg, params = smoke_model
    policy = BucketPolicy(EDGES, max_queue=99)
    engines = {n: ServeEngine(cfg, params, max_len=max(EDGES) + 16, slots=2,
                              scheduler=ShapeBucketScheduler(policy),
                              instance=n)
               for n in ("a", "b")}
    router = FleetRouter(engines, policy)
    assert router.live_instances() == ["a", "b"]
    assert router.known_instances() == {"a", "b"}
    assert router.instance_hardware("a") == engines["a"].hardware.name
    assert router.instance_hardware("zz") is None
    assert router.traffic_mix() == ({}, 0, 0)
    for p in _prompts(cfg, 4):
        assert router.route(p, max_new_tokens=NEW_TOKENS) is not None
    mix, nt_sum, n = router.traffic_mix()
    assert n == 4 and nt_sum == 4 * NEW_TOKENS
    assert sum(mix.values()) == 4 and set(mix) <= set(EDGES)
    assert sum(router.queue_depths().values()) >= 0
    mark = router.ttft_marks()
    router.run_until_done()
    samples, clipped = router.ttft_window_since(mark)
    assert len(samples) == 4 and not clipped
    assert router.ttft_window_since(router.ttft_marks()) == ([], False)
    assert router.pool_occupancy() == 0.0
    assert router.orphan_count() == 0
    # Pricing: a member and a candidate wrapping the same engine factory
    # agree (same plans, same hardware, same mix).
    cand = ScaleCandidate(
        name="c", hardware="tpu_v5e",
        make_engine=lambda name: ServeEngine(
            cfg, params, max_len=max(EDGES) + 16, slots=2,
            scheduler=ShapeBucketScheduler(policy), instance=name))
    got = router.price_candidate(cand, mix, NEW_TOKENS)
    want = router.price_instance("a", mix, NEW_TOKENS)
    assert got == pytest.approx(want, rel=1e-9)
    # Empty mix falls back to a uniform mix over the bucket edges.
    assert router.price_instance("a", {}, NEW_TOKENS) > 0.0
