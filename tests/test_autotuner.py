"""Autotuner, cost model, tiling policy — the paper's core machinery."""
import itertools
import os

import pytest

import repro.kernels.bilinear.ops  # noqa: F401  (registers kernels)
import repro.kernels.matmul.ops  # noqa: F401
import repro.kernels.flash_attention.ops  # noqa: F401
from repro.core import (
    GEFORCE_8800GTS, GTX260, TPU_V5E, TPU_V6E, Autotuner, TilingPolicy,
)
from repro.core import registry
from repro.core.cost_model import estimate
from repro.core.tiling import TileConstraints, TileShape, enumerate_tiles


def test_enumerate_respects_vmem():
    c = TileConstraints(rank=2, max_dims=(4096, 4096), lane_dim=1,
                        sublane_dim=0)
    vmem = lambda t: t.size * 4
    tiles = enumerate_tiles(c, TPU_V5E, "float32", vmem)
    budget = TPU_V5E.vmem_bytes * c.vmem_fraction
    assert tiles and all(t.size * 4 <= budget for t in tiles)


def test_enumerate_alignment():
    c = TileConstraints(rank=2, max_dims=(512, 4096), lane_dim=1,
                        sublane_dim=0)
    tiles = enumerate_tiles(c, TPU_V5E, "float32", lambda t: t.size * 4)
    for t in tiles:
        assert t[1] % TPU_V5E.lane_count == 0 or t[1] == 4096
        assert t[0] % TPU_V5E.sublane_fp32 == 0 or t[0] == 512


def test_autotuner_cache_roundtrip(tmp_path):
    path = os.path.join(tmp_path, "cache.json")
    at = Autotuner(cache_path=path)
    prob = dict(m=1024, k=1024, n=1024)
    t1 = at.best_tile("matmul", prob, "bfloat16", TPU_V5E)
    at2 = Autotuner(cache_path=path)
    t2 = at2.best_tile("matmul", prob, "bfloat16", TPU_V5E)
    assert t1 == t2
    assert at2.cached()


def test_measured_overrides_model():
    at = Autotuner()
    prob = dict(m=512, k=512, n=512)
    # Measurement prefers SMALL tiles — the opposite of the model's
    # fewer-grid-steps preference: the winner must be measurement-ranked.
    measured = []

    def measure(tile):
        measured.append(tile)
        return float(tile.size)

    res = at.sweep("matmul", prob, "bfloat16", TPU_V5E, measure_fn=measure)
    assert res.best.measured_s is not None
    assert res.best.tile == min(measured, key=lambda t: t.size)


def test_best_tile_differs_across_hardware():
    """The paper's central claim at the framework level: per-model optima."""
    at = Autotuner()
    prob = dict(src_h=800, src_w=800, scale=4)
    tiles = [TileShape((h, w))
             for h, w in itertools.product((4, 8, 16, 32), repeat=2)]
    r1 = at.sweep("bilinear_cuda", prob, "float32", GTX260, tiles=tiles)
    r2 = at.sweep("bilinear_cuda", prob, "float32", GEFORCE_8800GTS,
                  tiles=tiles)
    assert r1.best.tile != r2.best.tile


def test_policy_heuristic_legal():
    pol = TilingPolicy(mode="heuristic", hardware=TPU_V5E)
    t = pol.tile_for("matmul", dict(m=4096, k=4096, n=4096))
    spec = registry.get("matmul")
    assert spec.vmem_bytes(t, dict(m=4096, k=4096, n=4096), "bfloat16") \
        <= TPU_V5E.vmem_bytes


def test_policy_robust_worst_case():
    """§V: robust mode picks a tile near-optimal on the WORST fleet member."""
    fleet = (GTX260, GEFORCE_8800GTS)
    pol = TilingPolicy(mode="robust", fleet=fleet)
    prob = dict(src_h=800, src_w=800, scale=8)
    t = pol.tile_for("bilinear_cuda", prob, "float32")
    spec = registry.get("bilinear_cuda")
    # Evaluate the chosen tile on the weakest GPU vs its true optimum.
    at = Autotuner()
    best = at.sweep("bilinear_cuda", prob, "float32", GEFORCE_8800GTS).best
    cost_t = estimate(
        GEFORCE_8800GTS, spec.workload(t, prob, "float32"),
        spec.n_tiles(t, prob), spec.vmem_bytes(t, prob, "float32"),
    ).total_s
    assert cost_t <= 1.5 * best.score


def test_cost_model_infeasible_tiles():
    spec = registry.get("bilinear_cuda")
    prob = dict(src_h=800, src_w=800, scale=2)
    big = TileShape((64, 64))  # 4096 threads > 512 limit
    cost = estimate(GTX260, spec.workload(big, prob, "float32"),
                    spec.n_tiles(big, prob), 0.0)
    assert cost.total_s == float("inf")


def test_tpu_compute_bound_large_matmul():
    at = Autotuner()
    res = at.sweep("matmul", dict(m=8192, k=8192, n=8192), "bfloat16", TPU_V5E)
    assert res.best.cost.dominant() == "compute"
    assert res.best.cost.utilization > 0.9


def test_more_cores_less_sensitivity_tpu():
    """§IV.C on TPU descriptors: v6e (bigger) no more sensitive than v5e."""
    at = Autotuner()
    prob = dict(s=4096, f=4096)
    import repro.kernels.rglru.ops  # noqa: F401
    s5 = at.sweep("rglru", prob, "bfloat16", TPU_V5E).sensitivity()
    s6 = at.sweep("rglru", prob, "bfloat16", TPU_V6E).sensitivity()
    assert s6 <= s5 * 1.5
