"""Checkpoint manager: roundtrip, atomicity, retention, elastic restore."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)),
                   "groups": [{"a": jnp.arange(6).reshape(2, 3)}]},
        "opt": {"step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_save=False)
    tree = _tree()
    cm.save(10, tree)
    out = cm.restore(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert cm.meta()["step"] == 10


def test_async_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_save=True)
    tree = _tree(1)
    cm.save(5, tree)
    cm.wait()
    out = cm.restore(tree)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))


def test_retention(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = _tree(2)
    for s in (1, 2, 3, 4):
        cm.save(s, tree)
    assert cm.all_steps() == [3, 4]


def test_latest_and_specific_step(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=5, async_save=False)
    t1, t2 = _tree(3), _tree(4)
    cm.save(1, t1)
    cm.save(2, t2)
    out1 = cm.restore(t1, step=1)
    out2 = cm.restore(t2)
    np.testing.assert_array_equal(np.asarray(out1["params"]["w"]),
                                  np.asarray(t1["params"]["w"]))
    np.testing.assert_array_equal(np.asarray(out2["params"]["w"]),
                                  np.asarray(t2["params"]["w"]))


def test_corrupt_tmp_never_published(tmp_path):
    """A leftover tmp dir (simulated crash) is not visible as a checkpoint."""
    cm = CheckpointManager(str(tmp_path), async_save=False)
    os.makedirs(os.path.join(str(tmp_path), "tmp.99"))
    assert cm.latest_step() is None
    cm.save(1, _tree())
    assert cm.latest_step() == 1


def test_elastic_restore_resharding(tmp_path):
    """Restore device_puts onto provided shardings (new mesh)."""
    cm = CheckpointManager(str(tmp_path), async_save=False)
    tree = {"w": jnp.arange(32.0).reshape(4, 8)}
    cm.save(1, tree)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    sh = {"w": jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", None))}
    out = cm.restore(tree, shardings=sh)
    assert out["w"].sharding.is_equivalent_to(sh["w"], 2)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))


def test_missing_raises(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        cm.restore({"w": jnp.zeros(3)})
