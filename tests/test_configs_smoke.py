"""Per-architecture smoke: reduced config, one forward/train step on CPU,
shape + finiteness asserts; serve prefill+decode; decode==full consistency.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api
from repro.models import transformer as T

# Full per-arch smoke sweep takes >1 min on CPU; CI fast lane skips it.
pytestmark = pytest.mark.slow

ARCHS = configs.list_archs()


def _batch(cfg, key, b=2, s=16):
    tok = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tok, "targets": tok}
    if api.is_vlm(cfg):
        batch["patch_embeds"] = jax.random.normal(
            key, (b, cfg.encoder.seq_len, 1024)) * 0.1
    if api.is_encdec(cfg):
        batch["frames"] = jax.random.normal(
            key, (b, cfg.encoder.seq_len, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_validates(arch):
    cfg = configs.get_arch(arch)
    assert cfg.validate() is cfg
    assert len(cfg.layers()) == cfg.n_layers


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = api.init_params(cfg, key)
    batch = _batch(cfg, key)
    loss, metrics = api.train_loss(params, cfg, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: api.train_loss(p, cfg, batch)[0])(params)
    gsum = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert np.isfinite(gsum) and gsum > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_serve(arch):
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(1)
    params = api.init_params(cfg, key)
    b, s = 2, 16
    batch = _batch(cfg, key, b, s)
    extra = cfg.encoder.seq_len if api.is_vlm(cfg) else 0
    logits, state = api.prefill(params, cfg, batch, max_len=s + extra + 4)
    assert logits.shape == (b, cfg.padded_vocab)
    logits2, state = api.decode_step(params, cfg,
                                     batch["tokens"][:, :1], state)
    assert logits2.shape == (b, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize(
    "arch", ["qwen2-1.5b", "gemma2-9b", "recurrentgemma-9b", "mamba2-2.7b",
             "deepseek-moe-16b"])
def test_decode_matches_full_forward(arch):
    """Stepwise decode with caches == teacher-forced full forward.

    MoE capacity dropping depends on batch composition, so the consistency
    check runs with a no-drop capacity factor (capacity >= tokens).
    """
    import dataclasses
    cfg = configs.get_smoke(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=32.0))
    key = jax.random.PRNGKey(2)
    params = api.init_params(cfg, key)
    b, s = 2, 12
    tok = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    full = T.forward(params, cfg, tok, remat=False).logits
    caches = T.make_caches(cfg, b, s, jnp.float32)
    pre = T.forward(params, cfg, tok[:, :s - 1], caches=caches, remat=False)
    step = T.forward(params, cfg, tok[:, s - 1:], caches=pre.caches,
                     decode=True, remat=False)
    np.testing.assert_allclose(
        np.asarray(step.logits[:, 0]), np.asarray(full[:, -1]),
        rtol=5e-4, atol=5e-4)


def test_fused_loss_matches_materialized():
    cfg = configs.get_smoke("qwen2-1.5b")
    key = jax.random.PRNGKey(3)
    params = api.init_params(cfg, key)
    tok = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    out = T.forward(params, cfg, tok, remat=False)
    ref = T.lm_loss(out.logits, tok, cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    fused = T.fused_lm_loss(head, out.hidden, tok, cfg, chunk=8)
    np.testing.assert_allclose(float(fused), float(ref), rtol=1e-5)


def test_long_500k_applicability():
    from repro.configs.shapes import LONG_500K, applicable
    runs = {a: applicable(configs.get_arch(a), LONG_500K)[0] for a in ARCHS}
    assert runs["recurrentgemma-9b"] and runs["h2o-danube-1.8b"] \
        and runs["mamba2-2.7b"]
    assert not runs["gemma2-9b"] and not runs["command-r-35b"] \
        and not runs["whisper-large-v3"]
    assert sum(runs.values()) == 3
