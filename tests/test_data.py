"""Synthetic data pipeline: determinism, host sharding, checkpointable state."""
import numpy as np

from repro.data.pipeline import DataConfig, DataIterator, make_batch


def test_deterministic():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8)
    b1 = make_batch(cfg, 7)
    b2 = make_batch(cfg, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_steps_differ():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8)
    assert not np.array_equal(make_batch(cfg, 0)["tokens"],
                              make_batch(cfg, 1)["tokens"])


def test_targets_shifted():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=4)
    b = make_batch(cfg, 0)
    assert b["tokens"].shape == (4, 32) and b["targets"].shape == (4, 32)


def test_host_sharding_disjoint():
    c0 = DataConfig(vocab_size=1000, seq_len=16, global_batch=8,
                    num_hosts=2, host_id=0)
    c1 = DataConfig(vocab_size=1000, seq_len=16, global_batch=8,
                    num_hosts=2, host_id=1)
    b0, b1 = make_batch(c0, 3), make_batch(c1, 3)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_tokens_in_vocab():
    cfg = DataConfig(vocab_size=257, seq_len=64, global_batch=4)
    b = make_batch(cfg, 5)
    assert b["tokens"].min() >= 1 and b["tokens"].max() < 257


def test_iterator_resume():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=4)
    it = DataIterator(cfg)
    first = next(it)
    second = next(it)
    state = it.state
    it.close()
    it2 = DataIterator(cfg, start_step=state["step"])
    third = next(it2)
    it2.close()
    ref = make_batch(cfg, state["step"])
    np.testing.assert_array_equal(third["tokens"], ref["tokens"])
    assert not np.array_equal(first["tokens"], third["tokens"])
