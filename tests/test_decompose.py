"""Segment decomposition: periodic patterns scan their repeat unit."""
import pytest

from repro import configs
from repro.configs.base import ArchConfig, LayerSpec, repeat_pattern
from repro.models.transformer import decompose


def _flatten(segs):
    out = []
    for seg in segs:
        if seg[0] == "seq":
            out.extend(seg[1])
        else:
            _, unit, reps = seg
            out.extend(unit * reps)
    return tuple(out)


@pytest.mark.parametrize("arch", configs.list_archs())
def test_decomposition_preserves_pattern(arch):
    cfg = configs.get_arch(arch)
    if cfg.encoder is not None and cfg.encoder.kind == "audio":
        pytest.skip("enc-dec uses its own stacks")
    assert _flatten(decompose(cfg)) == cfg.layers()


def test_alternating_pattern_scans_unit():
    cfg = configs.get_arch("gemma2-9b")
    segs = decompose(cfg)
    assert len(segs) == 1 and segs[0][0] == "scan"
    assert len(segs[0][1]) == 2 and segs[0][2] == 21


def test_griffin_pattern_with_remainder():
    cfg = configs.get_arch("recurrentgemma-9b")
    segs = decompose(cfg)
    kinds = [s[0] for s in segs]
    assert "scan" in kinds
    scan = next(s for s in segs if s[0] == "scan")
    assert len(scan[1]) * scan[2] >= 36   # at least 12 units of 3


def test_prefix_irregular_layer():
    cfg = configs.get_arch("deepseek-moe-16b")
    segs = decompose(cfg)
    assert segs[0][0] == "seq" and len(segs[0][1]) == 1
    assert segs[1][0] == "scan" and segs[1][2] == 27


def test_homogeneous_single_scan():
    cfg = configs.get_arch("mamba2-2.7b")
    segs = decompose(cfg)
    assert len(segs) == 1 and segs[0][0] == "scan" and segs[0][2] == 64
