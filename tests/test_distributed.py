"""Distributed-path correctness on multi-host-device meshes (subprocesses:
device count must be set before jax init, so each case runs isolated).
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=ROOT, timeout=600)
    assert "OK" in out.stdout, (out.stdout[-1000:], out.stderr[-3000:])
    return out.stdout


def test_sharded_flash_decode_matches_full():
    """shard_map LSE-combined decode == single-device full forward."""
    _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.models import api, flags
from repro.models import transformer as T
from repro.distributed import sharding_rules as rules

mesh = jax.make_mesh((2, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = dataclasses.replace(configs.get_smoke("qwen2-1.5b"),
                          n_kv_heads=1, n_heads=4)
params = api.init_params(cfg, jax.random.PRNGKey(0))
B, S = 4, 16
tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
full = T.forward(params, cfg, tok, remat=False).logits
ctx = rules.make_context(mesh)
caches = T.make_caches(cfg, B, S, jnp.float32)
pre = T.forward(params, cfg, tok[:, :S-1], caches=caches, remat=False)
flags.set_perf(decode_sharded=True)
def _step(p, t, c):
    o = T.forward(p, cfg, t, ctx=ctx, caches=c, decode=True, remat=False)
    return o.logits, o.caches
with jax.set_mesh(mesh):
    logits, _ = jax.jit(_step)(params, tok[:, S-1:], pre.caches)
np.testing.assert_allclose(np.asarray(logits[:, 0]), np.asarray(full[:, -1]),
                           rtol=5e-4, atol=5e-4)
print("OK")
""")


def test_moe_ep_sharded_matches_local():
    """shard_map EP MoE == single-device all-experts computation."""
    _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.models import api
from repro.models import transformer as T
from repro.distributed import sharding_rules as rules

mesh = jax.make_mesh((2, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = configs.get_smoke("qwen3-moe-235b-a22b")
cfg = dataclasses.replace(
    cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=32.0))
params = api.init_params(cfg, jax.random.PRNGKey(0))
B, S = 4, 16
tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
ref = T.forward(params, cfg, tok, remat=False).logits

ctx = rules.make_context(mesh)
def f(p, t):
    return T.forward(p, cfg, t, ctx=ctx, remat=False).logits
with jax.set_mesh(mesh):
    out = jax.jit(f)(params, tok)
np.testing.assert_allclose(np.asarray(out, np.float32),
                           np.asarray(ref, np.float32), rtol=2e-3, atol=2e-3)
print("OK")
""")


def test_train_step_runs_on_mesh():
    """One real optimizer step executes on a 4-device mesh (DP x TP)."""
    _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.models import api
from repro.distributed import sharding_rules as rules
from repro.optim import adamw
from repro.train.step import make_train_step

mesh = jax.make_mesh((2, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = configs.get_smoke("qwen2-1.5b")
ctx = rules.make_context(mesh)
params = api.init_params(cfg, jax.random.PRNGKey(0))
ocfg = adamw.AdamWConfig()
opt = adamw.init_state(params, ocfg)
step = make_train_step(cfg, ctx, ocfg, microbatches=2)
tok = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
batch = {"tokens": tok, "targets": tok}
with jax.set_mesh(mesh):
    p2, o2, m = jax.jit(step)(params, opt, batch)
assert np.isfinite(float(m["loss"]))
# params actually changed
d = sum(float(jnp.abs(a - b).sum())
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
assert d > 0
print("OK")
""")
