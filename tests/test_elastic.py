"""Elastic scaling: checkpoint on one mesh, restore onto another.

A training job snapshotted on a 4-device (2x2) mesh restarts on a 2-device
(1x2) mesh — different device count, different shardings — and training
continues bit-correct from the restored step. Runs in subprocesses (device
count must be set before jax initializes).
"""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=ROOT, timeout=600)
    assert "OK" in out.stdout, (out.stdout[-800:], out.stderr[-3000:])
    return out.stdout


def test_checkpoint_crosses_meshes(tmp_path):
    save_code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from repro import configs
from repro.models import api
from repro.checkpoint.manager import CheckpointManager
from repro.distributed import sharding_rules as rules

mesh = jax.make_mesh((2, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = configs.get_smoke("qwen2-1.5b")
params = api.init_params(cfg, jax.random.PRNGKey(7))
shard = rules.param_shardings(api.param_logical_axes(cfg),
                              jax.eval_shape(lambda: params), mesh)
params = jax.tree.map(jax.device_put, params, shard)
cm = CheckpointManager(r"{tmp_path}", async_save=False)
cm.save(42, {{"params": params}})
print("OK", float(jax.tree.leaves(params)[0].sum()))
"""
    out1 = _run(save_code)
    ref_sum = out1.split("OK")[1].strip()

    restore_code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp
from repro import configs
from repro.models import api
from repro.checkpoint.manager import CheckpointManager
from repro.distributed import sharding_rules as rules

mesh = jax.make_mesh((1, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = configs.get_smoke("qwen2-1.5b")
template = jax.eval_shape(
    lambda: api.init_params(cfg, jax.random.PRNGKey(0)))
shard = rules.param_shardings(api.param_logical_axes(cfg), template, mesh)
cm = CheckpointManager(r"{tmp_path}")
tree = cm.restore({{"params": template}}, shardings={{"params": shard}})
leaf = jax.tree.leaves(tree["params"])[0]
assert len(leaf.sharding.device_set) <= 2
# continue training one step on the new mesh
from repro.optim import adamw
from repro.train.step import make_train_step
ctx = rules.make_context(mesh)
ocfg = adamw.AdamWConfig()
opt = adamw.init_state(tree["params"], ocfg)
step = make_train_step(cfg, ctx, ocfg)
tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
with jax.set_mesh(mesh):
    p2, o2, m = jax.jit(step)(tree["params"], opt,
                              {{"tokens": tok, "targets": tok}})
import numpy as np
assert np.isfinite(float(m["loss"]))
print("OK", float(leaf.sum()))
"""
    out2 = _run(restore_code)
    restored_sum = out2.split("OK")[1].strip()
    assert abs(float(ref_sum) - float(restored_sum)) < 1e-3
