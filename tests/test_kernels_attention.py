"""Flash attention kernel + chunked ref vs the dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import (
    attention_dense_ref, flash_attention_ref,
)


def _qkv(b=2, hq=4, hkv=2, s=128, d=32, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d), jnp.float32) * 0.3
    k = jax.random.normal(ks[1], (b, hkv, s, d), jnp.float32) * 0.3
    v = jax.random.normal(ks[2], (b, hkv, s, d), jnp.float32)
    return q, k, v


CASES = [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, window=32),
    dict(causal=True, softcap=30.0),
    dict(causal=True, window=48, softcap=20.0),
]


@pytest.mark.parametrize("kw", CASES)
def test_kernel_vs_dense(kw):
    q, k, v = _qkv()
    ref = attention_dense_ref(q, k, v, **kw)
    out = flash_attention(q, k, v, tile=(32, 32), interpret=True, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("kw", CASES)
def test_chunked_ref_vs_dense(kw):
    q, k, v = _qkv(key=1)
    ref = attention_dense_ref(q, k, v, **kw)
    out = flash_attention_ref(q, k, v, chunk=32, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("tile", [(16, 64), (64, 16), (128, 128)])
def test_tile_independence(tile):
    q, k, v = _qkv(s=128, key=2)
    ref = attention_dense_ref(q, k, v, causal=True)
    out = flash_attention(q, k, v, tile=tile, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("hq,hkv", [(8, 1), (8, 2), (4, 4)])
def test_gqa_ratios(hq, hkv):
    q, k, v = _qkv(hq=hq, hkv=hkv, s=64, key=3)
    ref = attention_dense_ref(q, k, v, causal=True)
    out = flash_attention(q, k, v, tile=(32, 32), causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_q_offset_decode_chunk():
    q, k, v = _qkv(s=128, key=4)
    ref = attention_dense_ref(q[:, :, -32:], k, v, causal=True, q_offset=96)
    out = flash_attention(q[:, :, -32:], k, v, causal=True, q_offset=96,
                          tile=(32, 64), interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_bf16():
    q, k, v = (t.astype(jnp.bfloat16) for t in _qkv(s=64, key=5))
    ref = attention_dense_ref(q, k, v, causal=True)
    out = flash_attention(q, k, v, tile=(32, 32), causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)
