"""Bilinear Pallas kernel vs the pure-jnp oracle (paper Eq. 1-5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bilinear.bilinear import bilinear_upscale
from repro.kernels.bilinear.ref import bilinear_upscale_ref


@pytest.mark.parametrize("scale", [2, 4, 6, 8, 10])
@pytest.mark.parametrize("hw", [(8, 16), (16, 32)])
def test_scales(scale, hw):
    h, w = hw
    src = jax.random.uniform(jax.random.PRNGKey(scale), (h, w), jnp.float32)
    ref = bilinear_upscale_ref(src, scale)
    out = bilinear_upscale(src, scale, tile=(h * scale, w * scale),
                           interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("tile", [(8, 32), (16, 16), (32, 64), (64, 128)])
def test_tile_independence(tile):
    """Any legal tile produces identical output — tiling is pure perf."""
    src = jax.random.uniform(jax.random.PRNGKey(0), (16, 32), jnp.float32)
    ref = bilinear_upscale_ref(src, 4)
    out = bilinear_upscale(src, 4, tile=tile, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    src = jax.random.uniform(jax.random.PRNGKey(1), (16, 16), dtype)
    ref = bilinear_upscale_ref(src.astype(jnp.float32), 2)
    out = bilinear_upscale(src, 2, tile=(16, 32), interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=tol, atol=tol)


def test_identity_scale_1():
    src = jax.random.uniform(jax.random.PRNGKey(2), (8, 128), jnp.float32)
    out = bilinear_upscale(src, 1, tile=(8, 128), interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(src),
                               rtol=1e-6, atol=1e-6)


def test_bad_tile_raises():
    src = jnp.zeros((16, 16), jnp.float32)
    with pytest.raises(ValueError):
        bilinear_upscale(src, 2, tile=(7, 32), interpret=True)
