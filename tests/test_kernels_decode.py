"""Flash-decode parity: chunked ref vs dense decode vs the Pallas kernel.

Grid covers GQA ratios {1, 2, 8}, sliding window on/off, softcap on/off,
full and ring-buffer cache layouts, and uneven ``pos`` vs ``bkv``
boundaries. Acceptance: the flash-decode reference matches the dense decode
oracle to <= 1e-5 in float32 across the whole grid. A hypothesis property
test checks the system-level invariant: decoding one token at a time
reproduces ``attn_forward``'s full-sequence outputs position by position.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.tiling import TileShape
from repro.kernels.flash_attention.decode import (
    fit_bkv, flash_decode, flash_decode_ref,
)
from repro.models import attention as attn_mod
from repro.models.layers import init_tree

TOL = dict(rtol=1e-5, atol=1e-5)


def _dense_decode(q, k, v, kv_pos, pos, window=None, softcap=None,
                  scale=None):
    """The dense masked-softmax oracle — attn_decode's no-tile math."""
    b, hq, d = q.shape
    hkv = k.shape[1]
    n_rep = hq // hkv
    ke = jnp.repeat(k, n_rep, axis=1) if n_rep > 1 else k
    ve = jnp.repeat(v, n_rep, axis=1) if n_rep > 1 else v
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bhk,bhsk->bhs", q.astype(ke.dtype), ke,
                   preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = (kv_pos >= 0) & (kv_pos <= pos)
    if window is not None:
        mask &= kv_pos > pos - window
    s = jnp.where(mask[None, None], s, -2.0e30)
    p = jax.nn.softmax(s, axis=-1).astype(ve.dtype)
    return jnp.einsum("bhs,bhsk->bhk", p, ve,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def _qkv(b=2, hq=4, hkv=2, s=128, d=32, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    q = jax.random.normal(ks[0], (b, hq, d), jnp.float32) * 0.3
    k = jax.random.normal(ks[1], (b, hkv, s, d), jnp.float32) * 0.3
    v = jax.random.normal(ks[2], (b, hkv, s, d), jnp.float32)
    return q, k, v


def _ring_kv_pos(s: int, pos: int) -> jnp.ndarray:
    """Ring layout: slot p % s holds position p for the last ``s`` steps."""
    lo = max(0, pos - s + 1)
    written = np.arange(lo, pos + 1)
    kv_pos = np.full(s, -1, np.int32)
    kv_pos[written % s] = written
    return jnp.asarray(kv_pos)


# GQA ratios 1, 2, 8 x window x softcap — the full parity grid.
GRID = [
    dict(hq=hq, hkv=hkv, window=w, softcap=c)
    for hq, hkv in ((4, 4), (8, 4), (8, 1))
    for w in (None, 48)
    for c in (None, 20.0)
]


@pytest.mark.parametrize("kw", GRID)
def test_ref_vs_dense(kw):
    q, k, v = _qkv(hq=kw["hq"], hkv=kw["hkv"], key=1)
    kv_pos = jnp.arange(128)
    for pos in (0, 77, 127):               # empty-ish, uneven, full cache
        ref = _dense_decode(q, k, v, kv_pos, pos, window=kw["window"],
                            softcap=kw["softcap"])
        out = flash_decode_ref(q, k, v, pos=pos, window=kw["window"],
                               softcap=kw["softcap"], bkv=32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


@pytest.mark.slow
@pytest.mark.parametrize("kw", GRID)
def test_pallas_vs_dense_grid(kw):
    q, k, v = _qkv(hq=kw["hq"], hkv=kw["hkv"], key=2)
    kv_pos = jnp.arange(128)
    ref = _dense_decode(q, k, v, kv_pos, 77, window=kw["window"],
                        softcap=kw["softcap"])
    out = flash_decode(q, k, v, pos=77, window=kw["window"],
                       softcap=kw["softcap"], bkv=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_pallas_vs_dense_smoke():
    """Fast-lane representative of the Pallas grid (rest is slow-marked)."""
    q, k, v = _qkv(hq=8, hkv=2, key=3)
    ref = _dense_decode(q, k, v, jnp.arange(128), 100, window=48,
                        softcap=20.0)
    out = flash_decode(q, k, v, pos=100, window=48, softcap=20.0, bkv=32,
                       interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


@pytest.mark.parametrize("bkv", [16, 32, 128])
def test_tile_independence(bkv):
    """Every legal KV split produces the same result (the tile changes the
    schedule, not the math — the property that makes bkv tunable)."""
    q, k, v = _qkv(key=4)
    base = flash_decode_ref(q, k, v, pos=93, bkv=64)
    out = flash_decode_ref(q, k, v, pos=93, bkv=bkv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base), **TOL)
    pal = flash_decode(q, k, v, pos=93, bkv=bkv, interpret=True)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(base), **TOL)


@pytest.mark.parametrize("pos", [0, 1, 31, 32, 77, 127])
def test_uneven_pos_vs_bkv_boundaries(pos):
    """Valid-key counts that don't align with the split must still match
    (the masked tail of the straddling block, and fully-skipped blocks)."""
    q, k, v = _qkv(key=5)
    ref = _dense_decode(q, k, v, jnp.arange(128), pos)
    for fn, kw in ((flash_decode_ref, {}), (flash_decode, dict(interpret=True))):
        out = fn(q, k, v, pos=pos, bkv=32, **kw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_ring_buffer_cache():
    """Ring layout: slots hold an interleaved window of absolute positions;
    per-key masking must recover exactly the window's keys."""
    s, pos, window = 64, 150, 64
    q, k, v = _qkv(hq=8, hkv=2, s=s, key=6)
    kv_pos = _ring_kv_pos(s, pos)
    ref = _dense_decode(q, k, v, kv_pos, pos, window=window)
    out = flash_decode_ref(q, k, v, pos=pos, kv_pos=kv_pos, window=window,
                           bkv=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)
    pal = flash_decode(q, k, v, pos=pos, kv_pos=kv_pos, window=window,
                       bkv=16, interpret=True)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref), **TOL)


def test_fit_bkv():
    assert fit_bkv(32, 128) == 32
    assert fit_bkv(512, 128) == 128
    assert fit_bkv(32, 96) == 32
    assert fit_bkv(40, 96) == 32          # snaps down to a divisor
    assert fit_bkv(7, 96) == 6


def test_bf16_cache():
    q, k, v = (t.astype(jnp.bfloat16) for t in _qkv(key=7))
    ref = _dense_decode(q, k, v, jnp.arange(128), 90)
    out = flash_decode_ref(q, k, v, pos=90, bkv=32)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# Model level: attn_decode's tile dispatch against its own dense path.
# ---------------------------------------------------------------------------

def _attn_setup(ring=False, max_len=24):
    cfg = configs.get_smoke("qwen2-1.5b")
    p = init_tree(attn_mod.attn_defs(cfg), jax.random.PRNGKey(0),
                  jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 1, cfg.d_model),
                          jnp.float32)
    cache = attn_mod.make_kv_cache(cfg, 2, max_len, jnp.float32, ring=ring)
    return cfg, p, x, cache


def _warm(cfg, p, cache, steps, window=None):
    key = jax.random.PRNGKey(2)
    for i in range(steps):
        key, k1 = jax.random.split(key)
        x = jax.random.normal(k1, (2, 1, cfg.d_model), jnp.float32)
        _, cache = attn_mod.attn_decode(p, cfg, x, cache=cache,
                                        window=window)
    return cache


@pytest.mark.parametrize("bkv", [4, 8, 24])
def test_attn_decode_tile_matches_dense(bkv):
    cfg, p, x, cache = _attn_setup()
    cache = _warm(cfg, p, cache, 7)
    y_dense, c_dense = attn_mod.attn_decode(p, cfg, x, cache=cache)
    y_tile, c_tile = attn_mod.attn_decode(p, cfg, x, cache=cache,
                                          tile=TileShape((bkv,)))
    np.testing.assert_allclose(np.asarray(y_tile), np.asarray(y_dense), **TOL)
    np.testing.assert_allclose(np.asarray(c_tile["k"]),
                               np.asarray(c_dense["k"]), **TOL)
    assert int(c_tile["pos"]) == int(c_dense["pos"])


def test_attn_decode_ring_tile_matches_dense():
    cfg, p, x, cache = _attn_setup(ring=True, max_len=8)
    cache = _warm(cfg, p, cache, 13, window=8)   # wrapped ring
    y_dense, _ = attn_mod.attn_decode(p, cfg, x, cache=cache, window=8)
    y_tile, _ = attn_mod.attn_decode(p, cfg, x, cache=cache, window=8,
                                     tile=TileShape((4,)))
    np.testing.assert_allclose(np.asarray(y_tile), np.asarray(y_dense), **TOL)


def test_decode_step_threads_tile(monkeypatch):
    """api.decode_step(tiles=...) must parameterize the decode lowering."""
    from repro.models import api

    cfg = configs.get_smoke("qwen2-1.5b")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": np.arange(6, dtype=np.int32)[None] + 2}
    _, state = api.prefill(params, cfg, batch, max_len=16)
    tok = jnp.asarray([[3]], jnp.int32)
    seen = []
    real = attn_mod.flash_decode_ref

    def spy(q, k, v, **kw):
        seen.append(kw.get("bkv"))
        return real(q, k, v, **kw)

    monkeypatch.setattr(attn_mod, "flash_decode_ref", spy)
    tiles = {"flash_decode": TileShape((8,))}
    logits_t, _ = api.decode_step(params, cfg, tok, state, tiles=tiles)
    assert 8 in seen                       # plan bkv -> reference KV split
    seen.clear()
    logits_d, _ = api.decode_step(params, cfg, tok, state)
    assert not seen                        # no tile -> dense path
    np.testing.assert_allclose(np.asarray(logits_t), np.asarray(logits_d),
                               rtol=2e-5, atol=2e-5)


def test_tile_fallback_events():
    """Non-dividing clamped tiles must be reported, not silently degraded."""
    cfg, p, x, cache = _attn_setup(max_len=24)
    cache = _warm(cfg, p, cache, 3)
    events = []
    with attn_mod.capture_tile_events(events.append):
        attn_mod.attn_decode(p, cfg, x, cache=cache, tile=TileShape((8,)))
        attn_mod.attn_decode(p, cfg, x, cache=cache, tile=TileShape((7,)))
    assert [e["fallback"] for e in events] == [False, True]
    assert events[1]["kernel"] == "flash_decode"
    assert events[1]["phase"] == "decode"
    assert events[1]["effective"] == 6     # largest divisor of 24 below 7

    # Prefill: the silent min(tile, s) clamp is now counted too.
    xs = jax.random.normal(jax.random.PRNGKey(3), (1, 12, cfg.d_model),
                           jnp.float32)
    positions = jnp.arange(12)[None]
    events.clear()
    with attn_mod.capture_tile_events(events.append):
        attn_mod.attn_forward(p, cfg, xs, positions, tile=TileShape((4, 4)))
        attn_mod.attn_forward(p, cfg, xs, positions, tile=TileShape((8, 8)))
    assert [e["fallback"] for e in events] == [False, True]
    assert events[1]["kernel"] == "flash_attention"
    assert events[1]["phase"] == "prefill"


# ---------------------------------------------------------------------------
# Property: decode one token at a time == attn_forward, position by position.
# ---------------------------------------------------------------------------

try:  # keep the rest of this module runnable without the dev dependency
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _decode_matches_prefill(seed, n, bkv, window):
    cfg = configs.get_smoke("qwen2-1.5b")
    p = init_tree(attn_mod.attn_defs(cfg), jax.random.PRNGKey(seed),
                  jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, n, cfg.d_model),
                          jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(n)[None], (1, n))
    y_full, _ = attn_mod.attn_forward(p, cfg, x, positions, window=window)

    t = max(1, n // 2)
    cache = attn_mod.make_kv_cache(cfg, 1, n, jnp.float32)
    y_pre, cache = attn_mod.attn_forward(p, cfg, x[:, :t], positions[:, :t],
                                         window=window, cache=cache)
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(y_full[:, :t]),
                               rtol=1e-4, atol=1e-4)
    for i in range(t, n):
        y_i, cache = attn_mod.attn_decode(p, cfg, x[:, i:i + 1], cache=cache,
                                          window=window,
                                          tile=TileShape((bkv,)))
        np.testing.assert_allclose(
            np.asarray(y_i[:, 0]), np.asarray(y_full[:, i]),
            rtol=1e-4, atol=1e-4,
            err_msg=f"position {i} (prefill {t}, bkv {bkv})")


if HAVE_HYPOTHESIS:
    @given(
        seed=st.integers(0, 2**16),
        n=st.integers(2, 10),
        bkv=st.integers(2, 12),
        window=st.sampled_from([None, 5]),
    )
    @settings(deadline=None, max_examples=15)
    def test_decode_matches_prefill_position_by_position(seed, n, bkv,
                                                         window):
        _decode_matches_prefill(seed, n, bkv, window)
else:
    @pytest.mark.parametrize(
        "seed,n,bkv,window",
        [(0, 6, 4, None), (1, 9, 7, None), (2, 10, 3, 5), (3, 2, 2, 5)],
    )
    def test_decode_matches_prefill_position_by_position(seed, n, bkv,
                                                         window):
        # hypothesis unavailable: run a fixed sample of the property grid.
        _decode_matches_prefill(seed, n, bkv, window)
