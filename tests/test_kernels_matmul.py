"""Tiled matmul Pallas kernel vs jnp oracle — shape/dtype/tile sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.matmul.matmul import matmul
from repro.kernels.matmul.ref import matmul_ref


@pytest.mark.parametrize("mkn", [(64, 64, 64), (128, 256, 64), (32, 512, 128)])
@pytest.mark.parametrize("tile", [(32, 64, 32), (64, 128, 64)])
def test_shapes_tiles(mkn, tile):
    m, k, n = mkn
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(ka, (m, k), jnp.float32)
    b = jax.random.normal(kb, (k, n), jnp.float32)
    out = matmul(a, b, tile=tile, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, b)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4), (jnp.bfloat16, 3e-2)])
def test_dtypes(dtype, tol):
    ka, kb = jax.random.split(jax.random.PRNGKey(1))
    a = jax.random.normal(ka, (64, 128), jnp.float32).astype(dtype)
    b = jax.random.normal(kb, (128, 64), jnp.float32).astype(dtype)
    out = matmul(a, b, tile=(32, 64, 64), interpret=True)
    ref = matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_k_accumulation_order():
    """Many k-steps accumulate in f32 regardless of input dtype."""
    ka, kb = jax.random.split(jax.random.PRNGKey(2))
    a = jax.random.normal(ka, (32, 1024), jnp.bfloat16)
    b = jax.random.normal(kb, (1024, 32), jnp.bfloat16)
    out = matmul(a, b, tile=(32, 128, 32), interpret=True)
    ref = matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=3e-2, atol=3e-1)


def test_indivisible_raises():
    a = jnp.zeros((33, 64), jnp.float32)
    b = jnp.zeros((64, 64), jnp.float32)
    with pytest.raises(ValueError):
        matmul(a, b, tile=(32, 64, 64), interpret=True)
