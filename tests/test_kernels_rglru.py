"""RG-LRU scan kernel vs oracle; associative-scan analysis path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rglru.ops import rglru
from repro.kernels.rglru.ref import rglru_ref
from repro.kernels.rglru.rglru import rglru_scan
from repro.models import flags


def _inputs(b=2, s=64, f=256, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 5)
    x = jax.random.normal(ks[0], (b, s, f), jnp.float32)
    r = jax.nn.sigmoid(jax.random.normal(ks[1], (b, s, f)))
    i = jax.nn.sigmoid(jax.random.normal(ks[2], (b, s, f)))
    ap = jax.random.normal(ks[3], (f,))
    h0 = jax.random.normal(ks[4], (b, f)) * 0.5
    return x, r, i, ap, h0


@pytest.mark.parametrize("tile", [(16, 128), (32, 256), (64, 128)])
def test_kernel_tiles(tile):
    x, r, i, ap, h0 = _inputs()
    y_ref, h_ref = rglru_ref(x, r, i, ap, h0=h0)
    y, h = rglru(x, r, i, ap, h0=h0, tile=tile, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)


def test_no_initial_state():
    x, r, i, ap, _ = _inputs(key=1)
    y_ref, _ = rglru_ref(x, r, i, ap)
    y, _ = rglru(x, r, i, ap, tile=(16, 128), interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_associative_scan_path_matches():
    x, r, i, ap, h0 = _inputs(s=32, f=64, key=2)
    y1, hl1 = rglru_ref(x, r, i, ap, h0=h0)
    flags.set_analysis_unroll(True)
    try:
        y2, hl2 = rglru_ref(x, r, i, ap, h0=h0)
    finally:
        flags.set_analysis_unroll(False)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hl1), np.asarray(hl2),
                               rtol=2e-4, atol=2e-4)


def test_decay_bounds():
    """|h_t| stays bounded when inputs are bounded (contractive recurrence)."""
    x, r, i, ap, _ = _inputs(s=256, key=3)
    y, h = rglru_ref(x, r, i, ap)
    assert float(jnp.max(jnp.abs(y))) < 50.0


def test_state_continuation():
    """Scanning halves with carried state == scanning the whole sequence."""
    x, r, i, ap, _ = _inputs(key=4)
    y_full, h_full = rglru_ref(x, r, i, ap)
    s = x.shape[1] // 2
    y1, h1 = rglru_ref(x[:, :s], r[:, :s], i[:, :s], ap)
    y2, h2 = rglru_ref(x[:, s:], r[:, s:], i[:, s:], ap, h0=h1)
    np.testing.assert_allclose(np.asarray(y_full[:, s:]), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h2),
                               rtol=1e-4, atol=1e-4)
