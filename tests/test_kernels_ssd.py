"""Mamba-2 SSD: chunked dual form + Pallas kernel vs literal recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_chunked_ref, ssd_ref


def _inputs(b=2, s=64, h=4, p=32, n=16, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 6)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, s, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, n)) * 0.5
    D = jax.random.normal(ks[5], (h,))
    return x, dt, A, Bm, C, D


@pytest.mark.parametrize("chunk", [8, 16, 32, 64])
def test_chunked_vs_recurrent(chunk):
    x, dt, A, Bm, C, D = _inputs()
    y1, h1 = ssd_ref(x, dt, A, Bm, C, D)
    y2, h2 = ssd_chunked_ref(x, dt, A, Bm, C, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("chunk", [16, 32])
def test_kernel_vs_recurrent(chunk):
    x, dt, A, Bm, C, D = _inputs(key=1)
    y1, h1 = ssd_ref(x, dt, A, Bm, C, D)
    y2, h2 = ssd(x, dt, A, Bm, C, D, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=3e-4, atol=3e-4)


def test_initial_state_continuation():
    x, dt, A, Bm, C, D = _inputs(key=2)
    s = x.shape[1] // 2
    y_full, h_full = ssd_ref(x, dt, A, Bm, C, D)
    _, h1 = ssd_ref(x[:, :s], dt[:, :s], A, Bm[:, :s], C[:, :s], D)
    y2, h2 = ssd_chunked_ref(x[:, s:], dt[:, s:], A, Bm[:, s:], C[:, s:], D,
                             h0=h1, chunk=16)
    np.testing.assert_allclose(np.asarray(y_full[:, s:]), np.asarray(y2),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h2),
                               rtol=3e-4, atol=3e-4)


def test_no_d_skip():
    x, dt, A, Bm, C, _ = _inputs(key=3)
    y1, _ = ssd_ref(x, dt, A, Bm, C, None)
    y2, _ = ssd_chunked_ref(x, dt, A, Bm, C, None, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=3e-4, atol=3e-4)


def test_decay_stability_long():
    """Strong decay: outputs remain finite over long sequences."""
    x, dt, A, Bm, C, D = _inputs(s=256, key=4)
    y, h = ssd_chunked_ref(x, dt, A * 4.0, Bm, C, D, chunk=32)
    assert np.isfinite(np.asarray(y)).all()
