"""MoE dispatch invariants: gather dispatch == dense reference, capacity,
gate normalization, shared experts, offset partitioning.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, LayerSpec, MoEConfig
from repro.models.moe import _capacity, moe_apply_local, moe_defs, moe_forward

# Dense-reference MoE comparisons are CPU-heavy; CI fast lane skips them.
pytestmark = pytest.mark.slow
from repro.models.layers import init_tree


def _cfg(n_experts=8, top_k=2, cf=32.0, renorm=True, shared=0):
    return ArchConfig(
        name="moe_test", family="moe", n_layers=1, d_model=32,
        n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=64,
        layer_pattern=(LayerSpec("attn", "moe"),),
        moe=MoEConfig(n_experts=n_experts, top_k=top_k, d_expert=16,
                      capacity_factor=cf, renorm_gates=renorm,
                      n_shared_experts=shared, d_shared=32 * shared),
    ).validate()


def _params(cfg, key=0):
    return init_tree(moe_defs(cfg), jax.random.PRNGKey(key), jnp.float32)


def _dense_reference(p, cfg, x2d):
    """All experts computed densely for every token (no dispatch)."""
    m = cfg.moe
    logits = x2d @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, m.top_k)
    if m.renorm_gates:
        gates = gates / gates.sum(-1, keepdims=True)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", x2d, p["w1"]))
    h = h * jnp.einsum("td,edf->tef", x2d, p["w3"])
    out_all = jnp.einsum("tef,efd->ted", h, p["w2"])   # [T, E, D]
    y = jnp.zeros_like(x2d)
    for k in range(m.top_k):
        sel = jnp.take_along_axis(
            out_all, eidx[:, k][:, None, None].repeat(x2d.shape[1], 2),
            axis=1)[:, 0]
        y = y + gates[:, k][:, None] * sel
    return y


@pytest.mark.parametrize("renorm", [True, False])
def test_dispatch_matches_dense(renorm):
    cfg = _cfg(renorm=renorm)
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (24, 32))
    y, aux = moe_apply_local(p, cfg, x, cfg.moe.n_experts, 0)
    ref = _dense_reference(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_offset_partition_sums_to_full():
    """Two half-expert shards' partial outputs sum to the full result."""
    cfg = _cfg()
    p = _params(cfg, key=2)
    x = jax.random.normal(jax.random.PRNGKey(3), (16, 32))
    full, _ = moe_apply_local(p, cfg, x, cfg.moe.n_experts, 0)

    def shard(lo, n):
        pl = dict(p)
        pl["w1"] = p["w1"][lo:lo + n]
        pl["w3"] = p["w3"][lo:lo + n]
        pl["w2"] = p["w2"][lo:lo + n]
        return moe_apply_local(pl, cfg, x, n, lo)[0]

    part = shard(0, 4) + shard(4, 4)
    np.testing.assert_allclose(np.asarray(part), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_capacity_drops_tokens():
    """With a tiny capacity factor, some contributions are dropped."""
    cfg_lo = _cfg(cf=0.1)
    cfg_hi = _cfg(cf=64.0)
    p = _params(cfg_lo, key=4)
    x = jax.random.normal(jax.random.PRNGKey(5), (256, 32))
    y_lo, _ = moe_apply_local(p, cfg_lo, x, 8, 0)
    y_hi, _ = moe_apply_local(p, cfg_hi, x, 8, 0)
    assert _capacity(256, cfg_lo) < _capacity(256, cfg_hi)
    # Dropped tokens => some rows are zero in the low-capacity output.
    lo_norm = np.linalg.norm(np.asarray(y_lo), axis=-1)
    hi_norm = np.linalg.norm(np.asarray(y_hi), axis=-1)
    assert (lo_norm < 1e-9).sum() > (hi_norm < 1e-9).sum()


def test_shared_experts_always_active():
    cfg = _cfg(shared=2)
    p = _params(cfg, key=6)
    x = jnp.zeros((1, 4, 32))
    x = x.at[0, 0, 0].set(1.0)
    y, _ = moe_forward(p, cfg, x, None)
    # Shared FF contributes even where routed capacity would not.
    assert float(jnp.abs(y[0, 0]).sum()) > 0


def test_gradients_flow_to_router():
    cfg = _cfg()
    p = _params(cfg, key=7)
    x = jax.random.normal(jax.random.PRNGKey(8), (16, 32))

    def loss(p):
        y, aux = moe_apply_local(p, cfg, x, 8, 0)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).sum()) > 0
    assert float(jnp.abs(g["w1"]).sum()) > 0
