"""Conformance suite for the serving trace layer (``repro.obs``).

The contracts under test:

* **determinism** — two virtual-clock runs of the same seed-pinned trace
  export byte-identical files (the trace is a function of the schedule,
  not of wall time);
* **non-interference** — tracing on vs off leaves served tokens and every
  ``ServeMetrics`` aggregate bit-identical, and with tracing disabled the
  step hot path performs zero tracer calls (guard via the
  ``Tracer.record``/``Tracer.defer`` chokepoints);
* **export fidelity** — Chrome-trace and JSONL exports round-trip through
  ``load_trace`` (process names, hardware, timestamps), and the Chrome
  form carries the Perfetto metadata (process/thread names, instant
  scopes, async-span ids) the UI needs;
* **windowed TTFT clipping** — ``ServeMetrics.ttft_window`` flags windows
  wider than the retained circular buffer, and ``FleetRouter.roll_plans``
  treats a clipped window as inconclusive (no confident keep/revert);
* **the diff CLI** — ``repro.launch.trace_report --diff`` exits 0 on an
  identical pair and nonzero when the candidate's p95 TTFT regresses.

Engine-driving tests are marked ``slow`` (the CI packing-conformance lane
runs them next to the packing suite); everything else is fast-lane.
"""
import json
import pathlib
import sys

import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                       / "benchmarks"))

from repro.launch.trace_report import diff, main as report_main  # noqa: E402
from repro.obs import Tracer, load_trace, write_jsonl, write_trace  # noqa: E402
from repro.obs.trace import LANE_STEPS  # noqa: E402
from repro.serve.metrics import (  # noqa: E402
    ServeMetrics, _LatencyStat, nearest_rank,
)

EDGES = (8, 64)
NEW_TOKENS = 3


class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# --------------------------------------------------------------------------
# Tracer core
# --------------------------------------------------------------------------

def test_deferred_step_spans_close_at_next_begin():
    clock = _Clock()
    tr = Tracer(clock=clock)
    p = tr.attach("eng")
    p.step_mark(0.0, {"prefill_tokens": 4}, 1)
    clock.t = 0.5
    p.step_mark(0.5, {"prefill_tokens": 0}, 2)
    # Step 1's span closed when step 2 began, with the inter-step duration.
    spans = [e for e in tr.events if e["name"] == "step"]
    assert len(spans) == 1
    assert spans[0]["ts"] == 0.0 and spans[0]["dur"] == 0.5
    assert spans[0]["args"]["step"] == 1
    clock.t = 0.7
    tr.flush()
    spans = [e for e in tr.events if e["name"] == "step"]
    assert len(spans) == 2
    assert spans[1]["ts"] == 0.5 and abs(spans[1]["dur"] - 0.2) < 1e-12
    # Idempotent: a second flush adds nothing.
    n = len(tr.events)
    tr.flush()
    assert len(tr.events) == n


def test_ttft_span_reproduces_metrics_sample():
    clock = _Clock()
    tr = Tracer(clock=clock)
    p = tr.attach("eng")
    clock.t = 1.25
    p.first_token(7, 64, 1.0)
    span = [e for e in tr.events if e["name"] == "ttft"][0]
    assert span["ts"] == 1.0 and span["dur"] == 0.25
    assert span["args"] == {"rid": 7, "bucket": 64}
    # No submit time -> instant only, no span (metrics recorded nothing).
    p.first_token(8, 64, None)
    assert len([e for e in tr.events if e["name"] == "ttft"]) == 1


def _tiny_trace(tmp_path, name="t.json"):
    clock = _Clock()
    tr = Tracer(clock=clock)
    p = tr.attach("engine-a", hardware="tpu_v5e")
    p.submit(1, 10, 8)
    clock.t = 0.5
    p.admit(1, 10, 0.5)
    p.step_mark(0.5, {"prefill_tokens": 10, "packed_chunks": 2}, 1)
    clock.t = 1.0
    p.first_token(1, 8, 0.0)
    p.finish(1, 3)
    path = str(tmp_path / name)
    write_trace(tr, path)
    return tr, path


def test_chrome_round_trip(tmp_path):
    tr, path = _tiny_trace(tmp_path)
    loaded = load_trace(path)
    assert loaded["procs"] == [{"pid": 1, "name": "engine-a",
                                "hardware": "tpu_v5e"}]
    names = [e["name"] for e in loaded["events"]]
    for expected in ("submit", "admit", "step", "ttft", "finish", "req"):
        assert expected in names, f"{expected} lost in round-trip"
    ttft = [e for e in loaded["events"] if e["name"] == "ttft"][0]
    assert abs(ttft["ts"] - 0.0) < 1e-9 and abs(ttft["dur"] - 1.0) < 1e-9


def test_chrome_export_is_perfetto_shaped(tmp_path):
    _, path = _tiny_trace(tmp_path)
    doc = json.load(open(path))
    evs = doc["traceEvents"]
    meta = {(e["name"], e["pid"], e["tid"]) for e in evs if e["ph"] == "M"}
    assert ("process_name", 1, 0) in meta
    assert ("thread_name", 1, LANE_STEPS) in meta
    by_name = {}
    for e in evs:
        by_name.setdefault(e["name"], []).append(e)
    assert all(e.get("s") == "t" for e in by_name["submit"])
    # Async req span pair carries a shared id (Perfetto groups by it).
    assert {e["ph"] for e in by_name["req"]} == {"b", "e"}
    assert {e["id"] for e in by_name["req"]} == {1}
    assert doc["otherData"]["trace_schema"] == 1


def test_jsonl_round_trip(tmp_path):
    clock = _Clock()
    tr = Tracer(clock=clock)
    p = tr.attach("eng", kind="engine", hardware="tpu_v4")
    p.submit(3, 5, 8)
    clock.t = 0.25
    p.first_token(3, 8, 0.0)
    path = str(tmp_path / "t.jsonl")
    write_jsonl(tr, path)
    loaded = load_trace(path)
    assert loaded["procs"][0]["name"] == "eng"
    assert loaded["procs"][0]["hardware"] == "tpu_v4"
    ttft = [e for e in loaded["events"] if e["name"] == "ttft"][0]
    assert ttft["dur"] == 0.25  # JSONL stores raw seconds, no unit cooking


# --------------------------------------------------------------------------
# ttft_window clipping + the roll_plans guard
# --------------------------------------------------------------------------

def test_ttft_window_flags_clipped_buffer():
    m = ServeMetrics(clock=lambda: 0.0)
    m.ttft[64] = _LatencyStat(sample_cap=4)
    for i in range(6):
        m.ttft[64].record(0.01 * (i + 1))
    samples, clipped = m.ttft_window()          # whole run: 6 > 4 retained
    assert clipped and len(samples) == 4
    samples, clipped = m.ttft_window({64: 3})   # window of 3 <= 4 retained
    assert not clipped and len(samples) == 3
    # The newest three, oldest first — circular buffer decoded correctly.
    assert samples == [0.04, 0.05, 0.06]
    assert m.ttft_p95({64: 3}) == nearest_rank(samples, 0.95)


class _StubEngine:
    """Duck-typed engine for roll_plans: metrics + plans + set_plans."""

    def __init__(self, sample_cap):
        self.metrics = ServeMetrics(clock=lambda: 0.0)
        self.metrics.ttft[64] = _LatencyStat(sample_cap=sample_cap)
        self.plans = object()
        self.swaps = []

    def set_plans(self, plans):
        self.swaps.append(plans)
        self.plans = plans


def _roll(sample_cap, n_probe):
    """One roll_plans pass where the post window regresses 100x."""
    from repro.serve.fleet import FleetRouter

    eng = _StubEngine(sample_cap)
    phase = {"n": 0}

    def probe(name):
        phase["n"] += 1
        dt = 0.01 if phase["n"] == 1 else 1.0
        for _ in range(n_probe):
            eng.metrics.ttft[64].record(dt)

    router = FleetRouter({"a": eng}, policy=None)
    new = object()
    (decision,) = router.roll_plans(new, drive_fn=probe, tolerance=1.10)
    return eng, new, decision


@pytest.mark.parametrize("sample_cap,n_probe,want_clipped,want_rollback", [
    (8192, 6, False, True),   # healthy window: 100x regression reverts
    (4, 6, True, False),      # window outgrew the buffer: inconclusive
])
def test_roll_plans_treats_clipped_windows_as_thin(
        sample_cap, n_probe, want_clipped, want_rollback):
    eng, new, decision = _roll(sample_cap, n_probe)
    assert decision.clipped is want_clipped
    assert decision.rolled_back is want_rollback
    if want_rollback:
        assert eng.swaps[-1] is not new and eng.plans is not new
    else:
        # Clipped: the swap stands unguarded, no revert happened.
        assert eng.swaps == [new] and eng.plans is new


# --------------------------------------------------------------------------
# trace_report + diff CLI
# --------------------------------------------------------------------------

def _trace_with_ttfts(tmp_path, name, durs, packed_steps=()):
    clock = _Clock()
    tr = Tracer(clock=clock)
    p = tr.attach("eng")
    for i, d in enumerate(durs):
        clock.t = float(i) + d
        p.first_token(i, 64, float(i))
    for i, n in enumerate(packed_steps):
        p.step_mark(clock.t + i, {"packed_chunks": n}, i + 1)
    clock.t += len(packed_steps) + 1.0
    path = str(tmp_path / name)
    write_trace(tr, path)
    return path


def test_diff_flags_ttft_and_occupancy_regressions(tmp_path):
    base = load_trace(_trace_with_ttfts(
        tmp_path, "base.json", [0.01] * 10, packed_steps=[3, 3, 3]))
    slow = load_trace(_trace_with_ttfts(
        tmp_path, "slow.json", [0.10] * 10, packed_steps=[3, 3, 3]))
    sparse = load_trace(_trace_with_ttfts(
        tmp_path, "sparse.json", [0.01] * 10, packed_steps=[1, 1, 1]))
    assert diff(base, base) == []
    breaches = diff(base, slow)
    assert len(breaches) == 1 and "ttft p95" in breaches[0]
    breaches = diff(base, sparse)
    assert len(breaches) == 1 and "occupancy" in breaches[0]
    # Tolerance is respected: a 5% drift under a 1.10 gate is clean.
    near = load_trace(_trace_with_ttfts(tmp_path, "near.json",
                                        [0.0105] * 10,
                                        packed_steps=[3, 3, 3]))
    assert diff(base, near) == []


def test_report_cli_exit_codes(tmp_path, capsys):
    base = _trace_with_ttfts(tmp_path, "base.json", [0.01] * 10)
    cand = _trace_with_ttfts(tmp_path, "cand.json", [0.10] * 10)
    assert report_main([base]) == 0                       # summary
    assert report_main([base, base, "--diff"]) == 0       # identical pair
    assert report_main([base, cand, "--diff"]) == 1       # regression
    assert report_main([cand, base, "--diff"]) == 0       # improvement
    assert report_main([base, "--diff"]) == 2             # usage
    assert report_main([str(tmp_path / "missing.json")]) == 2
    capsys.readouterr()
    assert report_main([base, cand, "--diff", "--json"]) == 1
    out = json.loads(capsys.readouterr().out)
    assert out["breaches"] and out["base"]["ttft"]["n"] == 10


# --------------------------------------------------------------------------
# Engine integration (slow: drives the real ServeEngine)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_model():
    import jax

    from repro import configs
    from repro.models import api

    cfg = configs.get_smoke("qwen2-1.5b")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(n=8, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, 64, size=int(s)).astype(np.int32)
            for s in rng.integers(4, 40, size=n)]


def _drive_traced(cfg, params, tracer, instance="eng"):
    """Packed-prefill engine on a virtual clock; fixed arrivals."""
    from repro.serve import BucketPolicy, ServeEngine, ShapeBucketScheduler

    clock = _Clock()
    if tracer is not None:
        tracer.clock = clock
    eng = ServeEngine(
        cfg, params, max_len=max(EDGES) + 16, slots=2,
        scheduler=ShapeBucketScheduler(BucketPolicy(EDGES, max_queue=99)),
        clock=clock, chunk_prefill=True, pack_prefill=True,
        prefill_slots=3, step_token_budget=32,
        tracer=tracer, instance=instance)
    prompts = _prompts()
    for i, prompt in enumerate(prompts):
        eng.add_request(prompt, max_new_tokens=NEW_TOKENS)
        if i % 3 == 2:
            eng.step()
            clock.t += 1e-3
    for _ in range(500):
        if not (eng.step() or eng.scheduler.pending()):
            break
        clock.t += 1e-3
    if tracer is not None:
        tracer.flush()
    return eng


@pytest.mark.slow
def test_two_virtual_clock_runs_export_byte_identical(smoke_model, tmp_path):
    cfg, params = smoke_model
    paths = []
    for run in ("a", "b"):
        tracer = Tracer()
        _drive_traced(cfg, params, tracer)
        path = str(tmp_path / f"run_{run}.json")
        write_trace(tracer, path)
        paths.append(path)
    a, b = (open(p, "rb").read() for p in paths)
    assert a == b, "same seed-pinned virtual-clock run, different bytes"
    assert len(load_trace(paths[0])["events"]) > 0


@pytest.mark.slow
def test_tracing_on_off_leaves_service_bit_identical(smoke_model):
    cfg, params = smoke_model
    eng_off = _drive_traced(cfg, params, None)
    eng_on = _drive_traced(cfg, params, Tracer())
    tokens_off = {r.rid: tuple(r.out_tokens) for r in eng_off._finished}
    tokens_on = {r.rid: tuple(r.out_tokens) for r in eng_on._finished}
    assert tokens_on == tokens_off and tokens_off
    assert eng_on.metrics.as_dict() == eng_off.metrics.as_dict()


@pytest.mark.slow
def test_disabled_tracing_makes_zero_tracer_calls(smoke_model, monkeypatch):
    cfg, params = smoke_model
    calls = {"n": 0}
    real_record, real_defer = Tracer.record, Tracer.defer

    def counting_record(self, *a, **k):
        calls["n"] += 1
        return real_record(self, *a, **k)

    def counting_defer(self, *a, **k):
        calls["n"] += 1
        return real_defer(self, *a, **k)

    monkeypatch.setattr(Tracer, "record", counting_record)
    monkeypatch.setattr(Tracer, "defer", counting_defer)
    eng = _drive_traced(cfg, params, None)
    assert eng._trace is None
    assert eng.metrics.completed > 0
    assert calls["n"] == 0, "hot path touched the tracer while disabled"
