"""AdamW, schedule, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw
from repro.optim.compression import _quantize, init_error
from repro.optim.schedule import warmup_cosine


def test_adamw_minimizes_quadratic():
    cfg = adamw.AdamWConfig(weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw.init_state(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.apply_updates(params, g, state, cfg,
                                               lr=jnp.asarray(0.1))
    assert float(loss(params)) < 1e-3


def test_clipping():
    cfg = adamw.AdamWConfig(clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    state = adamw.init_state(params, cfg)
    g = {"w": jnp.full(4, 100.0)}
    _, _, m = adamw.apply_updates(params, g, state, cfg, lr=jnp.asarray(0.0))
    assert float(m["grad_norm"]) == pytest.approx(200.0)
    assert float(m["clip_scale"]) == pytest.approx(1.0 / 200.0)


def test_bf16_moments():
    cfg = adamw.AdamWConfig(moment_dtype="bfloat16")
    params = {"w": jnp.ones((4, 4))}
    state = adamw.init_state(params, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((4, 4))}
    p2, s2, _ = adamw.apply_updates(params, g, state, cfg, lr=jnp.asarray(0.01))
    assert s2["m"]["w"].dtype == jnp.bfloat16
    assert not np.allclose(np.asarray(p2["w"]), np.asarray(params["w"]))


def test_schedule_shape():
    lr0 = float(warmup_cosine(jnp.asarray(0), peak_lr=1e-3, warmup_steps=10,
                              total_steps=100))
    lr_peak = float(warmup_cosine(jnp.asarray(10), peak_lr=1e-3,
                                  warmup_steps=10, total_steps=100))
    lr_end = float(warmup_cosine(jnp.asarray(100), peak_lr=1e-3,
                                 warmup_steps=10, total_steps=100))
    assert lr0 == pytest.approx(0.0)
    assert lr_peak == pytest.approx(1e-3)
    assert lr_end == pytest.approx(1e-4, rel=0.05)


def test_quantize_dequantize_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,))
    q, scale = _quantize(x)
    err = np.abs(np.asarray(q, np.float32) * float(scale) - np.asarray(x))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_error_feedback_accumulates_residual():
    """EF keeps the quantization residual so the running sum is unbiased."""
    from repro.optim.compression import compress_psum
    # Single-device 'mesh': axis size 1 via shard_map over a 1-element axis.
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (64,)) * 1e-3}
    e = init_error(g)

    from jax import shard_map
    from jax.sharding import PartitionSpec as P

    def f(gg, ee):
        return compress_psum(gg, ee, ("data",))

    total_true = np.zeros(64)
    total_deq = np.zeros(64)
    for i in range(20):
        out, e = shard_map(f, mesh=mesh, in_specs=(P(), P()),
                           out_specs=(P(), P()), check_vma=False)(g, e)
        total_true += np.asarray(g["w"])
        total_deq += np.asarray(out["w"])
    # With EF, cumulative dequantized sum tracks the true sum closely.
    scale = np.abs(np.asarray(g["w"])).max() / 127.0
    assert np.abs(total_true - total_deq).max() <= 3 * scale
