"""The paper's empirical claims, reproduced through the calibrated cost model.

Each test pins one claim from the paper (section references inline). The
cost model is calibrated against the paper's own Table I hardware
descriptors; see benchmarks/bench_bilinear_fig3.py for the full sweep.
"""
import itertools

import pytest

import repro.kernels.bilinear.ops  # noqa: F401  (registers bilinear_cuda)
from repro.core import Autotuner, GEFORCE_8800GTS, GTX260, TilingPolicy
from repro.core import registry
from repro.core.cost_model import estimate
from repro.core.tiling import TileShape

# The paper's sweep axis (Fig. 3): CUDA (x=width, y=height); our TileShape
# is (height, width).
SWEEP = [TileShape((h, w)) for h, w in itertools.product((4, 8, 16, 32),
                                                         repeat=2)]
AT = Autotuner()


def _prob(scale):
    return dict(src_h=800, src_w=800, scale=scale)


def _cost(hw, prob, tile):
    spec = registry.get("bilinear_cuda")
    return estimate(hw, spec.workload(tile, prob, "float32"),
                    spec.n_tiles(tile, prob), 0.0).total_s


def test_central_claim_optima_differ_across_models():
    """§IV/§V: the best tile on one GPU model is not the best on another."""
    diffs = 0
    for scale in (2, 4, 6, 8, 10):
        b1 = AT.sweep("bilinear_cuda", _prob(scale), "float32", GTX260,
                      tiles=SWEEP).best.tile
        b2 = AT.sweep("bilinear_cuda", _prob(scale), "float32",
                      GEFORCE_8800GTS, tiles=SWEEP).best.tile
        diffs += b1 != b2
    assert diffs >= 1


def test_fig4_wide_beats_tall():
    """Fig. 4: at fixed thread count, row-major-wide tiles win (both GPUs)."""
    prob = _prob(8)
    for hw in (GTX260, GEFORCE_8800GTS):
        assert _cost(hw, prob, TileShape((4, 8))) < \
            _cost(hw, prob, TileShape((8, 4)))
        assert _cost(hw, prob, TileShape((4, 32))) < \
            _cost(hw, prob, TileShape((32, 4)))


def test_sensitivity_higher_on_smaller_gpu_at_large_scales():
    """§IV.C: fewer cores => more tile-shape sensitivity (scales >= 6)."""
    for scale in (6, 8):
        s1 = AT.sweep("bilinear_cuda", _prob(scale), "float32", GTX260,
                      tiles=SWEEP).sensitivity()
        s2 = AT.sweep("bilinear_cuda", _prob(scale), "float32",
                      GEFORCE_8800GTS, tiles=SWEEP).sensitivity()
        assert s2 > s1


def test_occupancy_cliff_512_thread_tiles():
    """§III.B: a 32x16 tile fills GTX260 (2x512 active) but leaves the
    8800GTS at 512/768 — its relative cost vs the best tile is worse there."""
    prob = _prob(4)
    t = TileShape((16, 32))  # 512 threads
    rel_gtx = _cost(GTX260, prob, t) / AT.sweep(
        "bilinear_cuda", prob, "float32", GTX260, tiles=SWEEP).best.score
    rel_8800 = _cost(GEFORCE_8800GTS, prob, t) / AT.sweep(
        "bilinear_cuda", prob, "float32", GEFORCE_8800GTS,
        tiles=SWEEP).best.score
    assert rel_8800 > rel_gtx


def test_32x4_robust_choice():
    """§V conclusion: 32x4 is within ~10% of optimal on the worst-case GPU
    at every scale, and the robust policy picks a 32-wide small-height tile."""
    for scale in (2, 4, 6, 8, 10):
        best = AT.sweep("bilinear_cuda", _prob(scale), "float32",
                        GEFORCE_8800GTS, tiles=SWEEP).best.score
        c = _cost(GEFORCE_8800GTS, _prob(scale), TileShape((4, 32)))
        assert c <= 1.10 * best, scale

    pol = TilingPolicy(mode="robust", fleet=(GTX260, GEFORCE_8800GTS))
    t = pol.tile_for("bilinear_cuda", _prob(8), "float32")
    assert t[1] >= 32 and t[0] <= 8  # wide, shallow — the 32x4 principle
