"""Pipeline parallelism (GPipe over the pod axis): correctness vs sequential."""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=ROOT, timeout=600)
    assert "OK" in out.stdout, (out.stdout[-800:], out.stderr[-3000:])
    return out.stdout


def test_gpipe_matches_sequential():
    _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ArchConfig
from repro.distributed import pipeline as PP

cfg = ArchConfig(name="pp_test", family="dense", n_layers=4, d_model=64,
                 n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                 vocab_size=128, tie_embeddings=True).validate()
mesh = jax.make_mesh((2,), ("pod",),
                     axis_types=(jax.sharding.AxisType.Auto,))
params = PP.init_pipeline_params(cfg, jax.random.PRNGKey(0), n_stages=2)
sh = PP.pipeline_shardings(params, mesh)
params = jax.tree.map(jax.device_put, params, sh)

tok = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
loss_fn = PP.make_pipeline_loss(cfg, mesh, n_stages=2, n_microbatches=2)
with jax.set_mesh(mesh):
    pp_loss = jax.jit(loss_fn)(params, tok, tok)
ref = PP.sequential_reference_loss(cfg, jax.device_get(params), tok, tok)
np.testing.assert_allclose(float(pp_loss), float(ref), rtol=2e-4)

# gradients flow through the pipeline (ppermute transpose)
g = jax.jit(jax.grad(lambda p: loss_fn(p, tok, tok)))(params)
gn = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g))
assert np.isfinite(gn) and gn > 0
print("OK")
""")
