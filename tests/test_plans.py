"""AOT tile plans: artifact round-trip, resolution order, hot-path no-sweep.

Covers the plan-store contract end to end: save/load with schema checking,
corrupt-file recovery, exact-hit vs nearest-shape vs cross-hardware
resolution (with the transfer warning), Autotuner cache interop, and the
acceptance property that ServeEngine/Trainer construction resolves tiles
from a compiled plan without ever invoking ``Autotuner.sweep``.
"""
import json
import warnings

import jax
import numpy as np
import pytest

import repro.kernels.flash_attention.ops  # noqa: F401  (registers kernels)
import repro.kernels.matmul.ops  # noqa: F401
from repro import configs
from repro.core import (
    PLAN_SCHEMA_VERSION, PRODUCTION_TARGET, TPU_V5E, TPU_V6E, Autotuner,
    TilingPolicy,
)
from repro.core.autotuner import Autotuner as AutotunerClass
from repro.core.plans import (
    PlanSchemaError, PlanTransferWarning, PlanVersionWarning, TilePlan,
    compile_plan,
)
from repro.core.tiling import TileShape
from repro.data.pipeline import DataConfig
from repro.launch import compile_plans as compile_plans_cli
from repro.launch.specs import kernel_problems
from repro.models import api
from repro.serve.engine import ServeEngine
from repro.train.trainer import Trainer, TrainerConfig

PROB = dict(m=1024, k=1024, n=1024)


@pytest.fixture(scope="module")
def plan():
    return compile_plan([
        ("matmul", PROB, "bfloat16", TPU_V5E),
        ("matmul", dict(m=2048, k=1024, n=1024), "bfloat16", TPU_V6E),
    ])


# -- artifact round-trip ----------------------------------------------------

def test_roundtrip(tmp_path, plan):
    path = str(tmp_path / "plans.json")
    plan.save(path)
    loaded = TilePlan.load(path)
    assert len(loaded) == len(plan) == 2
    orig = plan.lookup("matmul", PROB, "bfloat16", TPU_V5E.name)
    back = loaded.lookup("matmul", PROB, "bfloat16", TPU_V5E.name)
    assert back is not None and back.tile == orig.tile
    assert back.curve == orig.curve and back.curve  # full sensitivity curve
    assert json.load(open(path))["schema_version"] == PLAN_SCHEMA_VERSION


def test_corrupt_artifact_recovery(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    with pytest.raises(PlanSchemaError):
        TilePlan.load(str(bad))
    assert TilePlan.load_or_none(str(bad)) is None
    assert TilePlan.load_or_none(str(tmp_path / "missing.json")) is None
    assert TilePlan.load_or_none(None) is None


def test_schema_version_and_field_validation(tmp_path, plan):
    path = tmp_path / "stale.json"
    d = plan.to_dict()
    d["schema_version"] = PLAN_SCHEMA_VERSION + 1
    path.write_text(json.dumps(d))
    with pytest.raises(PlanSchemaError, match="schema version"):
        TilePlan.load(str(path))

    d = plan.to_dict()
    del d["entries"][0]["tile"]
    with pytest.raises(PlanSchemaError, match="missing field"):
        TilePlan.from_dict(d)

    d = plan.to_dict()
    d["entries"][0]["tile"] = [0, -1]
    with pytest.raises(PlanSchemaError, match="bad tile"):
        TilePlan.from_dict(d)


@pytest.mark.parametrize("old_version", [1, 2])
def test_old_schema_artifact_loads_with_warning(tmp_path, plan, old_version):
    """The v1 -> v2 (packed_prefill serving cells) and v2 -> v3 (refinement
    provenance) bumps are clean: old artifacts still load — entries intact,
    resolutions unchanged — but emit PlanVersionWarning so operators
    recompile."""
    path = tmp_path / f"v{old_version}.json"
    d = plan.to_dict()
    assert d["schema_version"] == PLAN_SCHEMA_VERSION == 3
    d["schema_version"] = old_version
    path.write_text(json.dumps(d))
    with pytest.warns(PlanVersionWarning,
                      match=f"old schema version {old_version}"):
        loaded = TilePlan.load(str(path))
    assert len(loaded) == len(plan)
    assert loaded.resolve("matmul", PROB, "bfloat16",
                          TPU_V5E).source == "exact"
    # load_or_none keeps the degrade-don't-crash contract for compat loads.
    with pytest.warns(PlanVersionWarning):
        assert TilePlan.load_or_none(str(path)) is not None


def test_type_malformed_entries_degrade_not_crash(tmp_path, plan):
    # Coercion failures (str score, ragged curve point) must be schema
    # errors so load_or_none degrades instead of crashing serve/train init.
    for mutate in (
        lambda es: es[0].__setitem__("score_s", "fast"),
        lambda es: es[0].__setitem__("curve", [[[1, 2, 3]]]),
        lambda es: es.__setitem__(0, 5),  # non-object entry
    ):
        d = plan.to_dict()
        mutate(d["entries"])
        path = tmp_path / "malformed.json"
        path.write_text(json.dumps(d))
        with pytest.raises(PlanSchemaError):
            TilePlan.load(str(path))
        assert TilePlan.load_or_none(str(path)) is None


# -- resolution order -------------------------------------------------------

def test_exact_hit(plan):
    res = plan.resolve("matmul", PROB, "bfloat16", TPU_V5E)
    assert res.source == "exact"
    assert res.tile == plan.lookup("matmul", PROB, "bfloat16",
                                   TPU_V5E.name).tile


def test_nearest_shape_same_hardware(plan):
    with warnings.catch_warnings():
        warnings.simplefilter("error", PlanTransferWarning)  # must not fire
        res = plan.resolve("matmul", dict(m=4096, k=1024, n=1024),
                           "bfloat16", TPU_V5E)
    assert res.source == "nearest_shape"
    assert res.distance > 0
    # The donor tile must be legal for the target problem (clamped).
    assert all(d <= m for d, m in zip(res.tile.dims, (4096, 1024, 1024)))


def test_cross_hardware_transfer_warns(plan):
    # v6e has no entry for PROB's shape family only on other hardware? It
    # does have m=2048 — so ask for a dtype/hw cell that only v5e covers.
    only_v5e = compile_plan([("matmul", PROB, "bfloat16", TPU_V5E)])
    with pytest.warns(PlanTransferWarning, match="not portable"):
        res = only_v5e.resolve("matmul", PROB, "bfloat16", TPU_V6E)
    assert res.source == "cross_hardware"
    assert res.donor_hardware == TPU_V5E.name
    assert np.isfinite(res.score_s)


def test_resolution_priority(plan):
    # Target (m=2048, v5e): the v6e entry matches the problem EXACTLY but
    # sits on other hardware; the v5e entry is a nearest-shape neighbour.
    # Same-hardware nearest-shape must win over cross-hardware exact.
    res = plan.resolve("matmul", dict(m=2048, k=1024, n=1024),
                       "bfloat16", TPU_V5E)
    assert res.source == "nearest_shape"
    assert res.entry.hardware == TPU_V5E.name


def test_resolve_unknown_kernel_returns_none(plan):
    assert plan.resolve("nope", dict(x=1), "bfloat16", TPU_V5E) is None


def test_fallbacks_can_be_disabled(plan):
    only_v5e = compile_plan([("matmul", PROB, "bfloat16", TPU_V5E)])
    assert only_v5e.resolve("matmul", PROB, "bfloat16", TPU_V6E,
                            allow_transfer=False) is None
    assert plan.resolve("matmul", dict(m=4096, k=1024, n=1024), "bfloat16",
                        TPU_V5E, allow_nearest=False,
                        allow_transfer=False) is None


# -- Autotuner / policy interop ---------------------------------------------

def test_autotuner_plan_lookup_skips_sweep(tmp_path, plan):
    cache = str(tmp_path / "cache.json")
    at = Autotuner(cache_path=cache, plans=plan)
    tile = at.best_tile("matmul", PROB, "bfloat16", TPU_V5E)
    assert at.sweep_count == 0
    assert tile == plan.resolve("matmul", PROB, "bfloat16", TPU_V5E).tile
    # The hit lands in the persistent cache tagged with its provenance...
    entry = at.cached()[Autotuner._key("matmul", PROB, "bfloat16",
                                       TPU_V5E.name)]
    assert entry["source"] == "plan:exact"
    # ...and a fresh plan-less Autotuner serves it from the cache file.
    at2 = Autotuner(cache_path=cache)
    assert at2.best_tile("matmul", PROB, "bfloat16", TPU_V5E) == tile
    assert at2.sweep_count == 0


def test_autotuner_does_not_persist_approximate_tiles(tmp_path):
    # Cross-hardware and nearest-shape tiles are provisional; they must not
    # enter the durable cache — even when a LATER exact hit flushes the
    # whole cache — so a corrected artifact wins after restart.
    cache = str(tmp_path / "cache.json")
    only_v5e = compile_plan([("matmul", PROB, "bfloat16", TPU_V5E)])
    at = Autotuner(cache_path=cache, plans=only_v5e)
    with pytest.warns(PlanTransferWarning):
        at.best_tile("matmul", PROB, "bfloat16", TPU_V6E)
    near_prob = dict(m=2048, k=1024, n=1024)
    at.best_tile("matmul", near_prob, "bfloat16", TPU_V5E)  # nearest_shape
    at.best_tile("matmul", PROB, "bfloat16", TPU_V5E)       # exact -> flush
    assert at.sweep_count == 0
    v6e_key = Autotuner._key("matmul", PROB, "bfloat16", TPU_V6E.name)
    near_key = Autotuner._key("matmul", near_prob, "bfloat16", TPU_V5E.name)
    v5e_key = Autotuner._key("matmul", PROB, "bfloat16", TPU_V5E.name)
    assert at.cached()[v6e_key]["source"] == "plan:cross_hardware"  # in-mem
    assert at.cached()[near_key]["source"] == "plan:nearest_shape"
    durable = json.load(open(cache))
    assert v5e_key in durable
    assert v6e_key not in durable and near_key not in durable


def test_autotuner_falls_back_to_sweep_off_plan(plan):
    at = Autotuner(plans=plan)
    at.best_tile("flash_attention",
                 dict(sq=512, skv=512, d=128, hq=4, hkv=4, window=0),
                 "bfloat16", TPU_V5E)
    assert at.sweep_count == 1  # kernel not in the plan: lazy tuning remains


def test_policy_consults_plans_first(plan):
    pol = TilingPolicy(mode="heuristic", hardware=TPU_V5E, plans=plan)
    assert pol.tile_for("matmul", PROB) == plan.resolve(
        "matmul", PROB, "bfloat16", TPU_V5E).tile


def test_policy_tuned_mode_cache_outranks_plan(plan):
    # Tuned mode goes through the autotuner so an exact cache entry (e.g. a
    # measured tile) is not shadowed by an approximate plan resolution.
    at = Autotuner(plans=plan)
    measured = TileShape((8, 128, 128))
    at._cache[Autotuner._key("matmul", PROB, "bfloat16",
                             TPU_V5E.name)] = {"tile": list(measured.dims)}
    pol = TilingPolicy(mode="tuned", hardware=TPU_V5E, autotuner=at,
                       plans=plan)
    assert pol.tile_for("matmul", PROB) == measured
    assert at.sweep_count == 0


def test_robust_mode_ignores_plans(plan):
    # Robust mode's contract is the fleet worst-case minimum; a plan entry
    # for one hardware model must not silently replace it.
    with_plans = TilingPolicy(mode="robust", fleet=(TPU_V5E, TPU_V6E),
                              hardware=TPU_V5E, plans=plan)
    without = TilingPolicy(mode="robust", fleet=(TPU_V5E, TPU_V6E),
                           hardware=TPU_V5E)
    assert with_plans.tile_for("matmul", PROB) == without.tile_for(
        "matmul", PROB)


# -- hot-path wiring: no sweep in serve/train -------------------------------

def _forbid_sweeps(monkeypatch):
    def boom(self, *a, **kw):
        raise AssertionError("Autotuner.sweep invoked on the hot path")
    monkeypatch.setattr(AutotunerClass, "sweep", boom)


def test_serve_engine_resolves_without_sweep(monkeypatch):
    cfg = configs.get_smoke("qwen2-1.5b")
    probs = kernel_problems(cfg, 2, 64, "decode")
    plan = _precompiled_plan(probs)      # AOT compile: sweeps happen HERE
    _forbid_sweeps(monkeypatch)          # ...and nowhere past this point
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_len=64, slots=2, plans=plan)
    assert set(engine.tiles) == set(probs)
    assert all(r.source == "exact"
               for r in engine.tile_resolutions.values())
    engine.add_request(np.asarray([5, 6, 7]), max_new_tokens=4)
    done = engine.run_until_done()
    assert len(done[0].out_tokens) == 4


def test_trainer_resolves_without_sweep(monkeypatch, tmp_path):
    cfg = configs.get_smoke("qwen2-1.5b")
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=4)
    plan = _precompiled_plan(kernel_problems(cfg, 4, 32, "train"))
    _forbid_sweeps(monkeypatch)
    trainer = Trainer(
        cfg, data_cfg,
        TrainerConfig(steps=1, checkpoint_dir=str(tmp_path / "ck")),
        plans=plan)
    assert trainer.tiles and all(
        r.source == "exact" for r in trainer.tile_resolutions.values())


def test_trainer_tolerates_corrupt_plan_artifact(tmp_path):
    bad = tmp_path / "plans.json"
    bad.write_text("garbage")
    cfg = configs.get_smoke("qwen2-1.5b")
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=4)
    trainer = Trainer(
        cfg, data_cfg,
        TrainerConfig(steps=1, checkpoint_dir=str(tmp_path / "ck"),
                      tile_plans=str(bad)))
    assert trainer.tiles == {}  # degraded, not crashed


def _precompiled_plan(problems):
    jobs = [(k, p, "float32", PRODUCTION_TARGET)
            for k, p in problems.items()]
    return compile_plan(jobs)


# -- compile CLI ------------------------------------------------------------

def test_compile_plans_cli(tmp_path):
    out = str(tmp_path / "plans.json")
    compile_plans_cli.main([
        "--out", out, "--archs", "qwen2-1.5b",
        "--hardware", "tpu_v5e", "tpu_v6e", "--curve-cap", "8",
    ])
    plan = TilePlan.load(out)
    assert len(plan.kernels()) >= 3          # matmul, flash_attention, bilinear
    assert len(plan.hardware_names()) >= 2   # the acceptance floor
    for e in plan.entries():
        assert len(e.curve) <= 8
        assert e.tile.dims == e.curve[0][0]  # curve is score-sorted


def test_compile_plans_cli_serve_buckets(tmp_path):
    """--serve-buckets compiles the scheduler's prefill + decode cells."""
    out = str(tmp_path / "plans.json")
    compile_plans_cli.main([
        "--out", out, "--archs", "qwen2-1.5b", "--hardware", "tpu_v5e",
        "--dtypes", "float32", "--curve-cap", "4",
        "--serve-buckets", "16,32", "--serve-slots", "2",
        "--serve-max-len", "64",
    ])
    plan = TilePlan.load(out)
    assert plan.meta["serve_buckets"] == [16, 32]
    # Full-arch prefill cells for each edge (batch=1 -> m=edge tokens).
    cfg = configs.get_arch("qwen2-1.5b")
    for edge in (16, 32):
        assert plan.lookup(
            "matmul", dict(m=edge, k=cfg.d_model, n=cfg.d_ff),
            "float32", "tpu_v5e") is not None
    # Decode cell at the slot batch.
    assert plan.lookup(
        "matmul", dict(m=2, k=cfg.d_model, n=cfg.d_ff),
        "float32", "tpu_v5e") is not None


# -- decode cells: the paper's cross-model claim, asserted for decode --------

def _decode_prob(skv, b=4, d=128, hq=12, hkv=2, window=0):
    return dict(b=b, skv=skv, d=d, hq=hq, hkv=hkv, window=window)


DECODE_CACHE_LENS = (1024, 8192, 32768)


def test_decode_cells_pick_different_bkv_across_hardware():
    """Compile decode-cell plans for two modelled hardware targets and
    assert the cost model picks a different KV split for at least one cell
    — the paper's cross-model claim, now asserted for the decode kernel."""
    from repro.core.plans import compile_entry

    best = {}
    for hw in (TPU_V5E, TPU_V6E):
        for skv in DECODE_CACHE_LENS:
            entry = compile_entry("flash_decode", _decode_prob(skv),
                                  "float32", hw)
            best[(hw.name, skv)] = entry.tile[0]
    diverged = [skv for skv in DECODE_CACHE_LENS
                if best[("tpu_v5e", skv)] != best[("tpu_v6e", skv)]]
    assert diverged, f"no decode cell diverged across hardware: {best}"


def test_decode_cell_goldens():
    """Golden tiles: VMEM capacity bounds the split size per model (v6e has
    2x the VMEM of v5e, so its K/V double-buffer admits a 2x split), and
    small caches keep the whole-cache split (one DMA, no combine)."""
    from repro.core.plans import compile_entry

    expect = {
        ("tpu_v5e", 1024): 1024,
        ("tpu_v5e", 8192): 4096,
        ("tpu_v5e", 32768): 4096,
        ("tpu_v6e", 1024): 1024,
        ("tpu_v6e", 8192): 8192,
        ("tpu_v6e", 32768): 8192,
    }
    for (hw_name, skv), bkv in expect.items():
        hw = TPU_V5E if hw_name == "tpu_v5e" else TPU_V6E
        entry = compile_entry("flash_decode", _decode_prob(skv), "float32",
                              hw)
        assert entry.tile.dims == (bkv,), (
            f"{hw_name} skv={skv}: got {entry.tile}, want ({bkv},)")
        assert entry.dominant == "memory"      # decode is bandwidth-bound
        assert entry.sensitivity > 1.0         # the curve is not flat
        assert entry.curve[0][0] == entry.tile.dims


def test_decode_cells_resolve_for_serve_geometry():
    """kernel_problems' decode cells include flash_decode, and a plan
    compiled from them resolves exactly for the engine geometry."""
    cfg = configs.get_smoke("qwen2-1.5b")
    probs = kernel_problems(cfg, 2, 64, "decode")
    assert "flash_decode" in probs
    assert probs["flash_decode"]["skv"] == 64
    assert probs["flash_decode"]["b"] == 2
    assert "flash_attention" not in probs      # decode is its own kernel
    assert "flash_attention" in kernel_problems(cfg, 2, 64, "prefill")
    plan = _precompiled_plan(probs)
    res = plan.resolve("flash_decode", probs["flash_decode"], "float32",
                       PRODUCTION_TARGET)
    assert res is not None and res.source == "exact"
    assert 64 % res.tile[0] == 0               # legal split for the cache


# -- packed-prefill cells: pack width diverges per hardware model ------------

def _pack_prob(sq, d=128, hq=12, hkv=2, window=0):
    return dict(sq=sq, skv=sq, d=d, hq=hq, hkv=hkv, window=window)


PACK_BUCKET_EDGES = (512, 1024)


def test_packed_cells_pick_different_pack_width_across_hardware():
    """For the SAME bucket set, v5e and v6e compile different pack widths:
    VMEM bounds the resident packed query block, and v6e carries 2x the
    VMEM — the paper's per-model optimum on the pack-width tile axis."""
    from repro.core.plans import compile_entry

    best = {}
    for hw in (TPU_V5E, TPU_V6E):
        for sq in PACK_BUCKET_EDGES:
            entry = compile_entry("packed_prefill", _pack_prob(sq),
                                  "float32", hw)
            best[(hw.name, sq)] = entry.tile[0]
    diverged = [sq for sq in PACK_BUCKET_EDGES
                if best[("tpu_v5e", sq)] != best[("tpu_v6e", sq)]]
    assert diverged, f"no packed cell diverged across hardware: {best}"


def test_packed_cell_goldens():
    """Golden pack widths: the fixed per-step dispatch cost makes wider
    packs strictly cheaper until the resident pack block exhausts VMEM, so
    the optimum is the VMEM-bounded maximum — 2x wider on v6e (2x VMEM)
    than v5e for the same bucket edge."""
    from repro.core.plans import compile_entry

    expect = {
        ("tpu_v5e", 512): (2048, 256),
        ("tpu_v6e", 512): (4096, 256),
        ("tpu_v5e", 1024): (2048, 256),
        ("tpu_v6e", 1024): (4096, 256),
    }
    for (hw_name, sq), tile in expect.items():
        hw = TPU_V5E if hw_name == "tpu_v5e" else TPU_V6E
        entry = compile_entry("packed_prefill", _pack_prob(sq), "float32",
                              hw)
        assert entry.tile.dims == tile, (
            f"{hw_name} sq={sq}: got {entry.tile}, want {tile}")
        assert entry.tile[0] > sq            # pack spans > 1 segment
        assert entry.dominant == "memory"    # dispatch amortization regime
        assert entry.sensitivity > 1.0       # the curve is not flat
        assert entry.curve[0][0] == entry.tile.dims


def test_kernel_problems_packed_kind():
    """kind="packed_prefill" maps the attention cell onto the packed
    kernel (and nothing else changes vs prefill)."""
    cfg = configs.get_smoke("qwen2-1.5b")
    packed = kernel_problems(cfg, 1, 64, "packed_prefill")
    prefill = kernel_problems(cfg, 1, 64, "prefill")
    assert "packed_prefill" in packed
    assert "flash_attention" not in packed
    assert packed["packed_prefill"] == prefill["flash_attention"]
    assert packed["matmul"] == prefill["matmul"]


def test_serve_bucket_cells_include_packed():
    """compile_plans --serve-buckets sweeps a packed-prefill cell per
    bucket edge, so serving artifacts can resolve pack widths exactly."""
    from repro.launch.compile_plans import serve_bucket_cells

    cells = serve_bucket_cells(["qwen2-1.5b"], (16, 32), slots=2,
                               max_len=64, smoke=True)
    packed_sqs = {dict(p)["sq"] for k, p in cells if k == "packed_prefill"}
    assert packed_sqs == {16, 32}
    chunked_sqs = {dict(p)["sq"] for k, p in cells if k == "chunked_prefill"}
    assert chunked_sqs == {16, 32}


# -- kv_page cells: paged-pool page geometry diverges per hardware model -----

def _page_prob(skv, d=128, hkv=8):
    return dict(skv=skv, d=d, hkv=hkv)


KV_PAGE_CACHE_LENS = (1024, 8192, 32768)


def test_kv_page_cells_pick_different_page_across_hardware():
    """For the SAME cache length, v5e and v6e compile different KV page
    sizes: VMEM bounds the resident page a gather/append works on, and v6e
    carries 2x the VMEM — the paper's per-model tile optimum applied to
    the paged pool's page-geometry axis (serve/pool.py)."""
    from repro.core.plans import compile_entry

    best = {}
    for hw in (TPU_V5E, TPU_V6E):
        for skv in KV_PAGE_CACHE_LENS:
            entry = compile_entry("kv_page", _page_prob(skv), "bfloat16", hw)
            best[(hw.name, skv)] = entry.tile[0]
    diverged = [skv for skv in KV_PAGE_CACHE_LENS
                if best[("tpu_v5e", skv)] != best[("tpu_v6e", skv)]]
    assert diverged, f"no kv_page cell diverged across hardware: {best}"


def test_kv_page_cell_goldens():
    """Golden page sizes: larger pages amortize per-page table/DMA
    bookkeeping (fewer pages per request) until the resident page block
    exhausts the VMEM share — so the optimum is the VMEM-bounded maximum,
    2x larger on v6e (2x VMEM) than v5e at steady state, and a short cache
    keeps the whole-cache single page."""
    from repro.core.plans import compile_entry

    expect = {
        ("tpu_v5e", 1024): 1024,
        ("tpu_v5e", 8192): 1024,
        ("tpu_v5e", 32768): 1024,
        ("tpu_v6e", 1024): 1024,
        ("tpu_v6e", 8192): 2048,
        ("tpu_v6e", 32768): 2048,
    }
    for (hw_name, skv), page in expect.items():
        hw = TPU_V5E if hw_name == "tpu_v5e" else TPU_V6E
        entry = compile_entry("kv_page", _page_prob(skv), "bfloat16", hw)
        assert entry.tile.dims == (page,), (
            f"{hw_name} skv={skv}: got {entry.tile}, want ({page},)")
        assert entry.dominant == "memory"    # paging is a bandwidth story
        assert entry.sensitivity > 1.0       # the curve is not flat
        assert entry.curve[0][0] == entry.tile.dims


def test_kernel_problems_decode_includes_kv_page():
    """The kv_page cell rides the decode geometry (the steady-state page
    reader), so --serve-buckets artifacts sweep it with no extra flag."""
    from repro.launch.compile_plans import serve_bucket_cells

    cfg = configs.get_smoke("qwen2-1.5b")
    probs = kernel_problems(cfg, 2, 64, "decode")
    assert "kv_page" in probs
    assert probs["kv_page"]["skv"] == 64
    assert "kv_page" not in kernel_problems(cfg, 1, 64, "prefill")
    cells = serve_bucket_cells(["qwen2-1.5b"], (16, 32), slots=2,
                               max_len=64, smoke=True)
    assert {dict(p)["skv"] for k, p in cells if k == "kv_page"} == {64}


def test_paged_engine_reads_page_from_plan():
    """A paged ServeEngine built on a compiled plan adopts the resolved
    kv_page tile as its pool's page size — the plan actually shapes the
    pool, it is not just bookkeeping."""
    from repro.core.plans import compile_plan as _compile

    cfg = configs.get_smoke("qwen2-1.5b")
    probs = kernel_problems(cfg, 2, 64, "decode")
    plan = _compile([(k, p, "float32", PRODUCTION_TARGET)
                     for k, p in probs.items()])
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=64, slots=2, plans=plan,
                      hardware=PRODUCTION_TARGET, paged=True)
    res = plan.resolve("kv_page", probs["kv_page"], "float32",
                       PRODUCTION_TARGET)
    assert res is not None and res.source == "exact"
    assert eng.pool is not None
    assert eng.pool.page == int(res.tile[0])
    assert eng.max_len % eng.pool.page == 0 or eng.pool.n_pt * \
        eng.pool.page >= eng.max_len


# -- wall-clock measure path -------------------------------------------------

def test_measure_fn_gated_off_without_tpu():
    """On a host backend make_measure_fn must return None (analytic
    fallback) and compile_plan with the factory must equal analytic."""
    from repro.launch.measure import make_measure_fn

    problem = dict(m=64, k=64, n=128)
    assert make_measure_fn("matmul", problem, "float32",
                           PRODUCTION_TARGET) is None
    analytic = compile_plan([("matmul", problem, "float32",
                              PRODUCTION_TARGET)])
    with_factory = compile_plan(
        [("matmul", problem, "float32", PRODUCTION_TARGET)],
        measure_fn_factory=make_measure_fn)
    assert with_factory.meta["measured_jobs"] == 0
    a = analytic.lookup("matmul", problem, "float32", PRODUCTION_TARGET.name)
    b = with_factory.lookup("matmul", problem, "float32",
                            PRODUCTION_TARGET.name)
    assert a.tile == b.tile and a.score_s == b.score_s


def test_measure_fn_drives_sweep_selection():
    """A measure_fn's wall-clock scores outrank the analytic model in
    compile_entry (the real-TPU path, exercised with a fake measurer)."""
    from repro.core.plans import compile_entry

    problem = dict(m=64, k=64, n=128)
    analytic_best = compile_entry("matmul", problem, "float32",
                                  PRODUCTION_TARGET).tile
    # Fake hardware: every tile is "measured" slow except one non-optimal
    # candidate, which must win over the analytic favorite.
    target = None

    def fake_measure(tile):
        nonlocal target
        if target is None and tile != analytic_best:
            target = tile
        return 1e-9 if tile == target else 1.0

    entry = compile_entry("matmul", problem, "float32", PRODUCTION_TARGET,
                          measure_fn=fake_measure)
    assert entry.tile == target
    assert entry.tile != analytic_best
    assert entry.score_s == 1e-9
