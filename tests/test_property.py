"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the hypothesis dev dependency "
           "(pip install -e '.[dev]')",
)
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.kernels.bilinear.ops  # noqa: F401
import repro.kernels.matmul.ops  # noqa: F401
from repro.core import TPU_V5E, estimate
from repro.core.cost_model import TileWorkload
from repro.core.tiling import (
    TileConstraints, TileShape, cdiv, enumerate_tiles, round_up,
)
from repro.kernels.bilinear.bilinear import bilinear_upscale
from repro.kernels.bilinear.ref import bilinear_upscale_ref
from repro.models.layers import apply_rope, rms_norm

COMMON = dict(deadline=None, max_examples=25)


@given(st.integers(1, 10_000), st.integers(1, 512))
@settings(**COMMON)
def test_round_up_properties(x, m):
    r = round_up(x, m)
    assert r >= x and r % m == 0 and r - x < m


@given(st.integers(1, 10_000), st.integers(1, 512))
@settings(**COMMON)
def test_cdiv_properties(a, b):
    assert cdiv(a, b) * b >= a > (cdiv(a, b) - 1) * b


@given(st.integers(64, 2048), st.integers(64, 2048))
@settings(**COMMON)
def test_enumerate_tiles_legal(m, n):
    c = TileConstraints(rank=2, max_dims=(m, n), lane_dim=1, sublane_dim=0)
    tiles = enumerate_tiles(c, TPU_V5E, "float32", lambda t: t.size * 4)
    assert tiles
    budget = TPU_V5E.vmem_bytes * c.vmem_fraction
    for t in tiles:
        assert t[0] <= m and t[1] <= n
        assert t.size * 4 <= budget


@given(st.floats(1e6, 1e12), st.floats(1e3, 1e9))
@settings(**COMMON)
def test_cost_monotone_in_flops(flops, hbm):
    w1 = TileWorkload(flops=flops, hbm_bytes=hbm, row_segments=1,
                      row_stride_bytes=4096.0)
    w2 = TileWorkload(flops=flops * 2, hbm_bytes=hbm, row_segments=1,
                      row_stride_bytes=4096.0)
    c1 = estimate(TPU_V5E, w1, 10, vmem_bytes=1024.0)
    c2 = estimate(TPU_V5E, w2, 10, vmem_bytes=1024.0)
    assert c2.total_s >= c1.total_s


@given(st.integers(2, 6), st.integers(1, 4), st.sampled_from([2, 3, 4, 5]))
@settings(deadline=None, max_examples=10)
def test_bilinear_kernel_matches_ref_random_shapes(h8, w8, scale):
    h, w = h8 * 8, w8 * 16
    src = jax.random.uniform(jax.random.PRNGKey(h * w), (h, w), jnp.float32)
    ref = bilinear_upscale_ref(src, scale)
    out = bilinear_upscale(src, scale, tile=(h * scale, w * scale),
                           interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@given(st.integers(0, 10))
@settings(**COMMON)
def test_rms_norm_scale_invariance(seed):
    """rms_norm(c*x) == rms_norm(x) for c > 0 (scale invariance)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 32))
    w = jnp.zeros(32)
    a = rms_norm(x, w)
    b = rms_norm(x * 7.0, w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-5)


@given(st.integers(0, 10))
@settings(**COMMON)
def test_rope_norm_preserving(seed):
    """Rotary embedding is a rotation: preserves per-pair norms."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 8, 64))
    pos = jnp.arange(8)[None]
    y = apply_rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-4)


@given(st.integers(1, 64), st.integers(1, 8))
@settings(**COMMON)
def test_tileshape_ordering_total(size, rank):
    dims = tuple([size] * rank)
    t = TileShape(dims)
    assert t.size == size ** rank
    assert len(t) == rank


@given(st.integers(0, 20))
@settings(deadline=None, max_examples=8)
def test_quantize_idempotent_on_grid(seed):
    from repro.optim.compression import _quantize
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,))
    q, s = _quantize(x)
    deq = q.astype(jnp.float32) * s
    q2, s2 = _quantize(deq)
    np.testing.assert_allclose(np.asarray(q), np.asarray(q2), atol=1)
